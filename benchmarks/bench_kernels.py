"""Kernel-layer benchmarks: us_per_call of the jit'd XLA paths at model
shapes (the executable proxy on CPU), with the Pallas kernels validated
separately in interpret mode (tests/test_kernels.py).  On TPU the same
entry points dispatch to the Mosaic kernels."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.models.ssm import chunked_gla


def _bench(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_attention():
    rows = []
    key = jax.random.PRNGKey(0)
    for (name, B, H, S, D) in [("attn_1k", 1, 8, 1024, 64),
                               ("attn_4k_swa", 1, 4, 4096, 64)]:
        window = 512 if "swa" in name else 0
        q = jax.random.normal(key, (B, H, S, D), jnp.bfloat16)
        f = jax.jit(lambda q: ref.attention_ref(q, q, q, causal=True,
                                                window=window))
        us = _bench(f, q)
        flops = 4 * B * H * S * S * D / 2   # causal
        rows.append((name, us, f"{flops/us/1e3:.1f}GFLOP/s_cpu"))
    return rows


def bench_gla():
    key = jax.random.PRNGKey(1)
    B, H, S, N, P = 2, 8, 2048, 64, 64
    q = jax.random.normal(key, (B, S, H, N), jnp.float32) * 0.3
    v = jax.random.normal(key, (B, S, H, P), jnp.float32)
    la = -jnp.abs(jax.random.normal(key, (B, S, H))) * 0.1
    f = jax.jit(lambda q, v, la: chunked_gla(q, q, v, la, chunk=256)[0])
    us = _bench(f, q, v, la)
    return [("ssd_chunked_2k", us, f"chunk=256")]


def bench_router():
    key = jax.random.PRNGKey(2)
    T, E, K = 8192, 64, 8
    logits = jax.random.normal(key, (T, E))
    f = jax.jit(lambda l: ref.router_topk_ref(l, K, 256))
    us = _bench(f, logits)
    return [("router_topk_8k_64e", us, f"{T/us:.1f}tok/us")]
