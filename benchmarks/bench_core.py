"""Benchmarks for the paper's own performance claims (Secs. 2, 13).

Thread-tier farm/pipeline benchmarks use GIL-releasing tasks (timed sleeps
= I/O-shaped service times) to measure the *scheduling* behaviour the paper
describes — speedup ~ nw for farms, service time ~ max stage for pipelines
— independent of core count.  ``bench_farm_backends`` measures the
multicore claim itself: a CPU-bound numpy farm as GIL-serialized threads vs
as OS processes over shared-memory SPSC lanes (the process-backed host
tier), recording the throughput ratio; ``bench_a2a_backends`` does the same
for ``all_to_all`` over the shm MPMC lane grid.  The device-level
equivalents of these claims are exercised by the dry-run roofline instead
(benchmarks/roofline.py).

``bench_shm_transport`` measures the batched-transport claim directly:
vectored ``push_many``/``pop_many`` vs per-item push/pop on a cross-process
shm lane (small items, interleaved pairs, best demonstrated ratio — the
acceptance bar is >=3x) and the slab arena's streaming bandwidth for
ndarrays too large for a ring slot.

``bench_adaptive`` measures the adaptive runtime's two costs: the live
drain-and-swap reconfiguration latency (``reconfig_latency_ms``) and the
throughput overhead of an attached sampling Supervisor (as a
plain-vs-supervised ratio).  ``bench_net_hop`` measures the distributed
tier's channel: loopback ``NetLane`` round-trip to a worker pool
(``net_rtt_us``) and pipelined credit-window streaming throughput.

``bench_serving`` (benchmarks/bench_serving.py) replays an open-loop
Poisson arrival process against the continuous-batching serving engine at
2x its measured capacity: p50/p99 submit->finish latency of admitted
requests (``latency_ms``, ``latency_p99_ms``), ``goodput_items_per_s``,
and the typed-``Overloaded`` shed count — the SLO tier's bound-the-tail
claim, measured where closed-loop clients would hide it.

The ``--smoke`` JSON artifact carries machine-readable ``items_per_s`` /
``ratio_best`` / ``reconfig_latency_ms`` / ``net_rtt_us`` /
``latency_ms`` / ``goodput_items_per_s`` fields per metric; CI's
bench-compare step fails the build when throughput regresses >30% or a
latency metric grows past its (generous, machine-normalized) bound
against the committed ``benchmarks/BENCH_baseline.json`` (see
``tools/bench_compare.py``).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
from typing import List

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import Farm, FFNode, FF_EOS, FnNode, GO_ON, Pipeline
from repro.core import perf_model as pm
from repro.core.queues import SPSCQueue


def _timeit(fn, repeat=3):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# --- L1: SPSC queue throughput (paper Sec. 2 lock-free claim) -----------------
def bench_spsc_queue(n=200_000):
    q = SPSCQueue(1024)

    def run():
        k = 0
        for i in range(n):
            while not q.try_push(i):
                pass
            ok, _ = q.try_pop()
            k += ok
    dt = _timeit(run)
    us = dt / n * 1e6
    return [("spsc_push_pop", us, f"{1/ (dt/n)/1e6:.2f}Mops/s")]


# --- Sec. 13: farm speedup ~ T_seq / nw ----------------------------------------
class _SleepWorker(FFNode):
    def __init__(self, t):
        super().__init__()
        self.t = t

    def svc(self, task):
        time.sleep(self.t)
        return task


def bench_farm_speedup(m_tasks=32, t_task=0.01):
    rows = []
    base = m_tasks * t_task
    for nw in (1, 2, 4, 8):
        class Em(FFNode):
            def __init__(self):
                super().__init__()
                self.i = 0

            def svc(self, _):
                self.i += 1
                return self.i if self.i <= m_tasks else None

        f = Farm([_SleepWorker(t_task) for _ in range(nw)])
        f.add_emitter(Em()).add_collector(FnNode(lambda t: GO_ON))
        t0 = time.perf_counter()
        assert f.run_and_wait_end() == 0
        dt = time.perf_counter() - t0
        measured = base / dt
        predicted = pm.farm_speedup(m_tasks, t_task, nw)
        rows.append((f"farm_speedup_nw{nw}", dt / m_tasks * 1e6,
                     f"speedup={measured:.2f} predicted={predicted:.2f}"))
    return rows


# --- Sec. 13: pipeline service time = max stage time ----------------------------
def bench_pipeline_service_time(m_tasks=30):
    stage_times = [0.002, 0.008, 0.004]      # bottleneck = 8 ms

    class Gen(FFNode):
        def __init__(self):
            super().__init__()
            self.i = 0

        def svc(self, _):
            self.i += 1
            return self.i if self.i <= m_tasks else None

    stages = [Gen()] + [_SleepWorker(t) for t in stage_times]
    p = Pipeline(*stages)
    t0 = time.perf_counter()
    assert p.run_and_wait_end() == 0
    dt = time.perf_counter() - t0
    measured_service = dt / m_tasks
    predicted = pm.pipeline_service_time(stage_times)
    return [("pipeline_service_time", measured_service * 1e6,
             f"predicted={predicted*1e6:.0f}us ratio="
             f"{measured_service/predicted:.2f}")]


# --- Sec. 9: accelerator offload hides latency ----------------------------------
def bench_accelerator_offload(n=16, t_task=0.01):
    import jax
    from repro.core import JaxAccelerator

    def f(x):
        time.sleep(t_task)       # stand-in for device compute (GIL released)
        return x

    # inline baseline
    t0 = time.perf_counter()
    for i in range(n):
        f(i)
        time.sleep(t_task)       # interleaved host work
    inline = time.perf_counter() - t0

    acc = JaxAccelerator(f, max_inflight=n)
    acc.run_then_freeze()
    t0 = time.perf_counter()
    for i in range(n):
        acc.offload(i)
        time.sleep(t_task)       # host work overlaps accelerator work
    acc.offload(FF_EOS)
    while acc.load_result()[0]:
        pass
    acc.wait()
    overlapped = time.perf_counter() - t0
    return [("accelerator_offload", overlapped / n * 1e6,
             f"inline={inline:.3f}s overlapped={overlapped:.3f}s "
             f"hide={inline/overlapped:.2f}x")]


# --- staged graph compiler: compile latency + hybrid throughput ---------------
def bench_graph_compile(smoke: bool = False, repeat: int = 20):
    """Wall time of the four-stage compile pipeline (normalize -> annotate ->
    place -> emit) for a representative host graph — the cost a consumer
    pays per fresh runner (threads start later, at run)."""
    from repro.core import farm, pipeline

    def build():
        return pipeline(lambda x: x + 1.0,
                        farm(lambda x: x * 2.0, n=4),
                        lambda x: x - 3.0)

    n = 5 if smoke else repeat
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        build().compile()
        best = min(best, time.perf_counter() - t0)
    return [("graph_compile", best * 1e6, "normalize+annotate+place+emit")]


class _GenNode(FFNode):
    def __init__(self, n):
        super().__init__()
        self.i, self.n = 0, n

    def svc(self, _):
        import numpy as np
        self.i += 1
        return np.float32(self.i) if self.i <= self.n else None


def bench_hybrid_pipeline(smoke: bool = False):
    """Throughput of a hybrid plan: a stateful host reader feeding a
    flops-declared compute farm that place() puts on the mesh behind a
    device-put boundary node, vs. the same graph pinned all-host."""
    from repro.core import farm, pipeline
    from repro.core.plan import single_device_plan

    plan = single_device_plan()
    n_items = 64 if smoke else 512

    def heavy(x):
        return x * 2.0 + 1.0
    heavy.ff_flops = 1e9

    rows = []
    for mode, label in (("auto", "hybrid"), ("host", "host")):
        g = pipeline(_GenNode(n_items), farm(heavy, n=2))
        r = g.compile(plan, mode=mode)
        t0 = time.perf_counter()
        out = r.run()
        dt = time.perf_counter() - t0
        assert len(out) == n_items
        targets = "+".join(p.target for _, p in r.placements)
        rows.append((f"graph_pipeline_{label}", dt / n_items * 1e6,
                     f"{n_items/dt:.0f}items/s placements={targets}",
                     {"items_per_s": round(n_items / dt, 1)}))
    return rows


# --- host tier: thread farm vs process farm on CPU-bound numpy work -----------
def _gil_bound_numpy_task(x):
    """CPU-bound numpy stage in the fine-grain streaming mold: per-element
    work driven by the interpreter over numpy scalars, which never releases
    the GIL — so a thread farm serializes (and convoys) on it while the
    process tier gets true multicore parallelism."""
    s = 0.0
    v0 = float(x[0])
    v1 = float(x[1])
    for i in range(120_000):
        s += (v0 * i + v1) % 7.3
    return s


class _ArrGen(FFNode):
    def __init__(self, n):
        super().__init__()
        import numpy as np
        self.i, self.n = 0, n
        self.x = np.linspace(1.0, 2.0, 8, dtype=np.float32)

    def svc(self, _):
        self.i += 1
        return self.x * self.i if self.i <= self.n else None


def bench_farm_backends(smoke: bool = False, nw: int = 2):
    """The multicore-true claim: the same CPU-bound numpy farm as threads
    (GIL-serialized) vs as processes over shared-memory SPSC lanes, plus
    what cost-driven auto placement picks for it from the calibrated
    constants.

    Shared/throttled hosts make one-shot timings swing 2x (and under-report
    a small true advantage), so the two backends run as adjacent pairs in
    alternating order (both sides see the same noise phases) and the bench
    records the *best demonstrated* pair ratio — the capability claim — with
    the median ratio alongside for the central tendency."""
    import statistics

    import numpy as np
    from repro.core import farm, pipeline
    from repro.core import perf_model as pm

    n_items = 16 if smoke else 32
    n_pairs = 7 if smoke else 9

    def run_once(mode: str) -> float:
        g = pipeline(_ArrGen(n_items), farm(_gil_bound_numpy_task, n=nw))
        r = g.compile(mode=mode)
        t0 = time.perf_counter()
        out = r.run()
        dt = time.perf_counter() - t0
        assert len(out) == n_items
        return dt / n_items

    thread_t, proc_t, ratios = [], [], []
    for i in range(n_pairs):
        if i % 2 == 0:
            th = run_once("host")
            pr = run_once("process")
        else:
            pr = run_once("process")
            th = run_once("host")
        thread_t.append(th)
        proc_t.append(pr)
        ratios.append(th / pr)
    th_med = statistics.median(thread_t)
    pr_med = statistics.median(proc_t)
    best = max(ratios)
    med = statistics.median(ratios)
    rows = [(f"farm_thread_nw{nw}", th_med * 1e6, f"{1/th_med:.0f}items/s",
             {"items_per_s": round(1 / th_med, 1)}),
            (f"farm_process_nw{nw}", pr_med * 1e6, f"{1/pr_med:.0f}items/s",
             {"items_per_s": round(1 / pr_med, 1)})]
    auto = pipeline(_ArrGen(4), farm(_gil_bound_numpy_task, n=nw)).compile(
        sample=np.linspace(1.0, 2.0, 8, dtype=np.float32))
    auto_target = [p.target for d, p in auto.placements if "farm" in d]
    calib = pm.get_calibration(measure=False)
    del auto                    # release the never-run runner's shm workers
    import gc
    gc.collect()
    rows.append(("farm_process_vs_thread", pr_med * 1e6,
                 f"ratio={best:.2f}x (best of {n_pairs} interleaved pairs; "
                 f"median={med:.2f}x) auto={auto_target} "
                 f"calib={calib.source} "
                 f"proc_hop={calib.proc_hop_s*1e6:.1f}us",
                 {"ratio_best": round(best, 3),
                  "ratio_median": round(med, 3)}))
    return rows


# --- host tier: thread a2a vs process a2a on CPU-bound numpy work --------------
def _gil_bound_a2a_left(x):
    """Left-side a2a stage: interpreter-driven per-element work (never
    releases the GIL)."""
    s = 0.0
    v0 = float(x[0])
    v1 = float(x[1])
    for i in range(60_000):
        s += (v0 * i + v1) % 7.3
    return x * (1.0 + s % 2.0)


def _gil_bound_a2a_right(y):
    """Right-side a2a stage, same fine-grain GIL-bound mold."""
    s = 0.0
    v = float(y[0])
    for i in range(60_000):
        s += (v * i + 0.7) % 5.1
    return s


def _a2a_spread_router(y, n_right):
    return int(float(y[2]) * 10.0) % n_right


def bench_a2a_backends(smoke: bool = False, nl: int = 2, nr: int = 2):
    """The process-backed ``all_to_all`` claim: the same CPU-bound a2a as
    GIL-serialized threads vs as OS processes over the shared-memory MPMC
    lane grid.  Same noisy-runner discipline as ``bench_farm_backends``:
    interleaved adjacent pairs, best demonstrated pair ratio recorded with
    the median alongside."""
    import statistics

    from repro.core import all_to_all, pipeline

    n_items = 12 if smoke else 24
    n_pairs = 5 if smoke else 9

    def run_once(mode: str) -> float:
        g = pipeline(_ArrGen(n_items),
                     all_to_all([_gil_bound_a2a_left] * nl,
                                [_gil_bound_a2a_right] * nr,
                                router=_a2a_spread_router))
        r = g.compile(mode=mode)
        t0 = time.perf_counter()
        out = r.run(timeout=300.0)
        dt = time.perf_counter() - t0
        assert len(out) == n_items
        return dt / n_items

    thread_t, proc_t, ratios = [], [], []
    for i in range(n_pairs):
        if i % 2 == 0:
            th = run_once("host")
            pr = run_once("process")
        else:
            pr = run_once("process")
            th = run_once("host")
        thread_t.append(th)
        proc_t.append(pr)
        ratios.append(th / pr)
    th_med = statistics.median(thread_t)
    pr_med = statistics.median(proc_t)
    best = max(ratios)
    med = statistics.median(ratios)
    return [
        (f"a2a_thread_{nl}x{nr}", th_med * 1e6, f"{1/th_med:.0f}items/s",
         {"items_per_s": round(1 / th_med, 1)}),
        (f"a2a_process_{nl}x{nr}", pr_med * 1e6, f"{1/pr_med:.0f}items/s",
         {"items_per_s": round(1 / pr_med, 1)}),
        (f"a2a_process_vs_thread", pr_med * 1e6,
         f"ratio={best:.2f}x (best of {n_pairs} interleaved pairs; "
         f"median={med:.2f}x)",
         {"ratio_best": round(best, 3), "ratio_median": round(med, 3)}),
    ]


# --- shm transport: vectored lanes + slab arena --------------------------------
def bench_shm_transport(smoke: bool = False):
    """The batched-transport claims the CI gate watches:

    - ``shm_vectored_vs_per_item``: per-item cost of a cross-process shm
      lane driven with ``push_many``/``pop_many`` vs one driven per item,
      on small items (where the index traffic and pickling dominate) —
      the amortization the 2009 FastFlow TR's batched queues claim.  Same
      noisy-runner discipline as ``bench_farm_backends``: interleaved
      adjacent pairs, best demonstrated pair ratio recorded (the
      acceptance bar is >=3x);
    - ``shm_batched_lane``: the batched lane's absolute per-item
      throughput (machine-normalized by the gate);
    - ``shm_arena_bw``: streaming bandwidth of the slab-arena path for
      ndarrays too large for a ring slot (producer copy in + consumer
      copy out), as large-array items/s."""
    import statistics

    from repro.core.perf_model import (_measure_arena_bw, _measure_proc_hop,
                                       _measure_shm_batched_hop)

    n = 200 if smoke else 1000
    n_pairs = 3 if smoke else 5
    per_item, batched, ratios = [], [], []
    for i in range(n_pairs):
        if i % 2 == 0:
            p = _measure_proc_hop(n)
            b = _measure_shm_batched_hop(2 * n)
        else:
            b = _measure_shm_batched_hop(2 * n)
            p = _measure_proc_hop(n)
        per_item.append(p)
        batched.append(b)
        ratios.append(p / b)
    p_med = statistics.median(per_item)
    b_med = statistics.median(batched)
    best = max(ratios)
    med = statistics.median(ratios)
    arena_nbytes = 4 << 20
    bw = _measure_arena_bw(arena_nbytes, reps=3 if smoke else 5)
    arena_per_item = arena_nbytes / (bw * 1e9)
    return [
        ("shm_per_item_lane", p_med * 1e6, f"{1/p_med:.0f}items/s",
         {"items_per_s": round(1 / p_med, 1)}),
        ("shm_batched_lane", b_med * 1e6, f"{1/b_med:.0f}items/s",
         {"items_per_s": round(1 / b_med, 1)}),
        ("shm_vectored_vs_per_item", b_med * 1e6,
         f"ratio={best:.2f}x (best of {n_pairs} interleaved pairs; "
         f"median={med:.2f}x) per_item={p_med*1e6:.1f}us "
         f"batched={b_med*1e6:.1f}us",
         {"ratio_best": round(best, 3), "ratio_median": round(med, 3)}),
        ("shm_arena_bw", arena_per_item * 1e6,
         f"{bw:.2f}GB/s streaming 4MiB arrays through the slab arena",
         {"items_per_s": round(1 / arena_per_item, 1)}),
    ]


# --- adaptive runtime: reconfig latency + supervisor overhead ------------------
def _adaptive_light_task(x):
    return x * 1.0017


# --- distributed tier: the loopback network-lane hop ---------------------------
def _net_echo_task(x):
    """Identity worker: the bench isolates the lane, not the work."""
    return x


def bench_net_hop(smoke: bool = False):
    """The distributed tier's channel costs the CI gate watches:

    - ``net_rtt_us``: best round-trip of one item through a loopback
      ``NetLane`` to a ``worker_main`` pool and back — the per-item price
      of leaving the host, and the floor under every ``host_remote``
      placement decision (``perf_model`` calibrates ``net_hop_s`` from the
      same loopback measurement);
    - ``net_stream``: pipelined throughput over the same lane with the
      credit window keeping items in flight — what a remote farm's
      emitter/collector actually sustains."""
    import statistics
    import threading

    import numpy as np
    from repro.core.net import NetLane, spawn_loopback_pool
    from repro.core.shm import WorkerStats

    n_ping = 50 if smoke else 200
    n_stream = 256 if smoke else 1024
    x = np.linspace(1.0, 2.0, 8, dtype=np.float32)

    def pop_data(timeout=60.0):
        while True:                 # periodic WorkerStats ride the same lane
            item, _ = lane.pop_seq(timeout=timeout)
            if not isinstance(item, WorkerStats):
                return item

    addrs, procs = spawn_loopback_pool(1)
    try:
        lane = NetLane.connect(*addrs[0], credit=64)
        try:
            lane.push_fn(_net_echo_task)
            seq = 0
            lane.push(x, timeout=30.0, seq=seq)     # warm the path
            pop_data()
            seq += 1
            rtts = []
            for _ in range(n_ping):
                t0 = time.perf_counter()
                lane.push(x, timeout=30.0, seq=seq)
                pop_data()
                rtts.append(time.perf_counter() - t0)
                seq += 1

            def feed(base):
                for i in range(n_stream):
                    lane.push(x, timeout=60.0, seq=base + i)
            t = threading.Thread(target=feed, args=(seq,), daemon=True)
            t0 = time.perf_counter()
            t.start()
            for _ in range(n_stream):
                pop_data()
            dt = time.perf_counter() - t0
            t.join()
            lane.push_eos()
        finally:
            lane.shutdown()
    finally:
        for p in procs:
            p.terminate()
        for p in procs:
            p.join(timeout=10.0)
    best_rtt = min(rtts)
    per_item = dt / n_stream
    return [
        ("net_hop_roundtrip", best_rtt * 1e6,
         f"best of {n_ping} loopback ping-pongs; median="
         f"{statistics.median(rtts)*1e6:.0f}us",
         {"net_rtt_us": round(best_rtt * 1e6, 1)}),
        ("net_stream", per_item * 1e6,
         f"{1/per_item:.0f}items/s pipelined over a credit-64 lane",
         {"items_per_s": round(1 / per_item, 1)}),
    ]


# --- device tier: fused segment (one jitted program) vs per-stage dispatch ----
def bench_device_fusion(smoke: bool = False):
    """The device-segment-fusion gate: the same 4-stage pure pipeline on the
    device tier, compiled fused (ONE jitted program, one dispatch + one host
    sync per run — ``core/fuse.py``) vs per-stage (``fuse=False``: four
    dispatches + four ``block_until_ready`` host round-trips per run, the
    pre-fusion emit).  Same interleaved-adjacent-pairs protocol as the farm
    benches; ``ratio_best`` is the demonstrated fused speedup the CI gate
    holds."""
    import statistics

    import jax.numpy as jnp
    import numpy as np
    from repro.core import pipeline
    from repro.core.plan import single_device_plan

    plan = single_device_plan()
    # short runs: per-run dispatch + host-sync overhead is the quantity
    # under test, and it is a fixed per-run cost — small streams keep it
    # from being diluted by per-item work
    n_items = 4
    n_runs = 16 if smoke else 32
    n_pairs = 7 if smoke else 9
    item = np.linspace(0.0, 1.0, 64, dtype=np.float32)
    stream = [item * (i + 1) for i in range(n_items)]

    def build(fuse: bool):
        g = pipeline(lambda x: x * 1.0001 + 0.1,
                     lambda x: jnp.tanh(x) + x,
                     lambda x: x * 0.999 - 0.05,
                     lambda x: (x + x * x) * 0.5)
        return g.compile(plan, mode="device", fuse=fuse)

    fused, per_stage = build(True), build(False)
    assert len(fused.stats()["stages"]) == 1          # one program per run
    assert len(per_stage.stats()["stages"]) == 4      # pre-fusion split

    def run_once(r) -> float:
        t0 = time.perf_counter()
        for _ in range(n_runs):
            out = r.run(stream)
        dt = time.perf_counter() - t0
        assert len(out) == n_items
        return dt / (n_runs * n_items)

    run_once(fused)                 # warmup: pay the traces outside pair 0
    run_once(per_stage)
    fused_t, split_t, ratios = [], [], []
    for i in range(n_pairs):
        if i % 2 == 0:
            fu = run_once(fused)
            sp = run_once(per_stage)
        else:
            sp = run_once(per_stage)
            fu = run_once(fused)
        fused_t.append(fu)
        split_t.append(sp)
        ratios.append(sp / fu)
    fu_med = statistics.median(fused_t)
    sp_med = statistics.median(split_t)
    best = max(ratios)
    med = statistics.median(ratios)
    return [
        ("device_pipeline_fused", fu_med * 1e6, f"{1/fu_med:.0f}items/s",
         {"items_per_s": round(1 / fu_med, 1)}),
        ("device_pipeline_per_stage", sp_med * 1e6, f"{1/sp_med:.0f}items/s",
         {"items_per_s": round(1 / sp_med, 1)}),
        ("device_fusion_speedup", fu_med * 1e6,
         f"ratio={best:.2f}x (best of {n_pairs} interleaved pairs; "
         f"median={med:.2f}x) 4 stages -> 1 program",
         {"ratio_best": round(best, 3), "ratio_median": round(med, 3)}),
    ]


def bench_device_overlap(smoke: bool = False):
    """The overlapped-boundary gate: the same transfer-heavy hybrid
    pipeline (host feeder -> device segment -> host consumer) compiled with
    the depth-K asynchronous in-flight window (``overlap=True``: microbatch
    i+1 stacks and dispatches, and i-1 copies out, while i computes — no
    per-microbatch ``block_until_ready``) vs the strictly synchronous
    boundary (``overlap=False``: put -> compute -> copy-out per microbatch,
    the pre-overlap emit).  Small microbatches and a window covering the
    stream make the per-microbatch host sync round-trips the quantity under
    test.  Outputs are asserted byte-identical first — only the
    synchronization point moves.  Same interleaved-adjacent-pairs protocol
    as the farm and fusion benches; ``ratio_best`` is the demonstrated
    overlap speedup the CI gate holds."""
    import statistics

    import jax.numpy as jnp
    import numpy as np
    from repro.core import pipeline
    from repro.core.compiler import CompileConfig
    from repro.core.plan import single_device_plan

    plan = single_device_plan()
    n_items = 32
    n_runs = 4 if smoke else 8
    n_pairs = 7 if smoke else 9
    microbatch, inflight = 2, 16        # window covers the whole stream
    base = np.linspace(-1.0, 1.0, 64, dtype=np.float32)
    stream = [base * (1.0 + 0.001 * i) for i in range(n_items)]
    dev = lambda x: jnp.tanh(x) + x * 0.5   # noqa: E731

    def build(overlap: bool):
        g = pipeline(lambda x: np.asarray(x) * 1.0001, dev,
                     lambda y: np.asarray(y) * 1.0)
        return g.compile(config=CompileConfig(
            plan=plan, microbatch=microbatch, inflight=inflight,
            overlap=overlap, normalize=False,
            placements={0: "host", 1: "device", 2: "host"}))

    # warmup pays the jit traces — and proves overlap-off parity is
    # byte-identical (the acceptance bar for moving the sync point)
    a, b = build(True).run(stream), build(False).run(stream)
    assert ([np.asarray(y).tobytes() for y in a]
            == [np.asarray(y).tobytes() for y in b])

    def run_once(overlap: bool) -> float:
        t0 = time.perf_counter()
        for _ in range(n_runs):
            out = build(overlap).run(stream)
        dt = time.perf_counter() - t0
        assert len(out) == n_items
        return dt / (n_runs * n_items)

    ov_t, sy_t, ratios = [], [], []
    for i in range(n_pairs):
        if i % 2 == 0:
            ov = run_once(True)
            sy = run_once(False)
        else:
            sy = run_once(False)
            ov = run_once(True)
        ov_t.append(ov)
        sy_t.append(sy)
        ratios.append(sy / ov)
    ov_med = statistics.median(ov_t)
    sy_med = statistics.median(sy_t)
    best = max(ratios)
    med = statistics.median(ratios)
    return [
        ("device_boundary_overlapped", ov_med * 1e6,
         f"{1/ov_med:.0f}items/s inflight={inflight}",
         {"items_per_s": round(1 / ov_med, 1)}),
        ("device_boundary_sync", sy_med * 1e6,
         f"{1/sy_med:.0f}items/s per-microbatch sync",
         {"items_per_s": round(1 / sy_med, 1)}),
        ("device_overlap_speedup", ov_med * 1e6,
         f"ratio={best:.2f}x (best of {n_pairs} interleaved pairs; "
         f"median={med:.2f}x) async window vs per-microbatch sync",
         {"ratio_best": round(best, 3), "ratio_median": round(med, 3)}),
    ]


def bench_adaptive(smoke: bool = False):
    """The adaptive-runtime costs the CI gate watches:

    - ``reconfig_latency_ms``: wall time of one live drain-and-swap tier
      migration (thread -> process, then back) on a streaming adaptive farm
      — the price of a supervisor decision, dominated by the engine drain
      and the process-tier fork;
    - ``adaptive_supervisor_overhead``: throughput of an adaptive pipeline
      with a fast-sampling Supervisor attached vs the same pipeline without
      one, as a ratio (~1.0 when the supervisor is cheap), measured as
      interleaved adjacent pairs like the farm benches."""
    import statistics

    from repro.core import farm, pipeline
    from repro.core.runtime import Supervisor

    n_items = 256 if smoke else 1024
    n_pairs = 3 if smoke else 5

    def run_once(supervised: bool) -> float:
        g = pipeline(_GenNode(n_items), farm(_adaptive_light_task, n=2))
        r = g.compile(mode="host", adaptive=True)
        # observe-only: resize/migrate off, so the metric isolates the cost
        # of the attached sampler (policy churn would perturb throughput and
        # turn the CI gate into a noise comparison)
        sup = Supervisor(r, interval=0.002, resize=False, migrate=False) \
            if supervised else None
        if sup:
            sup.start()
        t0 = time.perf_counter()
        out = r.run()
        dt = time.perf_counter() - t0
        if sup:
            sup.stop()
        assert len(out) == n_items
        return dt / n_items

    run_once(False)                 # discard one warmup run: the very first
    #                                 pipeline pays thread spin-up / import
    #                                 costs that would skew pair 0's ratio
    plain_t, sup_t, ratios = [], [], []
    for i in range(n_pairs):
        if i % 2 == 0:
            pl = run_once(False)
            su = run_once(True)
        else:
            su = run_once(True)
            pl = run_once(False)
        plain_t.append(pl)
        sup_t.append(su)
        ratios.append(pl / su)      # >1 would mean supervised was FASTER
    best = max(ratios)
    med = statistics.median(ratios)

    # reconfig latency: migrate a lightly-loaded streaming farm there and
    # back; best of a few swaps is the capability number (the worst swap on
    # a noisy host measures the noise)
    from repro.core import EOS as _EOS
    g = farm(_adaptive_light_task, n=2)
    r = g.compile(mode="host", adaptive=True)
    r.run_then_freeze()
    h = r.stage_handles()[0]
    import threading

    stop = threading.Event()

    def pump():                     # keep a trickle of items in flight
        i = 0
        while not stop.is_set():
            r.offload(float(i))
            i += 1
            time.sleep(1e-3)
    threading.Thread(target=pump, daemon=True).start()
    drain = threading.Thread(
        target=lambda: [None for _ in iter(lambda: r.load_result()[0], False)],
        daemon=True)
    drain.start()
    lat = []
    time.sleep(0.05)
    for _ in range(2 if smoke else 3):
        for tier in ("host_process", "host"):
            t0 = time.perf_counter()
            h.migrate(tier)
            lat.append((time.perf_counter() - t0) * 1e3)
    stop.set()
    r.offload(_EOS)
    r.wait(30.0)
    best_lat = min(lat)
    return [
        ("adaptive_supervisor_overhead", statistics.median(sup_t) * 1e6,
         f"ratio={best:.2f}x (best of {n_pairs} interleaved pairs; "
         f"median={med:.2f}x; >=1 means free)",
         {"ratio_best": round(best, 3), "ratio_median": round(med, 3)}),
        ("adaptive_reconfig", best_lat * 1e3,
         f"best of {len(lat)} live tier swaps; median="
         f"{statistics.median(lat):.1f}ms",
         {"reconfig_latency_ms": round(best_lat, 2)}),
    ]


def _bench_serving(smoke: bool):
    # open-loop Poisson replay against the serving engine: p50/p99 latency
    # of admitted requests + goodput under 2x overload (bench_serving.py)
    from bench_serving import bench_serving
    return bench_serving(smoke)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset for CI; emits the JSON artifact")
    ap.add_argument("--out", default="BENCH_graph.json",
                    help="JSON artifact path (graph compile + hybrid "
                         "pipeline throughput)")
    args = ap.parse_args()

    benches = [lambda: bench_graph_compile(args.smoke),
               lambda: bench_hybrid_pipeline(args.smoke),
               lambda: bench_farm_backends(args.smoke),
               lambda: bench_a2a_backends(args.smoke),
               lambda: bench_shm_transport(args.smoke),
               lambda: bench_net_hop(args.smoke),
               lambda: bench_device_fusion(args.smoke),
               lambda: bench_device_overlap(args.smoke),
               lambda: bench_adaptive(args.smoke),
               lambda: _bench_serving(args.smoke)]
    if not args.smoke:
        benches += [bench_spsc_queue, bench_farm_speedup,
                    bench_pipeline_service_time, bench_accelerator_offload]
    results = {}
    print("name,us_per_call,derived")
    for b in benches:
        for row in b():
            name, us, derived = row[:3]
            rec = {"us_per_call": round(us, 2), "derived": derived}
            if len(row) > 3:
                # machine-readable throughput/ratio fields: what
                # tools/bench_compare.py gates CI on
                rec.update(row[3])
            results[name] = rec
            print(f"{name},{us:.1f},{derived}")
    with open(args.out, "w") as f:
        json.dump({"bench": "graph", "smoke": args.smoke,
                   "results": results}, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
