"""End-to-end step benchmarks on CPU (tiny configs): tokens/s through the
full train step and the serving engine — the 'whole system' numbers that
complement the per-layer rooflines."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core.plan import single_device_plan
from repro.runtime.steps import (init_state, make_decode_step,
                                 make_prefill_step, make_train_step)


def bench_train_step():
    plan = single_device_plan()
    cfg = get("ff-tiny")
    state = init_state(cfg, plan, jax.random.PRNGKey(0))
    B, S = 4, 256
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab)}
    step = jax.jit(make_train_step(cfg, plan, lambda s: 1e-3))
    state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    iters = 3
    for _ in range(iters):
        state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    us = (time.perf_counter() - t0) / iters * 1e6
    toks = B * S
    return [("train_step_ff_tiny", us, f"{toks/(us/1e6)/1e3:.1f}ktok/s_cpu")]


def bench_decode_step():
    plan = single_device_plan()
    cfg = get("ff-tiny")
    params = init_state(cfg, plan, jax.random.PRNGKey(0))["params"]
    B, S, CL = 8, 64, 128
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    _, caches = jax.jit(make_prefill_step(cfg, plan, CL))(
        params, {"tokens": toks})
    decode = jax.jit(make_decode_step(cfg, plan, CL))
    tok = jnp.zeros((B, 1), jnp.int32)
    nt, lg, caches = decode(params, caches, {"token": tok,
                                             "pos": jnp.asarray(S)})
    jax.block_until_ready(nt)
    t0 = time.perf_counter()
    iters = 10
    for i in range(iters):
        nt, lg, caches = decode(params, caches,
                                {"token": nt, "pos": jnp.asarray(S + i)})
    jax.block_until_ready(nt)
    us = (time.perf_counter() - t0) / iters * 1e6
    return [("decode_step_ff_tiny_b8", us,
             f"{B/(us/1e6):.0f}tok/s_cpu")]
