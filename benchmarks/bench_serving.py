"""Open-loop traffic replay against the serving engine (paper Sec. 9 +
the SLO serving tier).

Closed-loop clients (wait for a response before sending the next request)
hide overload: the offered rate collapses to whatever the server sustains.
This bench replays an OPEN-loop Poisson arrival process — requests are
submitted on schedule regardless of completions — at a rate expressed as a
multiple of the engine's measured capacity, and reports what an SLO serving
tier must bound:

  latency_ms            p50 submit->finish latency of ADMITTED requests
  latency_p99_ms        p99 of the same (the SLO-relevant tail)
  goodput_items_per_s   finished (non-shed) requests per second
  shed                  requests refused with a typed ``Overloaded``

Under ``--overload 2`` (offered load = 2x capacity) a correct engine sheds
or degrades instead of queueing unboundedly: the admitted tail stays
bounded because the waiting backlog is capped, and host memory stays flat.
The smoke subset feeds CI's bench-compare gate (``latency_ms`` bounded,
``goodput_items_per_s`` no-regress) via the ``BENCH_graph.json`` artifact.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np


def _build_engine(max_batch=2, cache_len=64, **kw):
    from repro.configs import get
    from repro.core.plan import single_device_plan
    from repro.runtime.steps import init_state
    from repro.serving import InferenceEngine

    cfg = get("ff-tiny").reduced()
    plan = single_device_plan()
    params = init_state(cfg, plan, jax.random.PRNGKey(0))["params"]
    eng = InferenceEngine(cfg, plan, params, max_batch=max_batch,
                          cache_len=cache_len, **kw)
    return cfg, eng


def _measure_capacity(cfg, eng, n=8, max_new=4, prompt_len=4):
    """Closed-loop warm-up: jit compile + a throughput estimate (req/s)
    the open-loop phase scales its offered rate from."""
    from repro.serving import Request
    rng = np.random.default_rng(0)
    hs = [eng.submit(Request(
        prompt=rng.integers(0, cfg.vocab, prompt_len, dtype=np.int32),
        max_new_tokens=max_new)) for _ in range(2)]
    for h in hs:
        h.result(timeout=300)           # compile happens here
    t0 = time.perf_counter()
    hs = [eng.submit(Request(
        prompt=rng.integers(0, cfg.vocab, prompt_len, dtype=np.int32),
        max_new_tokens=max_new)) for _ in range(n)]
    for h in hs:
        h.result(timeout=300)
    return n / (time.perf_counter() - t0)


def bench_serving(smoke: bool = True):
    from repro.core.runtime import SLOPolicy
    from repro.serving import Overloaded, Request

    n_requests = 24 if smoke else 96
    max_new = 4 if smoke else 8
    prompt_len = 4 if smoke else 16
    overload = 2.0
    cfg, eng = _build_engine(
        max_pending=8, slo=SLOPolicy(degrade_at=0.5, shed_at=0.9))
    rng = np.random.default_rng(1)
    with eng:
        cap = _measure_capacity(cfg, eng, max_new=max_new,
                                prompt_len=prompt_len)
        # open loop: Poisson arrivals at overload x measured capacity —
        # submissions happen on schedule whether or not the engine keeps up
        rate = cap * overload
        gaps = rng.exponential(1.0 / rate, n_requests)
        handles = []
        t0 = time.perf_counter()
        for i in range(n_requests):
            time.sleep(gaps[i])
            handles.append(eng.submit(Request(
                prompt=rng.integers(0, cfg.vocab, prompt_len,
                                    dtype=np.int32),
                max_new_tokens=max_new)))
        outs = [h.result(timeout=300) for h in handles]
        replay_s = time.perf_counter() - t0
    done = [o for o in outs if not isinstance(o, Overloaded)]
    shed = len(outs) - len(done)
    lats = sorted((o.finish_t - o.submit_t) * 1e3 for o in done)
    p50 = lats[len(lats) // 2] if lats else 0.0
    p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))] if lats else 0.0
    goodput = len(done) / replay_s
    return [(
        "serving_open_loop", p50 * 1e3,
        f"{overload:.0f}x overload Poisson replay: {len(done)}/{n_requests} "
        f"admitted, {shed} shed, p50={p50:.0f}ms p99={p99:.0f}ms, "
        f"goodput={goodput:.1f} req/s (capacity~{cap:.1f} req/s)",
        {"latency_ms": round(p50, 2), "latency_p99_ms": round(p99, 2),
         "goodput_items_per_s": round(goodput, 3), "shed": shed},
    )]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--overload", type=float, default=2.0)
    ap.add_argument("--out", default=None,
                    help="optional standalone JSON artifact")
    args = ap.parse_args()
    results = {}
    print("name,us_per_call,derived")
    for name, us, derived, fields in bench_serving(args.smoke):
        rec = {"us_per_call": round(us, 2), "derived": derived}
        rec.update(fields)
        results[name] = rec
        print(f"{name},{us:.1f},{derived}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"bench": "serving", "smoke": args.smoke,
                       "results": results}, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
