# One function per paper table/claim. Prints ``name,us_per_call,derived`` CSV.
#
#   Sec. 2  (L1 lock-free channels)   -> bench_spsc_queue
#   Sec. 13 (farm speedup ~ T_seq/nw) -> bench_farm_speedup
#   Sec. 13 (pipeline T_S = max T_Si) -> bench_pipeline_service_time
#   Sec. 9  (accelerator offload)     -> bench_accelerator_offload
#   kernels / end-to-end steps        -> bench_kernels, bench_train
#   (device-level rooflines live in benchmarks/roofline.py, fed by the
#    dry-run — this container has no TPU to time.)

import pathlib
import sys
import warnings

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))

warnings.filterwarnings("ignore")


def main() -> None:
    from benchmarks.bench_core import (bench_accelerator_offload,
                                       bench_farm_speedup,
                                       bench_pipeline_service_time,
                                       bench_spsc_queue)
    from benchmarks.bench_kernels import (bench_attention, bench_gla,
                                          bench_router)
    from benchmarks.bench_train import bench_decode_step, bench_train_step

    benches = [bench_spsc_queue, bench_farm_speedup,
               bench_pipeline_service_time, bench_accelerator_offload,
               bench_attention, bench_gla, bench_router,
               bench_train_step, bench_decode_step]
    print("name,us_per_call,derived")
    for b in benches:
        try:
            for name, us, derived in b():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            print(f"{b.__name__},ERROR,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
