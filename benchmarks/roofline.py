"""Roofline report (deliverable g): read results/dryrun/*.json -> the
per-(arch x shape) table of compute/memory/collective terms, dominant
bottleneck, MODEL_FLOPS ratio, and one-line recommendations.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh sp|mp] [--tag t]

``--autotune`` instead sweeps kernel tile sizes (``a2a_fused`` ``block_t``
per (T, E, D) shape) *and* the overlapped device boundary's in-flight
window depth (``device_overlap:window``) on this host, prints the winners,
and persists them into the perf_model cache (``REPRO_FF_CACHE``, same
read-only-dir degradation as ``calibrate()``) so ``_pick_block``, ``place``
and ``emit``'s default ``inflight`` pick them up in later runs.  ``--quick``
sweeps one small shape for CI cache pre-warming; ``--no-write`` keeps the
sweep in-memory.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _advice(rec):
    r = rec.get("roofline", {})
    dom = r.get("dominant", "?")
    mode = rec.get("mode")
    if dom == "compute":
        ratio = r.get("useful_flops_ratio", 0)
        if ratio < 0.5:
            return "cut non-model FLOPs (remat recompute / masked attn work)"
        return "near compute roof: fuse + MXU-align remaining ops"
    if dom == "memory":
        if mode == "decode":
            return "KV/state reads dominate: quantize cache or widen batch"
        return "fuse elementwise chains; raise arithmetic intensity per pass"
    if dom == "collective":
        return "reshard: cut all-gathers (FSDP prefetch overlap, SP), " \
               "compress pod traffic"
    return ""


def load(mesh="sp", tag=""):
    rows = []
    suffix = f"__{mesh}{('__' + tag) if tag else ''}.json"
    for p in sorted(RESULTS.glob(f"*{suffix}")):
        rec = json.loads(p.read_text())
        rows.append(rec)
    return rows


def table(rows, fmt="md"):
    out = []
    hdr = ["arch", "shape", "ok", "peak GiB", "compute s", "memory s",
           "collective s", "dominant", "MODEL/HLO flops", "roofline frac",
           "next lever"]
    if fmt == "md":
        out.append("| " + " | ".join(hdr) + " |")
        out.append("|" + "---|" * len(hdr))
    rows = sorted(rows, key=lambda r: (r.get("arch", ""),
                                       SHAPE_ORDER.index(r["shape"])
                                       if r.get("shape") in SHAPE_ORDER else 9))
    for rec in rows:
        if rec.get("skipped"):
            line = [rec["arch"], rec["shape"], "SKIP", "-", "-", "-", "-",
                    "-", "-", "-", rec.get("reason", "")[:48]]
        elif not rec.get("ok", False) and "roofline" not in rec:
            line = [rec["arch"], rec["shape"], "FAIL", "-", "-", "-", "-",
                    "-", "-", "-", rec.get("error", "")[:48]]
        else:
            r = rec["roofline"]
            line = [rec["arch"], rec["shape"],
                    "ok" if rec.get("fits_hbm", True) else "ok(>16GiB!)",
                    f"{rec['mem']['peak_gib']:.2f}",
                    f"{r['compute_s']:.4f}", f"{r['memory_s']:.4f}",
                    f"{r['collective_s']:.4f}", r["dominant"],
                    f"{r['useful_flops_ratio']:.3f}",
                    f"{r['roofline_fraction']:.3f}", _advice(rec)]
        if fmt == "md":
            out.append("| " + " | ".join(str(x) for x in line) + " |")
        else:
            out.append(",".join(str(x) for x in line))
    return "\n".join(out)


# ---------------------------------------------------------------------------
# Tile autotuning (--autotune): sweep block_t per shape, persist winners
# ---------------------------------------------------------------------------
AUTOTUNE_SHAPES = [          # (T, E, Din) — batch length, experts, item width
    (128, 4, 64),
    (256, 4, 64),
    (256, 8, 128),
    (512, 4, 256),
]
QUICK_SHAPES = [(128, 4, 64)]
BLOCK_CANDIDATES = [32, 64, 128, 256]


def _time_call(fn, repeats=3):
    import time
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


WINDOW_DEPTHS = [2, 4, 8]    # depth only matters once the boundary overlaps


def _sweep_window_depth(quick=False):
    """Sweep the overlapped boundary's in-flight window depth on the
    software-pipelined device path (``DeviceRunner._run_pipelined``) and
    return the ``device_overlap:window`` autotune entry — ``emit`` reads it
    as the default ``inflight`` when ``CompileConfig`` leaves it unset."""
    import numpy as np

    from repro.core import pipeline
    from repro.core.compiler import CompileConfig
    from repro.core.plan import single_device_plan

    plan = single_device_plan()
    n_items = 24 if quick else 48
    base = np.linspace(-1.0, 1.0, 128, dtype=np.float32)
    stream = [base * (1.0 + 0.01 * i) for i in range(n_items)]
    sweep = {}
    for k in WINDOW_DEPTHS:
        r = pipeline(lambda x: x * 1.5 + 0.25,
                     lambda x: x - 0.125).compile(config=CompileConfig(
                         plan=plan, mode="device", microbatch=2, inflight=k))
        r.run(stream)                                    # compile / warm up
        sweep[k] = _time_call(lambda: r.run(stream))
    win = min(sweep, key=sweep.get)
    return {"device_overlap:window": {
        "inflight": int(win), "time_s": float(sweep[win]),
        "sweep": {str(k): float(v) for k, v in sweep.items()},
    }}


def autotune(quick=False, write=True):
    """Sweep ``a2a_fused`` ``block_t`` per shape on this host; returns the
    winners dict and (optionally) persists it via ``perf_model``."""
    import jax
    import jax.numpy as jnp

    from repro.core import perf_model as pm
    from repro.kernels.a2a_fused import a2a_fused

    shapes = QUICK_SHAPES if quick else AUTOTUNE_SHAPES
    entries = {}
    for (T, E, D) in shapes:
        key = jax.random.PRNGKey(T * 7919 + E * 131 + D)
        k1, k2 = jax.random.split(key)
        logits = jax.random.normal(k1, (T, E), jnp.float32)
        xs = jax.random.normal(k2, (T, D), jnp.float32)
        fns = tuple((lambda x, s=float(j + 1): x * s + s) for j in range(E))
        cap = T // E  # bounded lanes: the interesting (drop-policy) regime
        sweep = {}
        for bt in [c for c in BLOCK_CANDIDATES if c <= T and T % c == 0]:
            def run(bt=bt):
                out, keep = a2a_fused(logits, xs, fns, cap, block_t=bt)
                jax.block_until_ready((out, keep))
            try:
                run()                                    # compile / warm up
                sweep[bt] = _time_call(run)
            except Exception as exc:  # noqa: BLE001 - skip broken candidate
                print(f"  [skip] block_t={bt} T={T}: {exc}", file=sys.stderr)
        if not sweep:
            continue
        win = min(sweep, key=sweep.get)
        entries[f"a2a_fused:T{T}:E{E}:D{D}"] = {
            "block_t": int(win), "time_s": float(sweep[win]),
            "sweep": {str(k): float(v) for k, v in sweep.items()},
        }
    entries.update(_sweep_window_depth(quick))
    n = pm.record_autotuned(entries, write=write)
    hdr = ["key", "winner", "best s", "sweep"]
    print("| " + " | ".join(hdr) + " |")
    print("|" + "---|" * len(hdr))
    for k, rec in entries.items():
        sweep = " ".join(f"{b}:{t:.2e}" for b, t in rec["sweep"].items())
        win = rec.get("block_t", rec.get("inflight"))
        print(f"| {k} | {win} | {rec['time_s']:.2e} | {sweep} |")
    print(f"# recorded {n} autotune entr{'y' if n == 1 else 'ies'} "
          f"({'persisted' if write else 'in-memory only'})")
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="sp", choices=["sp", "mp"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep kernel tiles and persist winners")
    ap.add_argument("--quick", action="store_true",
                    help="with --autotune: one small shape (CI pre-warm)")
    ap.add_argument("--no-write", action="store_true",
                    help="with --autotune: do not persist results")
    args = ap.parse_args()
    if args.autotune:
        autotune(quick=args.quick, write=not args.no_write)
        return
    rows = load(args.mesh, args.tag)
    if not rows:
        print("no dry-run results found; run: python -m repro.launch.dryrun --all",
              file=sys.stderr)
        return
    print(table(rows, "csv" if args.csv else "md"))


if __name__ == "__main__":
    main()
