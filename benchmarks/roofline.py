"""Roofline report (deliverable g): read results/dryrun/*.json -> the
per-(arch x shape) table of compute/memory/collective terms, dominant
bottleneck, MODEL_FLOPS ratio, and one-line recommendations.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh sp|mp] [--tag t]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _advice(rec):
    r = rec.get("roofline", {})
    dom = r.get("dominant", "?")
    mode = rec.get("mode")
    if dom == "compute":
        ratio = r.get("useful_flops_ratio", 0)
        if ratio < 0.5:
            return "cut non-model FLOPs (remat recompute / masked attn work)"
        return "near compute roof: fuse + MXU-align remaining ops"
    if dom == "memory":
        if mode == "decode":
            return "KV/state reads dominate: quantize cache or widen batch"
        return "fuse elementwise chains; raise arithmetic intensity per pass"
    if dom == "collective":
        return "reshard: cut all-gathers (FSDP prefetch overlap, SP), " \
               "compress pod traffic"
    return ""


def load(mesh="sp", tag=""):
    rows = []
    suffix = f"__{mesh}{('__' + tag) if tag else ''}.json"
    for p in sorted(RESULTS.glob(f"*{suffix}")):
        rec = json.loads(p.read_text())
        rows.append(rec)
    return rows


def table(rows, fmt="md"):
    out = []
    hdr = ["arch", "shape", "ok", "peak GiB", "compute s", "memory s",
           "collective s", "dominant", "MODEL/HLO flops", "roofline frac",
           "next lever"]
    if fmt == "md":
        out.append("| " + " | ".join(hdr) + " |")
        out.append("|" + "---|" * len(hdr))
    rows = sorted(rows, key=lambda r: (r.get("arch", ""),
                                       SHAPE_ORDER.index(r["shape"])
                                       if r.get("shape") in SHAPE_ORDER else 9))
    for rec in rows:
        if rec.get("skipped"):
            line = [rec["arch"], rec["shape"], "SKIP", "-", "-", "-", "-",
                    "-", "-", "-", rec.get("reason", "")[:48]]
        elif not rec.get("ok", False) and "roofline" not in rec:
            line = [rec["arch"], rec["shape"], "FAIL", "-", "-", "-", "-",
                    "-", "-", "-", rec.get("error", "")[:48]]
        else:
            r = rec["roofline"]
            line = [rec["arch"], rec["shape"],
                    "ok" if rec.get("fits_hbm", True) else "ok(>16GiB!)",
                    f"{rec['mem']['peak_gib']:.2f}",
                    f"{r['compute_s']:.4f}", f"{r['memory_s']:.4f}",
                    f"{r['collective_s']:.4f}", r["dominant"],
                    f"{r['useful_flops_ratio']:.3f}",
                    f"{r['roofline_fraction']:.3f}", _advice(rec)]
        if fmt == "md":
            out.append("| " + " | ".join(str(x) for x in line) + " |")
        else:
            out.append(",".join(str(x) for x in line))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="sp", choices=["sp", "mp"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = load(args.mesh, args.tag)
    if not rows:
        print("no dry-run results found; run: python -m repro.launch.dryrun --all",
              file=sys.stderr)
        return
    print(table(rows, "csv" if args.csv else "md"))


if __name__ == "__main__":
    main()
