"""End-to-end training driver (deliverable b): train a ~100M-parameter LM
for a few hundred steps through the full production stack — data pipeline,
mixed-precision train step, checkpointing, fault-tolerant driver, straggler
watchdog — with an injected mid-run failure to demonstrate checkpoint/
restart recovery.

Default config is CPU-sized so the example finishes in minutes; pass
--full-100m for the real ~100M model (same code path, longer wall time).

    PYTHONPATH=src python examples/train_e2e.py --steps 200
"""

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import Config, get
from repro.core.plan import single_device_plan
from repro.data import SyntheticLMSource, make_pipeline
from repro.optim.schedules import cosine_warmup
from repro.runtime.driver import DriverConfig, TrainDriver
from repro.runtime.steps import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true",
                    help="~100M params (slow on CPU)")
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="raise at this step once to demo restart")
    args = ap.parse_args()

    if args.full_100m:
        cfg = Config(name="ff-100m", family="dense", n_layers=12,
                     d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
                     d_ff=3072, vocab=32768, act="gelu",
                     attn_parallel="heads", n_kv_eff=12,
                     q_block=128, kv_block=128)
    else:
        cfg = Config(name="ff-20m", family="dense", n_layers=4,
                     d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
                     d_ff=1536, vocab=8192, act="gelu",
                     attn_parallel="heads", n_kv_eff=6,
                     q_block=128, kv_block=128)

    plan = single_device_plan()
    state = init_state(cfg, plan, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    src = SyntheticLMSource(cfg.vocab, args.seq, args.batch, seed=0)
    pipe = make_pipeline(src, plan, n_batches=args.steps + 16)
    step = jax.jit(make_train_step(cfg, plan,
                                   cosine_warmup(3e-3, 20, args.steps)),
                   donate_argnums=0)

    fail_at = args.inject_failure
    fired = [False]

    def fault_hook(s):
        if fail_at is not None and s == fail_at and not fired[0]:
            fired[0] = True
            raise RuntimeError("injected node failure (preemption)")

    driver = TrainDriver(
        step, state, pipe,
        DriverConfig(total_steps=args.steps, ckpt_every=25,
                     ckpt_dir="/tmp/repro_e2e_ckpt", log_every=20),
        fault_hook=fault_hook)
    t0 = time.time()
    out = driver.run()
    wall = time.time() - t0
    losses = [h["loss"] for h in out["history"]]
    toks = args.batch * args.seq * out["final_step"]
    print(f"done in {wall:.1f}s: loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"{toks/wall/1e3:.1f}k tok/s, restarts={out['restarts']}, "
          f"stragglers={out['stragglers']}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
