"""FastFlow *software accelerator* mode (paper Sec. 9) with the device mesh
as the accelerator, two ways:

1. raw JaxAccelerator: offload f(x) tasks (here: batched matmuls) and
   retrieve results asynchronously — the paper's offload/load_result
   pattern verbatim, with JAX async dispatch as the lock-free queue;
2. InferenceEngine: continuous-batching LM serving behind the same
   offload/load_result API (requests in, generated sequences out).

    PYTHONPATH=src python examples/accelerator_offload.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import FF_EOS, JaxAccelerator
from repro.core.plan import single_device_plan
from repro.runtime.steps import init_state
from repro.serving import InferenceEngine, Request


def demo_raw_accelerator():
    print("== raw accelerator: offloaded matmul stream ==")
    f = jax.jit(lambda x: (x @ x.T).sum(axis=1))
    acc = JaxAccelerator(f, max_inflight=8)
    acc.run_then_freeze()
    xs = [np.random.default_rng(i).normal(size=(256, 256)).astype(np.float32)
          for i in range(20)]
    t0 = time.perf_counter()
    for x in xs:
        acc.offload(x)          # returns immediately: async dispatch
    acc.offload(FF_EOS)
    n = 0
    while True:
        ok, r = acc.load_result()
        if not ok:
            break
        n += 1
    acc.wait()
    print(f"offloaded+retrieved {n} tasks in "
          f"{(time.perf_counter()-t0)*1e3:.1f} ms")
    assert n == len(xs)


def demo_serving():
    print("== inference engine: continuous batching ==")
    cfg = get("ff-tiny").reduced()
    plan = single_device_plan()
    params = init_state(cfg, plan, jax.random.PRNGKey(0))["params"]
    eng = InferenceEngine(cfg, plan, params, max_batch=2, cache_len=64)
    eng.run_then_freeze()
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.offload(Request(prompt=rng.integers(0, cfg.vocab, 8,
                                                dtype=np.int32),
                            max_new_tokens=8, id=i))
    eng.offload(FF_EOS)
    done = 0
    while True:
        ok, req = eng.load_result()
        if not ok:
            break
        done += 1
        print(f"request {req.id}: {len(req.tokens)} tokens "
              f"({(req.finish_t-req.submit_t)*1e3:.0f} ms) {req.tokens[:8]}")
    eng.wait()
    assert done == 5
    print(f"engine decode steps: {eng.steps} (continuous batching: "
          f"fewer than sequential 5x8={5*8})")


if __name__ == "__main__":
    demo_raw_accelerator()
    demo_serving()
