"""FastFlow *software accelerator* mode (paper Sec. 9) with the device mesh
as the accelerator, two ways:

1. raw JaxAccelerator: offload f(x) tasks (here: batched matmuls) and
   retrieve results asynchronously — the paper's offload/load_result
   pattern verbatim, with JAX async dispatch as the lock-free queue;
2. InferenceEngine: continuous-batching LM serving behind the typed
   client API — ``submit`` returns a ``RequestHandle``, ``results()``
   iterates outcomes, the engine is a context manager (the paper's
   offload/load_result surface remains available for compat).

    PYTHONPATH=src python examples/accelerator_offload.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import FF_EOS, JaxAccelerator
from repro.core.plan import single_device_plan
from repro.runtime.steps import init_state
from repro.serving import InferenceEngine, Request


def demo_raw_accelerator():
    print("== raw accelerator: offloaded matmul stream ==")
    f = jax.jit(lambda x: (x @ x.T).sum(axis=1))
    acc = JaxAccelerator(f, max_inflight=8)
    acc.run_then_freeze()
    xs = [np.random.default_rng(i).normal(size=(256, 256)).astype(np.float32)
          for i in range(20)]
    t0 = time.perf_counter()
    for x in xs:
        acc.offload(x)          # returns immediately: async dispatch
    acc.offload(FF_EOS)
    n = 0
    while True:
        ok, r = acc.load_result()
        if not ok:
            break
        n += 1
    acc.wait()
    print(f"offloaded+retrieved {n} tasks in "
          f"{(time.perf_counter()-t0)*1e3:.1f} ms")
    assert n == len(xs)


def demo_serving():
    print("== inference engine: continuous batching ==")
    cfg = get("ff-tiny").reduced()
    plan = single_device_plan()
    params = init_state(cfg, plan, jax.random.PRNGKey(0))["params"]
    rng = np.random.default_rng(0)
    with InferenceEngine(cfg, plan, params, max_batch=2,
                         cache_len=64) as eng:
        for _ in range(5):
            eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 8,
                                                   dtype=np.int32),
                               max_new_tokens=8))
    # leaving the with-block drained the engine; outcomes replay in
    # completion order
    done = 0
    for req in eng.results():
        done += 1
        print(f"request {req.id}: {len(req.tokens)} tokens "
              f"[{req.finish_reason}] "
              f"({(req.finish_t-req.submit_t)*1e3:.0f} ms) {req.tokens[:8]}")
    assert done == 5
    print(f"engine decode steps: {eng.steps} (continuous batching: "
          f"fewer than sequential 5x8={5*8})")


if __name__ == "__main__":
    demo_raw_accelerator()
    demo_serving()
