"""Quickstart: train a tiny LM with the full stack on CPU.

The paper's "skeleton program" abstraction end-to-end: data pipeline
(pipeline skeleton) -> train step (farm over the mesh) -> fault-tolerant
driver (supervising farm with feedback).

    PYTHONPATH=src python examples/quickstart.py [--steps 30]
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get
from repro.core.plan import single_device_plan
from repro.data import SyntheticLMSource, make_pipeline
from repro.optim.schedules import cosine_warmup
from repro.runtime.driver import DriverConfig, TrainDriver
from repro.runtime.steps import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--arch", default="ff-tiny")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get(args.arch).reduced() if args.arch != "ff-tiny" else get(args.arch)
    plan = single_device_plan()
    state = init_state(cfg, plan, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M")

    src = SyntheticLMSource(cfg.vocab, args.seq, args.batch, seed=0)
    pipe = make_pipeline(src, plan, n_batches=args.steps + 5)
    step = jax.jit(make_train_step(cfg, plan,
                                   cosine_warmup(3e-3, 10, args.steps)),
                   donate_argnums=0)

    driver = TrainDriver(step, state, pipe,
                         DriverConfig(total_steps=args.steps, ckpt_every=10,
                                      ckpt_dir="/tmp/repro_quickstart_ckpt",
                                      log_every=5))
    out = driver.run()
    losses = [h["loss"] for h in out["history"]]
    print(f"done: steps={out['final_step']} loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f} (restarts={out['restarts']})")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
