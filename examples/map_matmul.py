"""The paper's map-on-a-farm-template (FastFlow tutorial Sec. 12.1):
matrix multiply as Split -> workers -> Compose, at BOTH levels this
framework provides:

1. host level: the literal ff_map structure (Split emitter partitions
   C = A x B into row tasks, workers compute rows, Compose rebuilds C),
   built with the graph API's ``ffmap`` block and host-lowered;
2. device level: the same skeleton lowered to shard_map over the mesh
   (core.device.tensor_map) — Split = PartitionSpec, Compose = psum —
   plus the SAME ``farm`` graph lowered host-side and device-side through
   the one ``lower(plan)`` entry point, producing identical rows.

    PYTHONPATH=src python examples/map_matmul.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FFNode, GO_ON, all_to_all, farm, ffmap
from repro.core.device import tensor_map
from repro.core.plan import single_device_plan
from jax.sharding import PartitionSpec as P


# --- host-level ff_map (paper code structure) ---------------------------------
class Split(FFNode):
    """Emitter: one task per output row (the paper's finer-grain c_ij
    variant works too; rows keep the demo fast)."""
    def svc(self, task):
        A, B, C = task
        for i in range(A.shape[0]):
            self.ff_send_out(("row", i, A[i], B, C))
        return None


class Worker(FFNode):
    def svc(self, t):
        _, i, a_row, B, C = t
        return ("res", i, a_row @ B, C)


class Compose(FFNode):
    def __init__(self, n_rows):
        super().__init__()
        self.remaining = n_rows

    def svc(self, t):
        _, i, row, C = t
        C[i] = row
        self.remaining -= 1
        return GO_ON


def host_map_matmul(A, B, nworkers=4):
    C = np.zeros((A.shape[0], B.shape[1]), A.dtype)
    m = ffmap(Split(), [Worker() for _ in range(nworkers)],
              Compose(A.shape[0])).lower()
    m.run_then_freeze()
    m.offload((A, B, C))
    from repro.core import FF_EOS
    m.offload(FF_EOS)
    m.wait()
    return C


def main():
    rng = np.random.default_rng(0)
    A = rng.normal(size=(64, 32)).astype(np.float32)
    B = rng.normal(size=(32, 48)).astype(np.float32)

    C_host = host_map_matmul(A, B)
    np.testing.assert_allclose(C_host, A @ B, rtol=1e-5)
    print("host-level ff_map matmul: OK")

    # --- device-level map skeleton ------------------------------------------
    plan = single_device_plan()
    f = tensor_map(lambda a, b: a @ b, plan.mesh, axis="model",
                   split_spec=(P(None, "model"), P("model", None)),
                   compose="reduce")
    C_dev = f(jnp.asarray(A), jnp.asarray(B))
    np.testing.assert_allclose(np.asarray(C_dev), A @ B, rtol=1e-4,
                               atol=1e-5)
    print("device-level tensor_map matmul: OK (Split=PartitionSpec, "
          "Compose=psum)")

    # --- one graph, two lowerings -------------------------------------------
    Bj = jnp.asarray(B)
    g = farm(lambda row: row @ Bj, n=2)
    rows_host = g.lower().run(list(jnp.asarray(A)))
    rows_dev = g.lower(plan).run(list(A))
    np.testing.assert_allclose(np.sort(np.asarray(rows_host), axis=0),
                               np.sort(np.asarray(rows_dev), axis=0),
                               rtol=1e-5)
    print("graph farm lower() parity: host threads == mesh shard_map")

    # --- ff_a2a through the staged compiler ---------------------------------
    # rows are routed to one of two "experts" (scale vs negate) by the sign
    # of the first transformed element; the device lowering is MoE-style
    # dispatch/combine (router_topk lane occupancy + capacity-bounded gather)
    lefts = [lambda row: row @ Bj]
    rights = [lambda y: y * 2.0, lambda y: -y]
    router = lambda y, n: jnp.asarray(y[0] > 0, jnp.int32) % n

    def build():
        return all_to_all(lefts, rights, router=router)
    out_host = build().compile(mode="host").run(list(jnp.asarray(A)))
    out_dev = build().compile(plan, mode="device").run(list(A))
    np.testing.assert_allclose(np.sort(np.asarray(out_host), axis=0),
                               np.sort(np.asarray(out_dev), axis=0),
                               rtol=1e-5)
    print("graph a2a compile() parity: host MPMC grid == MoE dispatch/combine")


if __name__ == "__main__":
    main()
