"""The paper's Sieve of Eratosthenes (FastFlow tutorial Secs. 6-7),
written against the building-blocks graph API — same structure, same
semantics: a Generate source, N Sieve stages, a Printer sink, composed with
``pipeline(...)`` and executed through the staged graph compiler
(``compile()`` = normalize -> annotate -> place -> emit; every stage here is
stateful, so place() pins the whole network to host threads);
svc_init/svc_end lifecycle hooks included.

    PYTHONPATH=src python examples/sieve_pipeline.py 7 50
"""

import sys

sys.path.insert(0, "src")

from repro.core import FFNode, GO_ON, pipeline


class Generate(FFNode):
    def __init__(self, n):
        super().__init__()
        self.task, self.streamlen = 1, n

    def svc_init(self):
        print(f"Sieve started. Generating a stream of {self.streamlen} "
              f"elements, starting with 2")
        return 0

    def svc(self, _):
        self.task += 1
        return self.task if self.task <= self.streamlen else None


class Sieve(FFNode):
    def __init__(self):
        super().__init__()
        self.filter = 0

    def svc(self, t):
        if self.filter == 0:
            self.filter = t
            return GO_ON
        return GO_ON if t % self.filter == 0 else t

    def svc_end(self):
        print(f"Prime({self.filter})")


class Printer(FFNode):
    def __init__(self):
        super().__init__()
        self.first = 0

    def svc_init(self):
        print("Printer started")
        return 0

    def svc(self, t):
        if self.first == 0:
            self.first = t
        return GO_ON

    def svc_end(self):
        print(f"Sieve terminating, prime numbers found up to {self.first}")


def main():
    nstages = int(sys.argv[1]) if len(sys.argv) > 1 else 7
    streamlen = int(sys.argv[2]) if len(sys.argv) > 2 else 50
    graph = pipeline(Generate(streamlen),
                     *[Sieve() for _ in range(nstages)], Printer())
    runner = graph.compile()          # normalize -> annotate -> place -> emit
    for desc, p in runner.placements:
        print(f"  [{p.target:6s}] {desc}")
    if runner.run_and_wait_end() < 0:
        raise SystemExit("running pipeline failed")
    print(f"DONE, pipe time = {runner.ffTime():.3f} (ms)")


if __name__ == "__main__":
    main()
