"""Fault tolerance: checkpoint/restart on failure, straggler watchdog,
training continues to completion with correct data-stream resume."""

import time

import jax
import numpy as np
import pytest

from repro.configs import get
from repro.data import SyntheticLMSource, make_pipeline
from repro.optim.schedules import linear_warmup
from repro.runtime.driver import DriverConfig, TrainDriver
from repro.runtime.monitor import StragglerWatchdog
from repro.runtime.steps import init_state, make_train_step


def _driver(plan, rng, tmp_path, total=12, fail_at=None, fail_times=1):
    cfg = get("ff-tiny").reduced()
    state = init_state(cfg, plan, rng)
    src = SyntheticLMSource(cfg.vocab, 16, 2, seed=3)
    pipe = make_pipeline(src, plan, n_batches=total * 3)
    step = jax.jit(make_train_step(cfg, plan, linear_warmup(1e-3, 5)))
    fired = [0]

    def hook(s):
        if fail_at is not None and s == fail_at and fired[0] < fail_times:
            fired[0] += 1
            raise RuntimeError("injected preemption")

    return TrainDriver(step, state, pipe,
                       DriverConfig(total_steps=total, ckpt_every=4,
                                    ckpt_dir=str(tmp_path), max_retries=3,
                                    retry_backoff_s=0.01, log_every=1000),
                       fault_hook=hook)


def test_training_completes_without_failures(plan, rng, tmp_path):
    d = _driver(plan, rng, tmp_path, total=8)
    out = d.run()
    assert out["final_step"] == 8
    assert out["restarts"] == 0
    assert d.ckpt.latest() == 8


def test_restart_after_injected_failure(plan, rng, tmp_path):
    d = _driver(plan, rng, tmp_path, total=12, fail_at=6)
    out = d.run()
    assert out["final_step"] == 12
    assert out["restarts"] == 1                      # restored from step 4
    kinds = [e["kind"] for e in d.monitor.events]
    assert "step_failure" in kinds and "restart" in kinds
    # loss history covers re-executed steps (5,6 re-run after restore)
    steps = [h["step"] for h in out["history"]]
    assert steps.count(5) >= 1


def test_repeated_failure_exhausts_retries(plan, rng, tmp_path):
    d = _driver(plan, rng, tmp_path, total=12, fail_at=2, fail_times=99)
    with pytest.raises(RuntimeError, match="injected"):
        d.run()


def test_failure_before_first_checkpoint_retries_in_place(plan, rng,
                                                          tmp_path):
    d = _driver(plan, rng, tmp_path, total=6, fail_at=1, fail_times=2)
    out = d.run()
    assert out["final_step"] == 6


def test_straggler_watchdog_flags_outliers():
    wd = StragglerWatchdog(k=3.0, warmup=3)
    flagged = []
    for i in range(50):
        dt = 0.01 if i != 30 else 0.2
        flagged.append(wd.observe(dt))
    assert flagged[30] is True
    assert sum(flagged) == 1
    assert wd.count == 1
    # the outlier did not poison the EMA
    assert wd.mean < 0.02
