"""Data pipeline determinism/resume + checkpoint atomicity/async/reshard."""

import pathlib
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              load_checkpoint, save_checkpoint)
from repro.data import DataPipeline, MemmapTokenSource, SyntheticLMSource
from repro.data.sources import write_token_file


def test_synthetic_source_deterministic_and_resumable():
    s1 = SyntheticLMSource(100, 16, 4, seed=7)
    batches = [s1.next_batch()["tokens"] for _ in range(5)]
    st = s1.state()
    more = [s1.next_batch()["tokens"] for _ in range(3)]
    s2 = SyntheticLMSource(100, 16, 4, seed=7)
    s2.restore(st)
    resumed = [s2.next_batch()["tokens"] for _ in range(3)]
    for a, b in zip(more, resumed):
        assert np.array_equal(a, b)
    # and a fresh source replays identically from the start
    s3 = SyntheticLMSource(100, 16, 4, seed=7)
    assert np.array_equal(s3.next_batch()["tokens"], batches[0])


def test_memmap_source_sharded(tmp_path):
    toks = np.arange(16 * 64, dtype=np.int32)
    f = tmp_path / "tokens.bin"
    write_token_file(f, toks)
    a = MemmapTokenSource(f, seq_len=16, batch_size=2, shard_id=0,
                          num_shards=2)
    b = MemmapTokenSource(f, seq_len=16, batch_size=2, shard_id=1,
                          num_shards=2)
    ba, bb = a.next_batch()["tokens"], b.next_batch()["tokens"]
    # disjoint windows across shards
    assert set(ba[:, 0].tolist()).isdisjoint(bb[:, 0].tolist())
    # resumable
    st = a.state()
    nxt = a.next_batch()["tokens"]
    a2 = MemmapTokenSource(f, seq_len=16, batch_size=2)
    a2.restore(st)
    assert np.array_equal(a2.next_batch()["tokens"], nxt)


def test_pipeline_prefetch_and_backpressure():
    src = SyntheticLMSource(50, 8, 2, seed=1)
    pipe = DataPipeline(src, shardings=None, n_batches=6, prefetch=2).start()
    got = []
    while True:
        b = pipe.get(timeout=10)
        if b is None:
            break
        got.append(np.asarray(b["tokens"]))
    assert len(got) == 6
    ref = SyntheticLMSource(50, 8, 2, seed=1)
    for g in got:
        assert np.array_equal(g, ref.next_batch()["tokens"])


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
             "step": jnp.asarray(5)}
    save_checkpoint(tmp_path, 5, state, extras={"data": {"index": 9}})
    assert latest_step(tmp_path) == 5
    # no tmp dirs left behind
    assert not list(tmp_path.glob("*.tmp"))
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, extras = load_checkpoint(tmp_path, like)
    assert extras["data"]["index"] == 9
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert int(restored["step"]) == 5


def test_checkpoint_gc_keeps_latest(tmp_path):
    state = {"w": jnp.zeros((2,))}
    for s in [1, 2, 3, 4, 5]:
        save_checkpoint(tmp_path, s, state, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000004", "step_00000005"]


def test_async_checkpoint_snapshot_isolation(tmp_path):
    """save_async must snapshot values at call time, even if the live state
    is mutated right after (donation semantics)."""
    mgr = CheckpointManager(tmp_path)
    state = {"w": jnp.ones((4,))}
    mgr.save_async(1, state)
    state = {"w": jnp.zeros((4,))}          # mutate after enqueue
    mgr.wait()
    restored, _ = mgr.restore({"w": jnp.zeros((4,))})
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.ones(4))


def test_elastic_reshard_roundtrip(tmp_path, plan, rng):
    """Save on one 'mesh', restore through reshard onto another (both are
    1-device here; the path exercises device_put with plan shardings)."""
    from repro.configs import get
    from repro.checkpoint.reshard import reshard_state
    from repro.runtime.steps import init_state
    cfg = get("ff-tiny").reduced()
    state = init_state(cfg, plan, rng)
    save_checkpoint(tmp_path, 0, state)
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), state)
    host, _ = load_checkpoint(tmp_path, like)
    placed = reshard_state(cfg, host, plan)
    for a, b in zip(jax.tree.leaves(placed), jax.tree.leaves(state)):
        assert a.shape == b.shape and a.dtype == b.dtype
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(placed)[0], np.float32),
        np.asarray(jax.tree.leaves(state)[0], np.float32))
