"""The process-backed ``all_to_all``: ShmMPMCGrid lanes, ProcessA2ANode,
three-way backend parity, ordering, and crash surfacing (PR 4)."""

import os
import signal
import time

import numpy as np
import pytest

from repro.core import (FFNode, ProcessA2ANode, ProcessRunner, WorkerCrashed,
                        all_to_all, pipeline)


class Gen(FFNode):
    def __init__(self, n):
        super().__init__()
        self.i, self.n = 0, n

    def svc(self, _):
        self.i += 1
        return np.float32(self.i) if self.i <= self.n else None


# module-level (picklable under spawn too) heterogeneous workers + router
def _l_scale(x):
    return x * 10.0


def _l_shift(x):
    return x + 1.0


def _r_dec(y):
    return y - 1.0


def _r_double(y):
    return y * 2.0


def _route_by_value(y, n_right):
    # traceable (device lowering) AND picklable (process lowering): cast
    # instead of int(), which would concretize a jax tracer
    return y.astype("int32") % n_right


def _kill_self(x):
    if int(x) == 5:
        os.kill(os.getpid(), signal.SIGKILL)
    return float(x)


def _boom_on_seven(x):
    if int(x) == 7:
        raise ValueError("poisoned item")
    return float(x)


def _ident(x):
    return float(x)


def _to_zero(y, n_right):
    return 0


def _expected_in_order(n, lefts, rights, router):
    """What the a2a produces, in input order (round-robin over left from
    worker 0, matching every backend's feeder)."""
    out = []
    rr = [i % len(rights) for i in range(len(lefts))]
    for seq in range(n):
        i = seq % len(lefts)
        y = lefts[i](np.float32(seq + 1))
        if router is not None:
            j = int(router(y, len(rights))) % len(rights)
        else:
            j, rr[i] = rr[i], (rr[i] + 1) % len(rights)
        out.append(float(rights[j](y)))
    return out


# -- three-way parity ----------------------------------------------------------
@pytest.mark.shm
def test_a2a_parity_heterogeneous_workers_custom_router(plan):
    lefts = [_l_scale, _l_shift]
    rights = [_r_dec, _r_double]
    n = 14
    expected = _expected_in_order(n, lefts, rights, _route_by_value)

    xs = [np.float32(i) for i in range(1, n + 1)]
    host = all_to_all(lefts, rights, router=_route_by_value) \
        .compile(mode="host").run(xs, timeout=60.0)
    r = all_to_all(lefts, rights, router=_route_by_value) \
        .compile(mode="process")
    assert isinstance(r, ProcessRunner)
    proc = r.run(xs, timeout=60.0)
    # process a2a restores input order from wire sequence numbers — exact
    # order, stricter than the thread a2a's arrival order (same multiset)
    assert [float(v) for v in proc] == pytest.approx(expected)
    assert sorted(float(v) for v in host) == pytest.approx(sorted(expected))
    if plan is not None:
        dev = all_to_all(lefts, rights, router=_route_by_value) \
            .compile(plan, mode="device").run(xs)
        assert sorted(float(v) for v in dev) \
            == pytest.approx(sorted(expected))


@pytest.mark.shm
def test_a2a_parity_default_round_robin_router():
    lefts = [_l_scale, _l_shift]
    rights = [_r_dec, _r_double]
    n = 12
    expected = _expected_in_order(n, lefts, rights, None)
    r = pipeline(Gen(n), all_to_all(lefts, rights)).compile(mode="process")
    assert [float(v) for v in r.run(timeout=60.0)] == pytest.approx(expected)


# -- ordering under long streams -----------------------------------------------
@pytest.mark.shm
def test_a2a_process_order_on_stream_longer_than_ring_capacity():
    """The grid rings are clamped to <= 32 slots; a 400-item stream forces
    wraparound and sustained back-pressure, and the output must still be in
    exact input order (seq headers + the parent reorder buffer)."""
    lefts = [_l_scale, _l_shift]
    rights = [_r_dec, _r_double]
    n = 400
    expected = _expected_in_order(n, lefts, rights, _route_by_value)
    r = pipeline(Gen(n), all_to_all(lefts, rights, router=_route_by_value)) \
        .compile(mode="process")
    out = [float(v) for v in r.run(timeout=120.0)]
    assert out == pytest.approx(expected)


# -- crash surfacing -----------------------------------------------------------
@pytest.mark.shm
def test_a2a_crashed_right_worker_surfaces_error_not_wedge():
    r = pipeline(Gen(200), all_to_all([_ident, _ident],
                                      [_kill_self, _ident],
                                      router=_to_zero)) \
        .compile(mode="process")
    t0 = time.monotonic()
    with pytest.raises(WorkerCrashed) as ei:
        r.run(timeout=60.0)
    assert time.monotonic() - t0 < 45.0
    assert "right worker" in str(ei.value) and "died" in str(ei.value)


@pytest.mark.shm
def test_a2a_crashed_left_worker_surfaces_error_not_wedge():
    r = pipeline(Gen(200), all_to_all([_kill_self, _ident],
                                      [_ident, _ident])) \
        .compile(mode="process")
    t0 = time.monotonic()
    with pytest.raises(WorkerCrashed) as ei:
        r.run(timeout=60.0)
    assert time.monotonic() - t0 < 45.0
    assert "left worker" in str(ei.value) and "died" in str(ei.value)


@pytest.mark.shm
def test_a2a_right_exception_ships_back_with_traceback():
    r = pipeline(Gen(300), all_to_all([_ident, _ident],
                                      [_boom_on_seven, _ident],
                                      router=_to_zero)) \
        .compile(mode="process")
    with pytest.raises(WorkerCrashed) as ei:
        r.run(timeout=60.0)
    assert "ValueError" in str(ei.value)


@pytest.mark.shm
def test_a2a_left_exception_relays_through_right_worker():
    r = pipeline(Gen(300), all_to_all([_boom_on_seven, _ident],
                                      [_ident, _ident])) \
        .compile(mode="process")
    with pytest.raises(WorkerCrashed) as ei:
        r.run(timeout=60.0)
    assert "ValueError" in str(ei.value)


# -- node lifecycle / stats ------------------------------------------------------
@pytest.mark.shm
def test_a2a_node_stats_and_segment_release():
    n = 16
    r = pipeline(Gen(n), all_to_all([_l_scale, _l_shift],
                                    [_r_dec, _r_double])) \
        .compile(mode="process")
    r.run(timeout=60.0)
    node = [s for s in r._skel._stages if isinstance(s, ProcessA2ANode)][0]
    st = node.node_stats()
    assert st["backend"] == "process"
    assert st["items"] == n and st["delivered"] == n
    assert sum(st["routed_per_left_worker"]) == n
    # the run completed: workers exited, segments unlinked
    assert node._destroyed
    assert all(not p.is_alive()
               for p in (*node._left_procs, *node._right_procs))


def test_a2a_stateful_workers_stay_ineligible():
    g = pipeline(Gen(4), all_to_all([Gen(1), Gen(1)], [_ident, _ident])) \
        .compile(mode="process")
    # stateful left workers cannot ship to a child: stays on threads with
    # the reason recorded
    p = [p for d, p in g.placements if "a2a" in d][0]
    assert p.target == "host" and "process" in p.reason
