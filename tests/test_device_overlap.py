"""The overlapped device boundary (core/compiler.py `_DeviceStageNode` +
core/graph.py `DeviceRunner._run_pipelined`):

- overlap-on vs overlap-off byte-identical parity across hybrid pipeline /
  farm / all_to_all / wrap_around graphs (only the synchronization point
  moves — the same jitted programs see the same stacked inputs);
- exact input order preserved on a stream much longer than the in-flight
  window (FIFO retirement);
- a crash mid-window surfaces the error without wedging the runner;
- ``microbatch=1, inflight=1`` degenerates to the synchronous boundary;
- boundary stats, the :class:`DeviceBoundaryHandle` retune surface, and the
  Supervisor's ``_boundary_act`` grow/shrink policy.
"""

import numpy as np
import pytest

from repro.core import FFNode, all_to_all, farm, pipeline
from repro.core.compiler import (DeviceBoundaryHandle, HybridRunner,
                                 _DeviceStageNode)


class Gen(FFNode):
    def __init__(self, n):
        super().__init__()
        self.i, self.n = 0, n

    def svc(self, _):
        self.i += 1
        return np.float32(self.i) if self.i <= self.n else None


def _bytes(out):
    return [np.asarray(y).tobytes() for y in out]


def _boundary_nodes(r):
    return [s for s in r._skel._stages if isinstance(s, _DeviceStageNode)]


# ---------------------------------------------------------------------------
# overlap-on vs overlap-off parity
# ---------------------------------------------------------------------------
def test_hybrid_pipeline_overlap_parity(plan):
    xs = [np.linspace(-1.0, 1.0, 16, dtype=np.float32) * (i + 1)
          for i in range(23)]

    def run(overlap):
        r = pipeline(lambda x: np.asarray(x) + 1.0, lambda x: x * 1.5,
                     lambda x: x - 0.125).compile(
            plan, device_batch=4, inflight=3, normalize=False,
            overlap=overlap,
            placements={0: "host", 1: "device", 2: "device"})
        assert isinstance(r, HybridRunner)
        return r.run(xs)

    assert _bytes(run(True)) == _bytes(run(False))


def test_hybrid_farm_overlap_parity(plan):
    n = 17

    def run(overlap):
        r = pipeline(Gen(n), farm(lambda x: x * 3.0 + 0.5, n=2)).compile(
            plan, device_batch=4, inflight=2, normalize=False,
            overlap=overlap, placements={1: "device"})
        assert isinstance(r, HybridRunner)
        return r.run()

    a, b = run(True), run(False)
    assert len(a) == n
    assert _bytes(a) == _bytes(b)


def test_hybrid_a2a_overlap_parity(plan):
    """all_to_all routing keys off the absolute stream offset — the window
    must keep the per-microbatch ``_off`` discipline bit-for-bit."""
    n = 16

    def run(overlap):
        r = pipeline(Gen(n),
                     all_to_all([lambda x: x * 10.0],
                                [lambda y: y * 2.0, lambda y: y + 7.0]),
                     lambda y: float(np.asarray(y)) - 0.25).compile(
            plan, device_batch=4, inflight=3, normalize=False,
            overlap=overlap, placements={1: "device", 2: "host"})
        assert isinstance(r, HybridRunner)
        return r.run()

    a, b = run(True), run(False)
    assert len(a) == n
    assert _bytes(a) == _bytes(b)


def test_wrap_around_hybrid_forces_sync_boundary(plan):
    """A feedback loop circulates one item at a time: an async window
    holding results back would deadlock it, so the hybrid emit forces the
    synchronous boundary no matter what ``overlap``/``inflight`` ask for."""
    def run(overlap):
        g = pipeline(lambda x: float(x) + 0.0, lambda x: x + 1.0)
        g = g.wrap_around()
        r = g.compile(plan, overlap=overlap, inflight=8, normalize=False,
                      feedback_cond=lambda x: float(np.asarray(x)) < 10.0,
                      placements={0: "host", 1: "device"})
        assert isinstance(r, HybridRunner)
        node = _boundary_nodes(r)[0]
        assert node._inflight == 1        # sync forced, even overlap=True
        assert node._B == 1               # one item per turn
        return r.run([np.float32(i) for i in range(4)], timeout=60.0)

    a, b = run(True), run(False)
    assert sorted(_bytes(a)) == sorted(_bytes(b))
    assert sorted(float(np.asarray(x)) for x in a) == [10.0] * 4


def test_device_runner_microbatched_parity(plan):
    """All-device path: the software-pipelined chunking (async window AND
    strictly-sync chunking) matches the whole-stream batch byte-for-byte."""
    xs = [np.linspace(-1.0, 1.0, 8, dtype=np.float32) * (i + 1)
          for i in range(23)]

    def build():
        return pipeline(lambda x: x * 1.5 + 0.25, lambda x: x - 0.125)

    whole = build().compile(plan, mode="device").run(xs)
    piped = build().compile(plan, mode="device", microbatch=4,
                            inflight=3).run(xs)
    sync = build().compile(plan, mode="device", microbatch=4,
                           overlap=False).run(xs)
    assert _bytes(whole) == _bytes(piped) == _bytes(sync)


# ---------------------------------------------------------------------------
# ordering, degeneration, crash-in-flight
# ---------------------------------------------------------------------------
def test_exact_order_on_stream_much_longer_than_window(plan):
    n = 200                              # 50 microbatches through a 4-window
    xs = [np.float32(i) for i in range(n)]
    r = pipeline(lambda x: float(x), lambda x: x * 2.0).compile(
        plan, device_batch=4, inflight=4, normalize=False,
        placements={0: "host", 1: "device"})
    out = [float(np.asarray(y)) for y in r.run(xs)]
    assert out == [2.0 * i for i in range(n)]


def test_microbatch1_inflight1_degenerates_to_sync(plan):
    xs = [np.float32(i) for i in range(6)]
    r = pipeline(lambda x: float(x), lambda x: x + 1.0).compile(
        plan, microbatch=1, inflight=1, normalize=False,
        placements={0: "host", 1: "device"})
    node = _boundary_nodes(r)[0]
    assert node._B == 1 and node._inflight == 1
    out = [float(np.asarray(y)) for y in r.run(xs)]
    assert out == [i + 1.0 for i in range(6)]
    st = node.node_stats()
    assert st["boundary"]["mode"] == "sync"
    assert st["flushes"] == 6            # one dispatch per item, awaited
    assert st["boundary"]["stall_s"] == 0.0
    assert not node._window


def test_crash_in_flight_surfaces_error_without_wedging(plan):
    """A microbatch that fails to dispatch while older ones ride the window
    must surface the error from run() — not hang the boundary thread or
    leave the window half-drained."""
    n_good = 8                           # two clean microbatches go async
    xs = [np.ones((4,), np.float32) * i for i in range(n_good)]
    xs.append(np.ones((5,), np.float32))  # ragged: np.stack blows up
    xs += [np.ones((4,), np.float32)] * 3
    r = pipeline(lambda x: np.asarray(x), lambda x: x * 2.0).compile(
        plan, device_batch=4, inflight=4, normalize=False,
        placements={0: "host", 1: "device"})
    with pytest.raises(Exception):
        r.run(xs, timeout=30.0)
    node = _boundary_nodes(r)[0]
    assert node.error is not None        # the worker error, not a timeout
    assert not node._window              # drained, not wedged
    assert not node._alive()


# ---------------------------------------------------------------------------
# boundary stats, handle, supervisor policy
# ---------------------------------------------------------------------------
def test_boundary_stats_and_handle(plan):
    xs = [np.float32(i) for i in range(20)]
    r = pipeline(lambda x: float(x), lambda x: x * 2.0).compile(
        plan, device_batch=4, inflight=2, normalize=False,
        placements={0: "host", 1: "device"})
    r.run(xs)
    node = _boundary_nodes(r)[0]
    b = node.node_stats()["boundary"]
    assert b["mode"] == "overlapped"
    assert b["microbatch"] == 4 and b["inflight"] == 2
    assert b["retired"] == 20
    assert b["submit_s"] > 0.0 and b["drain_s"] > 0.0
    h = [h for h in r.stage_handles()
         if isinstance(h, DeviceBoundaryHandle)][0]
    assert h.boundary_tunable and not h.reconfigurable
    assert h.tier == "device"
    assert h.stats()["boundary"]["retired"] == 20
    h.set_window(inflight=5, microbatch=8)
    assert node._inflight == 5 and node._B == 8


def test_device_runner_boundary_stats(plan):
    xs = [np.float32(i) for i in range(23)]
    r = pipeline(lambda x: x * 2.0).compile(plan, mode="device",
                                            microbatch=4, inflight=3)
    r.run(xs)
    b = r.stats()["boundary"]
    assert b["mode"] == "overlapped" and b["chunks"] == 6
    assert b["h2d_s"] > 0.0 and b["drain_s"] > 0.0
    # the default whole-batch path still reports one batch (and says sync)
    r2 = pipeline(lambda x: x * 2.0).compile(plan, mode="device")
    r2.run(xs)
    s2 = r2.stats()
    assert s2["batches"] == 1 and s2["boundary"]["mode"] == "sync"


class _StubBoundaryHandle:
    boundary_tunable = True
    reconfigurable = False
    desc = "device[stub]"

    def __init__(self):
        self.windows = []

    def set_window(self, inflight=None, microbatch=None):
        self.windows.append(inflight)


class _StubRunner:
    def stage_handles(self):
        return []


def test_supervisor_boundary_retune_policy():
    """_boundary_act grows the window when the stall share of drain over a
    sampling window is high, shrinks it when the window never stalls, and
    ignores sync boundaries — with cooldown in between."""
    from repro.core.runtime import Supervisor

    def snap(retired, stall, drain, k=2, mode="overlapped"):
        return {"node": "device[x]",
                "boundary": {"mode": mode, "inflight": k, "retired": retired,
                             "stall_s": stall, "drain_s": drain}}

    sup = Supervisor(_StubRunner(), observe=False, min_window_items=4)
    h = _StubBoundaryHandle()
    sup._boundary_act(0, h, snap(0, 0.0, 0.0))          # seeds the window
    sup._boundary_act(0, h, snap(10, 0.9, 1.0))         # 90% stalled: grow
    assert h.windows == [3]
    assert sup.events and sup.events[-1].kind == "retune"
    sup._boundary_act(0, h, snap(20, 1.8, 2.0))         # cooldown: no act
    assert h.windows == [3]

    sup2 = Supervisor(_StubRunner(), observe=False, min_window_items=4)
    h2 = _StubBoundaryHandle()
    sup2._boundary_act(0, h2, snap(0, 0.0, 0.0, k=4))
    sup2._boundary_act(0, h2, snap(10, 0.0, 1.0, k=4))  # never stalls: shrink
    assert h2.windows == [3]

    sup3 = Supervisor(_StubRunner(), observe=False, min_window_items=4)
    h3 = _StubBoundaryHandle()
    sup3._boundary_act(0, h3, snap(0, 0.0, 0.0, mode="sync"))
    sup3._boundary_act(0, h3, snap(10, 0.9, 1.0, mode="sync"))
    assert h3.windows == []                             # sync: hands off
