"""Per-kernel validation: shape/dtype sweeps, interpret=True kernels vs the
pure-jnp oracles in kernels/ref.py, plus gradient paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import flash_attention, router_topk, ssd_scan

pytestmark = pytest.mark.kernels


def _rnd(key, *shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,H,Hkv,Sq,Sk,D", [
    (1, 2, 2, 128, 128, 64),
    (2, 4, 2, 256, 256, 64),     # GQA 2:1
    (1, 4, 1, 128, 256, 32),     # MQA, chunked-prefill alignment
    (1, 2, 2, 128, 128, 128),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, Hkv, Sq, Sk, D, causal, window,
                                     dtype, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    q = _rnd(k1, B, H, Sq, D, dtype=dtype)
    k = _rnd(k2, B, Hkv, Sk, D, dtype=dtype)
    v = _rnd(k3, B, Hkv, Sk, D, dtype=dtype)
    o = flash_attention(q, k, v, causal, window, 128)
    r = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=TOL[dtype], atol=TOL[dtype])


def test_flash_attention_grad_finite(rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    q = _rnd(k1, 1, 2, 128, 32)
    k = _rnd(k2, 1, 2, 128, 32)
    v = _rnd(k3, 1, 2, 128, 32)
    g = jax.grad(lambda q, k, v: flash_attention(q, k, v).sum(),
                 argnums=(0, 1, 2))(q, k, v)
    for t in g:
        assert np.all(np.isfinite(np.asarray(t)))
    # grad matches grad of the oracle
    gr = jax.grad(lambda q, k, v: ref.attention_ref(q, k, v).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("B,H,S,N,P,chunk", [
    (1, 2, 128, 16, 32, 64),
    (2, 3, 256, 32, 64, 128),
    (1, 1, 64, 8, 8, 64),        # single chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(B, H, S, N, P, chunk, dtype, rng):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    q = _rnd(k1, B, H, S, N, dtype=dtype, scale=0.3)
    k = _rnd(k2, B, H, S, N, dtype=dtype, scale=0.3)
    v = _rnd(k3, B, H, S, P, dtype=dtype)
    la = (-jnp.abs(jax.random.normal(k4, (B, H, S))) * 0.1)
    o = ssd_scan(q, k, v, la, chunk)
    r = ref.ssd_scan_ref(q, k, v, la)
    tol = 2e-3 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("T,E,K,C,bt", [
    (256, 8, 2, 80, 128),
    (512, 16, 4, 150, 256),
    (128, 4, 1, 40, 128),
])
def test_router_matches_ref(T, E, K, C, bt, rng):
    logits = jax.random.normal(rng, (T, E))
    w, i, p, keep = router_topk(logits, K, C, bt)
    wr, ir, pr, keepr = ref.router_topk_ref(logits, K, C)
    assert np.array_equal(np.asarray(i), np.asarray(ir))
    assert np.array_equal(np.asarray(p), np.asarray(pr))
    assert np.array_equal(np.asarray(keep), np.asarray(keepr))
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr), rtol=1e-5,
                               atol=1e-6)


def test_router_capacity_never_exceeded(rng):
    """Property: per-expert kept count <= capacity, kept slots unique."""
    T, E, K, C = 512, 8, 2, 64
    logits = jax.random.normal(rng, (T, E)) * 3.0   # skewed -> drops happen
    w, i, p, keep = router_topk(logits, K, C, 256)
    i, p, keep = map(np.asarray, (i, p, keep))
    for e in range(E):
        kept = keep & (i == e)
        assert kept.sum() <= C
        slots = p[kept]
        assert len(set(slots.tolist())) == len(slots)   # unique lane slots
    assert keep.sum() > 0


def test_flash_attention_in_model_path(plan, rng):
    """cfg.use_pallas integration: attention block output with the kernel
    equals the XLA streaming path."""
    from repro.configs import get
    from repro.models import attention as A
    from repro.models.params import init_params
    cfg = get("ff-tiny").reduced()
    p = init_params(A.attn_defs(cfg, None), rng)
    B, S = 2, 64
    x = _rnd(rng, B, S, cfg.d_model, dtype=jnp.bfloat16, scale=0.3)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out_xla, _ = A.attention(x, p, cfg, plan, positions=pos, q_block=32,
                             kv_block=32)
    # kernel path
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    from repro.models.layers import apply_rope
    q, k = apply_rope(q, pos, cfg.rope_theta), apply_rope(k, pos,
                                                          cfg.rope_theta)
    o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), True, 0, 32)
    out_k = jnp.einsum("bshk,hkd->bsd", o.transpose(0, 2, 1, 3), p["wo"])
    np.testing.assert_allclose(np.asarray(out_xla, np.float32),
                               np.asarray(out_k, np.float32),
                               rtol=3e-2, atol=3e-2)
