"""The batched uSPSC shm transport (PR 7): vectored push_many/pop_many
batch-boundary correctness, the uSPSC unbounded tier, the slab arena for
oversize ndarrays, compile(transport=...) tuning knobs, NUMA degradation on
a single-node container, and the amortized-hop calibration constants."""

import os
import signal
import time

import numpy as np
import pytest

from repro.core import (FFNode, ProcessRunner, WorkerCrashed, farm,
                        perf_model as pm, pipeline)
from repro.core.process import (_node_affinity, _numa_topology,
                                _parse_cpulist, _pin)
from repro.core.queues import QueueClosed
from repro.core.shm import (BatchedLaneWriter, ShmArena, ShmError,
                            ShmSPSCQueue, ShmUSPSCQueue, TransportConfig,
                            as_transport)


class Gen(FFNode):
    def __init__(self, n):
        super().__init__()
        self.i, self.n = 0, n

    def svc(self, _):
        self.i += 1
        return np.float32(self.i) if self.i <= self.n else None


# -- vectored push/pop batch boundaries ----------------------------------------
def test_push_many_partial_flushes_preserve_exact_order():
    # ring far smaller than the stream: every push_many is a partial flush
    q = ShmSPSCQueue(capacity=4)
    items = [(i, f"s{i}") for i in range(257)]   # odd count: partial tail
    sent = 0
    out = []
    while sent < len(items) or len(out) < len(items):
        sent += q.try_push_many(items[sent:sent + 16])
        out.extend(item for item, _seq in q.try_pop_many(8))
    assert out == items
    q.destroy()


def test_push_many_assigns_contiguous_seqs_across_partial_flushes():
    q = ShmSPSCQueue(capacity=4)
    seqs = []
    sent = 0
    while sent < 40 or len(seqs) < 40:
        sent += q.try_push_many(list(range(sent, min(40, sent + 7))),
                                seqs=list(range(sent, min(40, sent + 7))))
        seqs.extend(s for _item, s in q.try_pop_many(5))
    assert seqs == list(range(40))
    q.destroy()


def test_eos_after_pending_partial_batch_arrives_last():
    from repro.core.node import EOS
    q = ShmSPSCQueue(capacity=32)
    w = BatchedLaneWriter(q, batch=16, flush_s=60.0)
    for i in range(5):                  # pending partial batch, never due
        w.put(i, seq=i)
    assert q.empty()                    # nothing flushed yet
    w.push_eos()                        # must flush the 5, THEN mark EOS
    got = [item for item, _ in q.try_pop_many(64)]
    assert got[:5] == [0, 1, 2, 3, 4]   # items strictly before the mark
    assert got[5] is EOS and len(got) == 6
    q.destroy()


def test_err_after_pending_partial_batch_arrives_after_items():
    q = ShmSPSCQueue(capacity=32)
    w = BatchedLaneWriter(q, batch=16, flush_s=60.0)
    for i in range(3):
        w.put(i, seq=i)
    w.push_err(ShmError(0, "ValueError: boom", "tb"))
    got = [q.pop() for _ in range(3)]
    assert got == [0, 1, 2]
    err = q.pop()
    assert isinstance(err, ShmError) and "ValueError" in err.exc
    q.destroy()


def test_batched_writer_age_flush():
    q = ShmSPSCQueue(capacity=32)
    w = BatchedLaneWriter(q, batch=16, flush_s=0.01)
    w.put("x", seq=0)
    assert q.empty()
    deadline = time.monotonic() + 5.0
    while q.empty():
        w.maybe_flush()
        if time.monotonic() > deadline:
            pytest.fail("age flush never fired")
        time.sleep(1e-3)
    assert q.pop() == "x"
    q.destroy()


# -- uSPSC unbounded tier ------------------------------------------------------
def test_uspsc_grows_segments_on_stream_far_beyond_capacity():
    q = ShmUSPSCQueue(capacity=8)
    n = 500                             # >> one 8-slot segment
    for i in range(n):                  # never blocks: the chain grows
        q.push(i, timeout=1.0)
    assert q.segments_grown > 0
    assert [q.pop() for _ in range(n)] == list(range(n))
    q.destroy()


def test_uspsc_push_many_grows_and_preserves_order():
    # ndarrays take one slot each (no batch coalescing), so 300 of them
    # must span many 8-slot segments within the single push_many call
    q = ShmUSPSCQueue(capacity=8)
    items = [np.full(4, i, dtype=np.int64) for i in range(300)]
    q.push_many(items, timeout=5.0)     # single call spans many segments
    assert q.segments_grown > 0
    out = []
    while len(out) < len(items):
        out.extend(item for item, _ in q.pop_many(64, timeout=5.0))
    assert [int(a[0]) for a in out] == list(range(300))
    q.destroy()


def test_uspsc_push_many_coalesces_small_items_without_growth():
    # the flip side: runs of small non-array items pickle together into
    # BATCH slots, so even 300 of them fit one 8-slot segment
    q = ShmUSPSCQueue(capacity=8)
    items = [(i, "payload") for i in range(300)]
    q.push_many(items, timeout=5.0)
    assert q.segments_grown == 0
    out = []
    while len(out) < len(items):
        out.extend(item for item, _ in q.pop_many(512, timeout=5.0))
    assert out == items
    q.destroy()


def _uspsc_producer_child(q, n):
    for i in range(n):
        q.push(np.full(2, i, dtype=np.int64), timeout=30.0)
    q.push_eos()
    q.detach()


@pytest.mark.shm
def test_uspsc_cross_process_growth_and_order():
    import multiprocessing as mp
    from repro.core.node import EOS
    q = ShmUSPSCQueue(capacity=8)
    n = 400
    p = mp.get_context("fork").Process(
        target=_uspsc_producer_child, args=(q, n), daemon=True)
    p.start()
    out = []
    while True:                         # EOS rides in-stream, like a farm lane
        item = q.pop(timeout=30.0)
        if item is EOS:
            break
        out.append(int(item[0]))
    assert out == list(range(n))
    p.join(timeout=10.0)
    q.destroy()


def test_uspsc_close_drains_then_raises():
    q = ShmUSPSCQueue(capacity=4)
    for i in range(10):
        q.push(np.full(2, i, dtype=np.int64))
    q.close()                           # marks the producer's final segment
    assert [int(q.pop()[0]) for _ in range(10)] == list(range(10))
    with pytest.raises(QueueClosed):
        q.pop(timeout=1.0)
    q.destroy()


def test_spmc_unbounded_lanes_never_backpressure():
    from repro.core.shm import ShmSPMCQueue
    q = ShmSPMCQueue(2, capacity=4, bounded=False)
    for i in range(100):                # 50 items per 4-slot lane
        q.push_to(i % 2, i, timeout=1.0)    # never blocks: chains grow
    a = [q.lanes[0].pop() for _ in range(50)]
    b = [q.lanes[1].pop() for _ in range(50)]
    assert a == list(range(0, 100, 2))
    assert b == list(range(1, 100, 2))
    q.destroy()


# -- slab arena ----------------------------------------------------------------
def test_oversize_array_takes_arena_path_never_pickle():
    q = ShmSPSCQueue(capacity=8, slot_bytes=1024, arena_bytes=1 << 22)
    a = np.arange(65_536, dtype=np.float32)     # 256 KiB >> slot_bytes
    assert q.try_push(a)
    assert q.arena_pushes == 1
    assert q.pickle_fallbacks == 0              # the regression guard
    ok, out = q.try_pop()
    assert ok and np.array_equal(out, a) and out.dtype == a.dtype
    q.destroy()


def test_arena_frees_space_after_consumption():
    q = ShmSPSCQueue(capacity=8, slot_bytes=1024, arena_bytes=1 << 20)
    a = np.zeros(100_000, dtype=np.float32)     # 400 KiB of a 1 MiB arena
    for _ in range(8):                          # > arena capacity in total
        assert q.try_push(a)
        ok, _out = q.try_pop()
        assert ok
    assert q.arena_pushes == 8
    q.destroy()


def test_arena_backpressure_when_full_then_recovers():
    q = ShmSPSCQueue(capacity=8, slot_bytes=1024, arena_bytes=1 << 20)
    a = np.zeros(100_000, dtype=np.float32)
    assert q.try_push(a)
    assert q.try_push(a)
    assert not q.try_push(a)            # arena full: back-pressure, no pickle
    assert q.pickle_fallbacks == 0
    q.try_pop()
    assert q.try_push(a)                # freed space is reusable
    q.destroy()


def test_array_larger_than_whole_arena_raises():
    q = ShmSPSCQueue(capacity=8, slot_bytes=1024, arena_bytes=1 << 16)
    with pytest.raises(ValueError, match="arena_bytes"):
        q.try_push(np.zeros(1 << 20, dtype=np.uint8))
    q.destroy()


def test_arena_roundtrip_noncontiguous_and_fortran_arrays():
    q = ShmSPSCQueue(capacity=8, slot_bytes=512, arena_bytes=1 << 22)
    base = np.arange(40_000, dtype=np.float64).reshape(200, 200)
    for a in (base[::2, ::2], np.asfortranarray(base)):
        assert q.try_push(a)
        ok, out = q.try_pop()
        assert ok and np.array_equal(out, a)
    q.destroy()


def _arena_echo_child(in_lane, out_lane):
    from repro.core.node import EOS
    while True:
        item = in_lane.pop()
        if item is EOS:
            break
        out_lane.push(item)
    out_lane.push_eos()
    in_lane.detach()
    out_lane.detach()


@pytest.mark.shm
def test_arena_arrays_cross_process_roundtrip():
    import multiprocessing as mp
    ping = ShmSPSCQueue(capacity=8, slot_bytes=1024, arena_bytes=1 << 22)
    pong = ShmSPSCQueue(capacity=8, slot_bytes=1024, arena_bytes=1 << 22)
    p = mp.get_context("fork").Process(
        target=_arena_echo_child, args=(ping, pong), daemon=True)
    p.start()
    rng = np.random.default_rng(0)
    for _ in range(5):
        a = rng.standard_normal(50_000).astype(np.float32)  # 200 KiB
        ping.push(a, timeout=30.0)
        out = pong.pop(timeout=30.0)
        assert np.array_equal(out, a)
    assert ping.arena_pushes == 5 and ping.pickle_fallbacks == 0
    ping.push_eos()
    p.join(timeout=10.0)
    ping.destroy()
    pong.destroy()


# -- crashed worker mid-batch --------------------------------------------------
def _kill_on_five(x):
    if int(x) == 5:
        os.kill(os.getpid(), signal.SIGKILL)
    return float(x)


@pytest.mark.shm
def test_crashed_worker_mid_batch_surfaces_worker_crashed():
    # stream >> batch so the crash lands with batches pending on both the
    # emitter and collector sides; the farm must unwind, not wedge
    r = pipeline(Gen(200), farm(_kill_on_five, n=2)).compile(
        mode="process", transport={"batch": 16, "flush_s": 0.001})
    t0 = time.monotonic()
    with pytest.raises(WorkerCrashed):
        r.run(timeout=60.0)
    assert time.monotonic() - t0 < 45.0


# -- compile(transport=...) knobs ----------------------------------------------
def test_transport_config_defaults_and_validation():
    tc = TransportConfig()
    assert (tc.ring_slots, tc.grid_slots, tc.slot_bytes) == (64, 32, 1 << 16)
    assert tc.bounded and tc.batch == 16
    assert as_transport(None) == TransportConfig()
    assert as_transport({"ring_slots": 8}).ring_slots == 8
    assert as_transport(tc) is tc
    with pytest.raises(ValueError):
        TransportConfig(ring_slots=1)
    with pytest.raises(ValueError):
        TransportConfig(batch=0)
    with pytest.raises(TypeError):
        as_transport({"bogus_knob": 1})


@pytest.mark.shm
def test_compile_transport_dict_tunes_farm_lanes():
    r = pipeline(Gen(6), farm(lambda x: x * 2.0, n=2)).compile(
        mode="process",
        transport={"ring_slots": 8, "slot_bytes": 1 << 12, "batch": 4})
    assert isinstance(r, ProcessRunner)
    assert sorted(float(v) for v in r.run(timeout=60.0)) == [
        2.0 * i for i in range(1, 7)]


@pytest.mark.shm
def test_compile_transport_unbounded_worker_lanes():
    r = pipeline(Gen(50), farm(lambda x: x + 1.0, n=2)).compile(
        mode="process", transport=TransportConfig(ring_slots=4,
                                                  bounded=False))
    out = sorted(float(v) for v in r.run(timeout=60.0))
    assert out == [float(i) + 1.0 for i in range(1, 51)]


# -- NUMA degradation ----------------------------------------------------------
def test_parse_cpulist_forms():
    assert _parse_cpulist("0-3,8-11\n") == [0, 1, 2, 3, 8, 9, 10, 11]
    assert _parse_cpulist("0") == [0]
    assert _parse_cpulist("") == []


@pytest.mark.shm
def test_numa_degrades_gracefully_on_single_node_host():
    # the CI container has one (or zero) sysfs NUMA nodes: topology must
    # come back empty, pinning must fall back to round-robin cores, and the
    # affinity guard must be a no-op — never a crash
    nodes = _numa_topology(refresh=True)
    assert isinstance(nodes, list)
    saved = os.sched_getaffinity(0)
    try:
        _pin(0)                         # falls back to core round-robin
        _pin(7)
    finally:
        os.sched_setaffinity(0, saved)
    with _node_affinity([]):            # empty node set: no-op
        pass
    r = pipeline(Gen(6), farm(lambda x: x * 3.0, n=2)).compile(
        mode="process")
    assert sorted(float(v) for v in r.run(timeout=60.0)) == [
        3.0 * i for i in range(1, 7)]


# -- calibration: the amortized hop --------------------------------------------
def test_calibration_effective_hop_caps_at_per_item_hop():
    c = pm.HostCalibration(peak_flops=1e10, queue_hop_s=1e-5,
                           proc_hop_s=2e-4, device_dispatch_s=1e-5,
                           shm_batched_hop_s=1e-5)
    assert c.proc_hop_effective_s() == 1e-5
    noisy = pm.HostCalibration(peak_flops=1e10, queue_hop_s=1e-5,
                               proc_hop_s=2e-4, device_dispatch_s=1e-5,
                               shm_batched_hop_s=5e-4)
    assert noisy.proc_hop_effective_s() == 2e-4


@pytest.mark.shm
def test_measured_batched_hop_beats_per_item_hop():
    batched = pm._measure_shm_batched_hop(n=400, batch=32)
    per_item = pm._measure_proc_hop(n=100)
    assert 0.0 < batched < per_item


def test_calibration_cache_roundtrips_batched_constants(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_FF_CALIB_CACHE",
                       str(tmp_path / "calib.json"))
    import dataclasses
    import json
    c = dataclasses.replace(pm.DEFAULT_CALIBRATION,
                            shm_batched_hop_s=7e-6, arena_bw_gbs=3.5,
                            source="measured")
    with open(tmp_path / "calib.json", "w") as f:
        json.dump({"version": pm._CALIB_VERSION,
                   "cpu_count": os.cpu_count(), **c.as_dict()}, f)
    loaded = pm._load_cached_calibration()
    assert loaded is not None
    assert loaded.shm_batched_hop_s == 7e-6
    assert loaded.arena_bw_gbs == 3.5
    # version-2 caches (no batched constants) must miss cleanly
    with open(tmp_path / "calib.json", "w") as f:
        d = {"version": 2, "cpu_count": os.cpu_count(), **c.as_dict()}
        del d["shm_batched_hop_s"], d["arena_bw_gbs"]
        json.dump(d, f)
    assert pm._load_cached_calibration() is None
