"""The distributed tier (PR 6): frame codec robustness, NetLane credit /
heartbeat discipline, loopback-cluster remote farms, cluster autoscaling,
and the net-hop calibration + observe() feedback."""

import contextlib
import os
import pathlib
import signal
import socket
import struct
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import (EOS, FFNode, GraphError, HostRunner, NetLane,
                        RemoteFarmNode, RemoteRunner, WorkerCrashed, farm,
                        perf_model as pm, pipeline, spawn_loopback_pool)
from repro.core.net import (FrameError, MAX_FRAME_BYTES, TAG_ARR, TAG_PKL,
                            _SLOT_FMT, _SLOT_HDR, decode_payload, encode_frame,
                            encode_item, parse_addr, read_frame)
from repro.core.runtime import Supervisor
from repro.core.shm import WorkerStats

pytestmark = pytest.mark.net


# -- module-level workers (must pickle across the wire) ------------------------
class _Gen(FFNode):
    def __init__(self, n):
        super().__init__()
        self.i, self.n = 0, n

    def svc(self, _):
        self.i += 1
        return np.float32(self.i) if self.i <= self.n else None


class _ArrGen(FFNode):
    def __init__(self, n):
        super().__init__()
        self.i, self.n = 0, n

    def svc(self, _):
        if self.i >= self.n:
            return None
        self.i += 1
        return np.arange(8, dtype=np.float32) + np.float32(self.i)


class _GenUnpicklable(FFNode):
    def __init__(self):
        super().__init__()
        self.done = False

    def svc(self, _):
        if self.done:
            return None
        self.done = True
        return (i for i in range(3))    # generators cannot pickle


def _double(x):
    return x * 2.0


def _sleepy(x):
    time.sleep(0.01)
    return x + 1.0


def _kill_on_seven(x):
    if int(x) == 7:
        os.kill(os.getpid(), signal.SIGKILL)
    return float(x)


@contextlib.contextmanager
def _pool(n, **kw):
    addrs, procs = spawn_loopback_pool(n, **kw)
    try:
        yield addrs, procs
    finally:
        for p in procs:
            p.terminate()
        for p in procs:
            p.join(timeout=10.0)


def _roundtrip(item):
    frame = encode_item(item)
    length, tag, seq = struct.unpack(_SLOT_FMT, frame[:_SLOT_HDR])
    assert length == len(frame) - _SLOT_HDR
    return tag, decode_payload(tag, frame[_SLOT_HDR:])


# -- frame codec ---------------------------------------------------------------
def test_parse_addr_forms():
    assert parse_addr("127.0.0.1:7001") == ("127.0.0.1", 7001)
    assert parse_addr(("10.0.0.2", 80)) == ("10.0.0.2", 80)
    with pytest.raises(ValueError):
        parse_addr("no-port-here")


def test_contiguous_array_rides_raw_fast_path_byte_identical():
    a = np.random.default_rng(0).standard_normal((5, 7)).astype(np.float32)
    tag, b = _roundtrip(a)
    assert tag == TAG_ARR
    assert b.dtype == a.dtype and b.shape == a.shape
    assert b.tobytes() == a.tobytes()


def test_0d_forder_and_noncontiguous_arrays_roundtrip():
    z = np.array(3.5, dtype=np.float64)             # 0-d
    tag, b = _roundtrip(z)
    assert tag == TAG_ARR and b.shape == () and float(b) == 3.5

    f = np.asfortranarray(np.arange(12, dtype=np.int32).reshape(3, 4))
    tag, b = _roundtrip(f)                          # F-order: made contiguous
    assert tag == TAG_ARR
    np.testing.assert_array_equal(b, f)

    s = np.arange(20, dtype=np.float32)[::3]        # strided view
    tag, b = _roundtrip(s)
    assert tag == TAG_ARR
    np.testing.assert_array_equal(b, s)


def test_structured_object_and_pytree_fall_back_to_pickle():
    rec = np.zeros(3, dtype=[("a", "f4"), ("b", "i8")])
    tag, b = _roundtrip(rec)
    assert tag == TAG_PKL
    np.testing.assert_array_equal(b, rec)

    obj = np.array([{"k": 1}, None, (2, 3)], dtype=object)
    tag, b = _roundtrip(obj)
    assert tag == TAG_PKL and b[0] == {"k": 1}

    tree = {"x": np.float32(2.0), "y": [1, "two"]}
    tag, b = _roundtrip(tree)
    assert tag == TAG_PKL and b == tree


def test_oversized_payload_rejected_on_both_sides():
    big = np.zeros(1024, dtype=np.uint8)
    with pytest.raises(FrameError):
        encode_frame(TAG_ARR, big, max_frame=64)    # encode side
    a, b = socket.socketpair()
    try:
        # a length word past the lane limit is rejected before allocation
        a.sendall(struct.pack(_SLOT_FMT, MAX_FRAME_BYTES + 1, TAG_PKL, 0))
        with pytest.raises(FrameError, match="oversized"):
            read_frame(b)
    finally:
        a.close()
        b.close()


def test_partial_reads_reassemble_truncation_raises_clean_eof_is_none():
    frame = encode_item(np.arange(64, dtype=np.float32), seq=9)

    a, b = socket.socketpair()
    try:
        def drip():
            for i in range(0, len(frame), 7):       # 7-byte chunks
                a.sendall(frame[i:i + 7])
                time.sleep(0.001)
        t = threading.Thread(target=drip, daemon=True)
        t.start()
        tag, payload, seq = read_frame(b)
        t.join()
        assert (tag, seq) == (TAG_ARR, 9)
        np.testing.assert_array_equal(decode_payload(tag, payload),
                                      np.arange(64, dtype=np.float32))
    finally:
        a.close()
        b.close()

    a, b = socket.socketpair()
    try:
        a.sendall(frame[:_SLOT_HDR + 10])           # truncated mid-payload
        a.close()
        with pytest.raises(FrameError, match="truncated"):
            read_frame(b)
    finally:
        b.close()

    a, b = socket.socketpair()
    try:
        a.close()                                   # clean EOF at a boundary
        assert read_frame(b) is None
    finally:
        b.close()


def test_corrupt_ndarray_meta_raises_frame_error():
    frame = encode_item(np.arange(8, dtype=np.float32))
    payload = bytearray(frame[_SLOT_HDR:])
    payload[0] = 7                                  # lie about ndim
    with pytest.raises(FrameError):
        decode_payload(TAG_ARR, bytes(payload))


# -- NetLane: credit window + liveness ----------------------------------------
def _lane_pair(credit=4, **kw):
    a, b = socket.socketpair()
    kw.setdefault("hb_interval", 5.0)               # quiet heartbeats
    return (NetLane(a, credit=credit, label="A", **kw),
            NetLane(b, credit=credit, label="B", **kw))


def test_credit_window_backpressures_and_pop_regrants():
    A, B = _lane_pair(credit=4)
    try:
        for i in range(4):
            assert A.try_push(np.float32(i), seq=i)
        assert not A.try_push(np.float32(99), seq=99)   # window exhausted
        assert len(A) >= 4

        item, seq = B.pop_seq(timeout=10.0)             # pop grants a credit
        assert (float(item), seq) == (0.0, 0)
        A.push(np.float32(4), timeout=10.0, seq=4)      # ... which re-opens
        for want in (1, 2, 3, 4):
            item, seq = B.pop_seq(timeout=10.0)
            assert seq == want
    finally:
        A.shutdown()
        B.shutdown()


def test_stream_longer_than_window_arrives_in_exact_order():
    A, B = _lane_pair(credit=4)
    n = 64
    try:
        def feed():
            for i in range(n):
                A.push(np.float32(i), timeout=30.0, seq=i)
            A.push_eos()
        t = threading.Thread(target=feed, daemon=True)
        t.start()
        seqs, vals = [], []
        while True:
            item, seq = B.pop_seq(timeout=30.0)
            if item is EOS:
                break
            seqs.append(seq)
            vals.append(float(item))
        t.join()
        assert seqs == list(range(n))
        assert vals == [float(i) for i in range(n)]
        assert A.max_depth <= 4                         # window held
    finally:
        A.shutdown()
        B.shutdown()


def test_heartbeat_timeout_marks_silent_peer_dead():
    a, b = socket.socketpair()
    lane = NetLane(a, hb_interval=0.05, hb_timeout=0.25, label="hb")
    try:
        deadline = time.monotonic() + 5.0
        while lane.peer_dead is None and time.monotonic() < deadline:
            time.sleep(0.01)
        assert lane.peer_dead is not None
        assert "heartbeat" in lane.peer_dead
        with pytest.raises(WorkerCrashed):
            lane.push(np.float32(1.0), timeout=1.0)
    finally:
        lane.shutdown()
        b.close()


def test_eof_mid_stream_marks_dead_and_pop_raises():
    a, b = socket.socketpair()
    lane = NetLane(a, hb_interval=5.0, label="eof")
    try:
        b.close()                                   # peer vanishes, no EOS
        with pytest.raises(WorkerCrashed):
            lane.pop_seq(timeout=5.0)
        assert "closed" in lane.peer_dead
    finally:
        lane.shutdown()


# -- loopback cluster: remote farms -------------------------------------------
def test_remote_farm_parity_exact_order_past_credit_window():
    n = 64                                          # stream >> credit window
    expected = [(np.arange(8, dtype=np.float32) + np.float32(i)) * 2.0
                for i in range(1, n + 1)]
    with _pool(2) as (addrs, _):
        r = pipeline(_ArrGen(n), farm(_double, n=2)).compile(
            mode="remote", remote_workers=addrs, net_credit=8)
        assert isinstance(r, RemoteRunner)
        farm_p = [p for d, p in r.placements if "farm" in d][0]
        assert farm_p.target == "host_remote" and farm_p.width == 2
        out = r.run(timeout=120.0)
    # byte-identical AND exactly input-ordered, past the credit window
    assert len(out) == n
    for got, want in zip(out, expected):
        assert got.dtype == want.dtype and got.tobytes() == want.tobytes()

    host = pipeline(_ArrGen(n), farm(_double, n=2)).compile(mode="host").run()
    assert sorted(a.tobytes() for a in host) \
        == sorted(a.tobytes() for a in expected)


def test_remote_farm_with_absorbed_emitter_collector():
    n = 10
    with _pool(2) as (addrs, _):
        r = pipeline(_Gen(n), lambda x: x + 0.5, farm(_double, n=2),
                     lambda y: y - 1.0).compile(
            mode="remote", remote_workers=addrs)
        assert isinstance(r, RemoteRunner)
        out = [float(v) for v in r.run(timeout=120.0)]
    assert out == pytest.approx(
        [(i + 0.5) * 2.0 - 1.0 for i in range(1, n + 1)])


def test_unencodable_item_surfaces_item_error_not_cluster_death():
    # an item the wire cannot carry is the item's fault: the farm must
    # surface the encode error (like the shm tier's oversized-slot raise),
    # not misreport "all workers are gone" while every worker is alive
    with _pool(2) as (addrs, procs):
        r = pipeline(_GenUnpicklable(), farm(_double, n=2)).compile(
            mode="remote", remote_workers=addrs)
        with pytest.raises(Exception) as ei:
            r.run(timeout=120.0)
        assert not isinstance(ei.value, WorkerCrashed)
        assert "gone" not in str(ei.value)
        assert all(p.is_alive() for p in procs)


def test_killed_remote_worker_surfaces_crash_not_wedge():
    with _pool(2) as (addrs, _):
        r = pipeline(_Gen(40), farm(_kill_on_seven, n=2)).compile(
            mode="remote", remote_workers=addrs)
        t0 = time.monotonic()
        with pytest.raises(WorkerCrashed):
            r.run(timeout=120.0)
        assert time.monotonic() - t0 < 60.0
        assert isinstance(r.error(), WorkerCrashed)


def test_autoscale_remote_farm_grows_active_set_from_lane_depth():
    n = 80
    with _pool(2) as (addrs, _):
        r = pipeline(_Gen(n), farm(_sleepy, n=2, autoscale=True)).compile(
            mode="remote", remote_workers=addrs)
        node = [s for s in r._skel._stages
                if isinstance(s, RemoteFarmNode)][0]
        out = [float(v) for v in r.run(timeout=120.0)]
        assert out == pytest.approx([i + 1.0 for i in range(1, n + 1)])
        st = node.node_stats()
        assert st["autoscale"]["grown"] >= 1        # 1-wide start, grew
        assert sum(st["routed_per_worker"]) == n
        assert st["svc_cpu_ema_s"] >= 0.0           # WorkerStats folded


def test_supervisor_drives_cluster_autoscaling_from_lane_depth():
    """The PR-5 Supervisor over a remote farm: trickle retires a remote
    worker, a burst reactivates it — cluster autoscaling through the same
    width policy the on-box tiers use, order preserved throughout."""
    with _pool(3) as (addrs, _):
        r = farm(_sleepy, n=3).compile(mode="remote", remote_workers=addrs)
        handles = r.stage_handles()
        assert [h.tier for h in handles] == ["host_remote"]
        assert handles[0].can_migrate("host") is False
        r.run_then_freeze()
        sup = Supervisor(r, interval=0.01, migrate=False).start()
        got = []
        done = threading.Event()

        def collect():
            while True:
                ok, item = r.load_result(timeout=120.0)
                if not ok:
                    break
                got.append(item)
            done.set()

        threading.Thread(target=collect, daemon=True).start()
        # trickle: lanes idle -> the supervisor retires remote workers
        for i in range(12):
            r.offload(float(i))
            time.sleep(0.02)
        deadline = time.monotonic() + 10.0
        while not any(e.kind == "shrink" for e in sup.events) \
                and time.monotonic() < deadline:
            time.sleep(0.01)
        # burst: deep lanes -> it grows the active remote set back
        for i in range(12, 120):
            r.offload(float(i))
        r.offload(EOS)
        assert done.wait(120.0)
        assert r.wait(30.0) == 0
        sup.stop()
        kinds = {e.kind for e in sup.events}
        assert "shrink" in kinds and "grow" in kinds
        assert got == [i + 1.0 for i in range(120)]  # seq-ordered throughout


def test_worker_cli_serves_a_lane_end_to_end():
    """python -m repro.launch.worker --listen 127.0.0.1:0 comes up, prints
    its bound port, serves the FN handshake + a short stream, ships its
    WorkerStats CPU record, and answers EOS."""
    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.worker",
         "--listen", "127.0.0.1:0"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env)
    try:
        line = proc.stdout.readline().strip()
        assert line.startswith("listening "), line
        host, port = parse_addr(line.split()[1])
        from repro.launch.worker import demo_fn
        lane = NetLane.connect(host, port, timeout=30.0)
        try:
            lane.push_fn(demo_fn)
            for i in range(5):
                lane.push(float(i), timeout=10.0, seq=i)
            lane.push_eos()
            got, stats = {}, None
            while True:
                item, seq = lane.pop_seq(timeout=60.0)
                if item is EOS:
                    break
                if isinstance(item, WorkerStats):
                    stats = item
                    continue
                got[seq] = item
            assert got == {i: float(i) * float(i) for i in range(5)}
            assert stats is not None and stats.items == 5
            assert stats.cpu_ema_s >= 0.0
        finally:
            lane.shutdown()
    finally:
        proc.terminate()
        proc.wait(timeout=10.0)


# -- placement: the host_remote target ----------------------------------------
def test_mode_remote_without_pool_rejected():
    with pytest.raises(GraphError, match="remote_workers"):
        pipeline(_Gen(3), farm(_double, n=2)).compile(mode="remote")


def test_host_remote_override_without_pool_rejected():
    with pytest.raises(GraphError):
        pipeline(_Gen(3), farm(_double, n=2)).compile(
            placements={1: "host_remote"})


def test_forced_remote_with_unpicklable_worker_falls_back_to_host():
    # a lambda cannot cross hosts even though fork-based processes take it
    r = pipeline(_Gen(3), farm(lambda x: x + 1.0, n=2)).compile(
        mode="remote", remote_workers=["127.0.0.1:1", "127.0.0.1:2"])
    assert isinstance(r, HostRunner) and not isinstance(r, RemoteRunner)
    p = [p for d, p in r.placements if "farm" in d][0]
    assert p.target == "host" and "pickle" in p.reason


# -- calibration + observe feedback (satellites) -------------------------------
def _fast_measures(monkeypatch, skip=()):
    for name in ("_measure_peak_flops", "_measure_queue_hop",
                 "_measure_proc_hop", "_measure_device_dispatch",
                 "_measure_net_hop"):
        if name not in skip:
            monkeypatch.setattr(
                pm, name,
                lambda *a, _n=name, **k:
                    1e9 if _n == "_measure_peak_flops" else 1e-4)


def test_calibrate_measures_net_hop_and_caches(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_FF_CALIB_CACHE", str(tmp_path / "calib.json"))
    _fast_measures(monkeypatch, skip=("_measure_net_hop",))
    pm.reset_calibration()
    c = pm.calibrate()
    assert c.source == "measured"
    assert 0 < c.net_hop_s < 0.1                    # loopback-measured
    pm.reset_calibration()
    c2 = pm.get_calibration(measure=False)
    assert c2.source == "cached"
    assert c2.net_hop_s == pytest.approx(c.net_hop_s)
    pm.reset_calibration()


def test_unwritable_cache_dir_degrades_with_warning(monkeypatch):
    """Satellite: a read-only cache location (sealed CI sandbox, remote
    container) keeps the measured constants in memory instead of raising."""
    monkeypatch.setenv("REPRO_FF_CALIB_CACHE", "/proc/ff-denied/calib.json")
    _fast_measures(monkeypatch)
    pm.reset_calibration()
    with pytest.warns(RuntimeWarning, match="not writable"):
        c = pm.calibrate()
    assert c.source == "measured"                   # still usable in-process
    pm.reset_calibration()


def test_observe_absorbs_remote_hop_and_true_service_time(tmp_path,
                                                          monkeypatch):
    monkeypatch.setenv("REPRO_FF_CALIB_CACHE", str(tmp_path / "calib.json"))
    pm.reset_calibration()
    pm.reset_observed()
    c0 = pm.get_calibration(measure=False)
    absorbed = pm.observe({"stages": [{
        "node": "remote_farm[2]", "backend": "remote", "tier": "host_remote",
        "fn_key": "tests.fake_remote_fn", "items": 16,
        "svc_cpu_ema_s": 2e-3, "hop_ema_s": 4e-3}]})
    assert absorbed == 2                            # hop fact + cost fact
    c1 = pm.get_calibration(measure=False)
    assert c1.source == "observed"
    assert c1.net_hop_s == pytest.approx(0.75 * c0.net_hop_s + 0.25 * 4e-3)
    assert c1.proc_hop_s == c0.proc_hop_s           # untouched
    rec = pm.lookup_observed("tests.fake_remote_fn")
    assert rec is not None and rec["t_task"] == pytest.approx(2e-3)
    pm.reset_calibration()
    pm.reset_observed()
