"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, asserting output shapes + no NaNs;
plus prefill/decode consistency against the parallel forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get
from repro.models.lm import LM
from repro.optim.schedules import cosine_warmup
from repro.runtime.steps import (init_state, make_decode_step,
                                 make_prefill_step, make_train_step)


def _batch_for(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["embeds"] = 0.1 * jax.random.normal(key, (B, S, cfg.d_model),
                                                  jnp.bfloat16)
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.family == "encdec":
        batch = {"frames": 0.1 * jax.random.normal(
                     key, (B, S, cfg.d_model), jnp.bfloat16),
                 "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_train_step(arch, plan, rng):
    cfg = get(arch).reduced()
    state = init_state(cfg, plan, rng)
    batch = _batch_for(cfg, rng)
    step = jax.jit(make_train_step(cfg, plan, cosine_warmup(1e-3, 5, 50)))
    state2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    assert int(state2["step"]) == 1
    # params updated, shapes preserved, finite
    for p, p2 in zip(jax.tree.leaves(state["params"]),
                     jax.tree.leaves(state2["params"])):
        assert p.shape == p2.shape and p.dtype == p2.dtype
        assert np.all(np.isfinite(np.asarray(p2, np.float32)))


@pytest.mark.parametrize("arch", ["gemma-7b", "mixtral-8x7b", "xlstm-125m",
                                  "zamba2-1.2b", "qwen2-vl-2b"])
def test_arch_smoke_serve(arch, plan, rng):
    cfg = get(arch).reduced()
    params = init_state(cfg, plan, rng)["params"]
    B, S, CL = 2, 16, 32
    batch = _batch_for(cfg, rng, B, S)
    logits, caches = jax.jit(make_prefill_step(cfg, plan, CL))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    decode = jax.jit(make_decode_step(cfg, plan, CL))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    db = {"token": tok, "pos": jnp.asarray(S, jnp.int32)}
    if cfg.family == "vlm":
        db["embeds"] = 0.1 * jax.random.normal(rng, (B, 1, cfg.d_model),
                                               jnp.bfloat16)
        db["mrope_positions"] = jnp.full((3, B, 1), S, jnp.int32)
    nt, lg, caches = decode(params, caches, db)
    assert nt.shape == (B, 1)
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))


def test_prefill_decode_matches_parallel_forward(plan, rng):
    """decode(prefill(t[:S]), t[S]) logits == prefill(t[:S+1]) logits —
    the KV cache path agrees with the parallel path."""
    cfg = get("ff-tiny").reduced()
    params = init_state(cfg, plan, rng)["params"]
    B, S = 2, 12
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab)
    CL = 24
    prefill = jax.jit(make_prefill_step(cfg, plan, CL))
    lg_full, _ = prefill(params, {"tokens": toks})
    lg_pre, caches = prefill(params, {"tokens": toks[:, :S]})
    decode = jax.jit(make_decode_step(cfg, plan, CL))
    _, lg_dec, _ = decode(params, caches,
                          {"token": toks[:, S:S + 1],
                           "pos": jnp.asarray(S, jnp.int32)})
    a = np.asarray(lg_full[:, -1], np.float32)
    b = np.asarray(lg_dec[:, -1], np.float32)
    np.testing.assert_allclose(a, b, rtol=3e-2, atol=3e-2)
    # and the argmax (the actual served token) agrees
    assert np.array_equal(a.argmax(-1), b.argmax(-1))


def test_swa_ring_cache_matches_full_window(plan, rng):
    """SWA ring cache decode == full-cache decode with window mask."""
    import dataclasses
    cfg = get("mixtral-8x7b").reduced()
    cfg = dataclasses.replace(cfg, window=8, attn_kind="swa")
    params = init_state(cfg, plan, rng)["params"]
    B, S = 1, 12
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab)
    # ring cache (cache_len == window)
    lg_pre, caches = jax.jit(make_prefill_step(cfg, plan, S))(
        params, {"tokens": toks[:, :S]})
    decode = jax.jit(make_decode_step(cfg, plan, S))
    _, lg_ring, _ = decode(params, caches,
                           {"token": toks[:, S:S + 1],
                            "pos": jnp.asarray(S, jnp.int32)})
    # oracle: parallel forward over the full prompt
    cfg2 = dataclasses.replace(cfg)
    lg_full, _ = jax.jit(make_prefill_step(cfg2, plan, S + 1))(
        params, {"tokens": toks})
    a = np.asarray(lg_full[:, -1], np.float32)
    b = np.asarray(lg_ring[:, -1], np.float32)
    assert np.array_equal(a.argmax(-1), b.argmax(-1))
    # bf16 cache + different softmax path (streaming vs full): loose bound
    np.testing.assert_allclose(a, b, rtol=0.2, atol=0.2)


def test_moe_block_matches_dense_mixture(plan, rng):
    """With ample capacity, the scatter/dispatch MoE == explicit per-token
    mixture of expert FFNs (the farm's collector is exact)."""
    from repro.models.moe import moe_block, moe_defs, _route
    from repro.models.params import init_params
    import dataclasses
    cfg = get("mixtral-8x7b").reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    defs = moe_defs(cfg, None)
    p = init_params(defs, rng)
    B, S = 2, 16
    x = 0.5 * jax.random.normal(rng, (B, S, cfg.d_model), jnp.float32) \
        .astype(jnp.bfloat16)
    out, aux = jax.jit(lambda x, p: moe_block(x, p, cfg, plan))(x, p)

    # oracle
    x2 = x.reshape(-1, cfg.d_model)
    probs, tw, ti, _ = _route(x2, p["router"], cfg.top_k)
    def ffn(e, t):
        a = t @ p["wi"][e]
        g = jax.nn.silu(t @ p["wg"][e])
        return (a * g) @ p["wo"][e]
    ref = jnp.zeros((x2.shape[0], cfg.d_model), jnp.float32)
    for k in range(cfg.top_k):
        for e in range(cfg.n_experts):
            m = (ti[:, k] == e)[:, None]
            ref = ref + jnp.where(
                m, tw[:, k:k + 1] * ffn(e, x2).astype(jnp.float32), 0.0)
    ref = ref.reshape(B, S, cfg.d_model)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ssm_chunked_equals_sequential(rng):
    """chunked_gla == step-by-step recurrence."""
    from repro.models.ssm import chunked_gla, gla_step
    B, S, H, N, P = 2, 64, 3, 8, 16
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    q = jax.random.normal(k1, (B, S, H, N)) * 0.5
    k = jax.random.normal(k2, (B, S, H, N)) * 0.5
    v = jax.random.normal(k3, (B, S, H, P))
    la = -jnp.abs(jax.random.normal(k4, (B, S, H))) * 0.1
    y, s_fin = chunked_gla(q, k, v, la, chunk=16)
    state = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        state, yt = gla_step(state, q[:, t:t + 1], k[:, t:t + 1],
                             v[:, t:t + 1], la[:, t:t + 1])
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(state),
                               rtol=1e-3, atol=1e-3)


def test_vocab_parallel_ce_matches_naive(plan, rng):
    from repro.models.lm import vocab_parallel_ce
    B, S, d, V = 2, 8, 16, 64
    x = jax.random.normal(rng, (B, S, d), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(rng, 1), (d, V)) * 0.1
    w = w.astype(jnp.bfloat16)
    labels = jax.random.randint(rng, (B, S), 0, V)
    mask = jnp.ones((B, S), jnp.float32)
    loss = vocab_parallel_ce(x, w, labels, mask, plan, chunks=2)
    logits = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    lse = jax.nn.logsumexp(logits, -1)
    ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    ref = jnp.mean(lse - ll)
    np.testing.assert_allclose(float(loss), float(ref), rtol=2e-3)
