"""Building-blocks graph API: construction, optimize() normal-form
invariants (semantics preserved), all-to-all routing, feedback via Deliver,
and host-vs-device lowering parity through the single lower() entry point."""

import numpy as np
import pytest

from repro.core import (Deliver, FF_EOS, FFNode, GO_ON, GraphError,
                        all_to_all, farm, ffmap, pipeline, seq)
from repro.core.graph import FarmG, PipeG, SeqG


class Gen(FFNode):
    def __init__(self, n):
        super().__init__()
        self.i, self.n = 1, n

    def svc(self, _):
        self.i += 1
        return self.i if self.i <= self.n else None


class Sink(FFNode):
    def __init__(self):
        super().__init__()
        self.got = []

    def svc(self, t):
        self.got.append(t)
        return GO_ON


class Sieve(FFNode):
    def __init__(self):
        super().__init__()
        self.f = 0

    def svc(self, t):
        if self.f == 0:
            self.f = t
            return GO_ON
        return GO_ON if t % self.f == 0 else t


# -- construction -------------------------------------------------------------
def test_construction_coerces_blocks():
    g = pipeline(Gen(5), lambda x: x + 1, farm(lambda x: x, n=2))
    assert isinstance(g.root, PipeG)
    s0, s1, s2 = g.root.stages
    assert isinstance(s0, SeqG) and not s0.pure
    assert isinstance(s1, SeqG) and s1.pure
    assert isinstance(s2, FarmG) and len(s2.workers) == 2
    assert "pipe(" in g.describe()


def test_construction_rejects_bad_blocks():
    with pytest.raises(GraphError):
        pipeline()
    with pytest.raises(GraphError):
        farm(lambda x: x)                    # replicated fn needs n
    with pytest.raises(GraphError):
        seq(object())
    with pytest.raises(GraphError):
        farm(Sink(), n=3)                    # stateful node can't replicate
    with pytest.raises(GraphError):
        farm(42)


def test_farm_replicates_pure_seq_worker():
    g = farm(seq(lambda x: x + 1, pure=True), n=4)
    assert sorted(g.lower().run(range(8))) == list(range(1, 9))


def test_offload_after_clean_termination_returns():
    class Once(FFNode):
        def svc(self, t):
            return None                      # terminate on first item

    r = pipeline(Once()).lower(capacity=4)
    r.run_then_freeze()
    r.offload(1)
    assert r.wait(timeout=30) == 0
    for i in range(20):                      # beyond capacity: must not spin
        r.offload(i)


def test_farm_accepts_single_node_worker():
    sink = Sink()
    g = pipeline(Gen(5), farm(sink))
    assert g.lower().run_and_wait_end() == 0
    assert sorted(sink.got) == [2, 3, 4, 5]


def test_seq_pure_override_does_not_alias():
    g1 = seq(lambda x: x)                    # callables default to pure
    g2 = seq(g1, pure=False)                 # downgrade must copy, not alias
    assert g1.root.pure and not g2.root.pure
    with pytest.raises(GraphError):
        seq(pipeline(lambda x: x, lambda x: x), pure=True)
    with pytest.raises(GraphError):
        seq(Sink(), pure=True)               # not callable: lowering would crash


def test_stateful_graphs_are_single_use():
    g = pipeline(Gen(5), Sink())
    assert g.lower().run_and_wait_end() == 0
    with pytest.raises(GraphError):
        g.lower()                            # stale node state must not rerun
    # pure graphs re-lower freely
    p = pipeline(lambda x: x + 1)
    assert p.lower().run([1]) == [2]
    assert p.lower().run([2]) == [3]


def test_crashed_farm_worker_releases_emitter():
    # worker 0 dies instantly; round-robin keeps feeding its lane — the dead
    # node must drain it so the stream completes and the error is reported
    def boom(t):
        raise RuntimeError("worker down")

    g = farm([boom, lambda t: t * 2], lb=None)
    r = g.lower(capacity=4)
    r.run_then_freeze()
    for i in range(60):                      # far beyond lane capacity
        r.offload(i)
    r.offload(FF_EOS)
    got = []
    while True:
        ok, v = r.load_result(timeout=30)
        if not ok:
            break
        got.append(v)
    assert r.wait(timeout=30) == -1
    assert isinstance(r.error(), RuntimeError)
    assert got == [i * 2 for i in range(1, 60, 2)]   # odd items, worker 1


def test_pipeline_batch_run_preserves_order():
    out = pipeline(lambda x: x + 1, lambda x: x * 10).lower().run([1, 2, 3])
    assert out == [20, 30, 40]


def test_source_pipeline_runs_to_completion():
    sink = Sink()
    rc = pipeline(Gen(5), sink).lower().run_and_wait_end()
    assert rc == 0
    assert sink.got == [2, 3, 4, 5]


# -- optimize(): normal form, semantics preserved -----------------------------
def test_optimize_flattens_and_preserves_sieve_semantics():
    def build(optimized):
        stages = [Sieve() for _ in range(7)]
        sink = Sink()
        g = pipeline(Gen(30), pipeline(*stages), sink)
        if optimized:
            g = g.optimize()
        assert g.lower().run_and_wait_end() == 0
        return sorted(s.f for s in stages), sink.got

    primes_ref, survivors_ref = build(optimized=False)
    primes_opt, survivors_opt = build(optimized=True)
    assert primes_opt == primes_ref == [2, 3, 5, 7, 11, 13, 17]
    assert survivors_opt == survivors_ref == [19, 23, 29]


def test_optimize_fuses_adjacent_pure_farms():
    g = pipeline(farm(lambda x: x * 2, n=3), farm(lambda x: x - 1, n=3))
    root = g.optimize().root
    assert isinstance(root, FarmG) and len(root.workers) == 3
    a = sorted(g.lower().run(range(10)))
    b = sorted(g.optimize().lower().run(range(10)))
    assert a == b == sorted(x * 2 - 1 for x in range(10))


def test_optimize_collapses_seq_into_farm_collector_and_emitter():
    g = pipeline(lambda x: x + 1,           # source-position: must survive
                 farm(lambda x: x * 2, n=2),
                 lambda x: x + 100)          # collapses into the collector
    root = g.optimize().root
    assert isinstance(root, PipeG) and len(root.stages) == 2
    assert isinstance(root.stages[1], FarmG)
    assert root.stages[1].collector is not None
    a = sorted(g.lower().run(range(8)))
    b = sorted(g.optimize().lower().run(range(8)))
    assert a == b == sorted((x + 1) * 2 + 100 for x in range(8))


def test_optimize_leaves_stateful_farms_alone():
    g = pipeline(farm([Sieve(), Sieve()]), farm([Sieve(), Sieve()]))
    root = g.optimize().root
    assert isinstance(root, PipeG) and len(root.stages) == 2


# -- all-to-all ---------------------------------------------------------------
def test_all_to_all_routes_by_key():
    seen = [[], [], []]

    class Right(FFNode):
        def __init__(self, j):
            super().__init__()
            self.j = j

        def svc(self, t):
            seen[self.j].append(t)
            return t

    g = all_to_all([lambda x: x * 10, lambda x: x * 10],
                   [Right(j) for j in range(3)],
                   router=lambda item, n: item % n)
    out = g.lower().run(range(12))
    assert sorted(out) == [x * 10 for x in range(12)]
    for j in range(3):
        assert seen[j] and all(v % 3 == j for v in seen[j])


def test_all_to_all_accelerator_mode():
    g = all_to_all([lambda x: x + 1], [lambda x: x, lambda x: x])
    r = g.lower()
    r.run_then_freeze()
    for i in range(6):
        r.offload(i)
    r.offload(FF_EOS)
    got = []
    while True:
        ok, v = r.load_result()
        if not ok:
            break
        got.append(v)
    assert r.wait() == 0
    assert sorted(got) == list(range(1, 7))


# -- feedback -----------------------------------------------------------------
def test_feedback_loop_with_deliver():
    class Halver(FFNode):
        """Divide&conquer: halve evens until odd, deliver odd results.
        Looped items are tagged so in-flight accounting stays exact."""

        def __init__(self):
            super().__init__()
            self.inflight = 0
            self.draining = False

        def svc(self, t):
            if t == "drain":
                self.draining = True
            else:
                if isinstance(t, tuple):          # back from the feedback edge
                    self.inflight -= 1
                    t = t[1]
                if t % 2 == 0:
                    self.inflight += 1
                    return ("loop", t // 2)
                self.ff_send_out(Deliver(t))
            if self.draining and self.inflight == 0:
                return None
            return GO_ON

    g = pipeline(Halver()).wrap_around()
    r = g.lower()
    r.run_then_freeze()
    for x in (40, 12, 7):
        r.offload(x)
    r.offload("drain")
    got = []
    while True:
        ok, v = r.load_result(timeout=10)
        if not ok:
            break
        got.append(v)
    assert r.wait(timeout=10) == 0
    assert sorted(got) == [3, 5, 7]


def test_voluntary_early_stage_termination_releases_producer():
    # second stage returns None (=EOS) on its first item: the generator
    # must not wedge on the full inter-stage queue
    rc = pipeline(Gen(6000), lambda x: None).lower().run_and_wait_end()
    assert rc == 0


def test_self_terminating_collector_releases_workers():
    class TwoThenDone(FFNode):
        def __init__(self):
            super().__init__()
            self.n = 0

        def svc(self, t):
            self.n += 1
            return FF_EOS if self.n > 2 else t

    g = farm([lambda x: x, lambda x: x], collector=TwoThenDone())
    r = g.lower(capacity=4)
    r.run_then_freeze()
    for i in range(100):
        r.offload(i)
    r.offload(FF_EOS)
    got = []
    while True:
        ok, v = r.load_result(timeout=30)
        if not ok:
            break
        got.append(v)
    assert r.wait(timeout=30) == 0
    assert len(got) == 2


def test_collector_svc_init_failure_reports_error():
    class BadInit(FFNode):
        def svc_init(self):
            return -1

        def svc(self, t):
            return t

    g = farm([lambda x: x, lambda x: x], collector=BadInit())
    r = g.lower(capacity=4)
    r.run_then_freeze()
    for i in range(100):
        r.offload(i)
    r.offload(FF_EOS)
    while r.load_result(timeout=30)[0]:
        pass
    assert r.wait(timeout=30) == -1


def test_run_streams_larger_than_all_buffering():
    # offload and collection must overlap: a long stream + unread results
    # previously filled every queue and deadlocked
    out = pipeline(lambda x: x + 1).lower().run(range(10_000))
    assert out == list(range(1, 10_001))


def test_a2a_rejects_composite_workers():
    with pytest.raises(GraphError):
        all_to_all([pipeline(lambda x: x + 1, lambda x: x * 2)],
                   [lambda x: x])
    with pytest.raises(GraphError):
        all_to_all([lambda x: x], [farm(lambda x: x, n=2)])


def test_a2a_crashed_worker_reports_error():
    def boom(t):
        raise RuntimeError("a2a worker down")

    g = all_to_all([lambda x: x], [boom, lambda x: x * 2],
                   router=lambda item, n: item % n)
    r = g.lower(capacity=4)
    r.run_then_freeze()
    for i in range(60):
        r.offload(i)
    r.offload(FF_EOS)
    got = []
    while True:
        ok, v = r.load_result(timeout=30)
        if not ok:
            break
        got.append(v)
    assert r.wait(timeout=30) == -1
    assert isinstance(r.error(), RuntimeError)
    assert got == [i * 2 for i in range(1, 60, 2)]   # surviving worker's lane


def test_drainers_exit_after_clean_wait():
    import threading
    import time as _time

    class OneShot(FFNode):
        def svc(self, t):
            self.ff_send_out(Deliver(t))
            return None                    # voluntary exit leaves a drainer

    r = pipeline(OneShot()).wrap_around().lower()
    r.run_then_freeze()
    r.offload(1)
    ok, v = r.load_result(timeout=30)
    assert ok and v == 1
    assert r.wait(timeout=30) == 0
    deadline = _time.monotonic() + 5
    while _time.monotonic() < deadline:
        if not any(t.name == "ff-drain" and t.is_alive()
                   for t in threading.enumerate()):
            break
        _time.sleep(0.05)
    else:
        raise AssertionError("ff-drain thread leaked after clean wait()")


def test_run_and_wait_end_discards_unconsumed_output():
    # sinks that emit more items than any queue capacity must not wedge a
    # network nobody is draining
    rc = pipeline(Gen(6000), lambda x: x).lower().run_and_wait_end()
    assert rc == 0


def test_nested_wrapped_subgraph_rejected():
    inner = pipeline(lambda x: x).wrap_around()
    with pytest.raises(GraphError):
        pipeline(lambda x: x, inner)


def test_crashed_stage_reports_error_instead_of_hanging():
    class Boom(FFNode):
        def svc(self, t):
            raise RuntimeError("boom")

    r = pipeline(lambda t: t, Boom(), lambda t: t).wrap_around().lower()
    r.run_then_freeze()
    r.offload(1)
    ok, _ = r.load_result(timeout=30)
    assert not ok
    assert r.wait(timeout=30) == -1
    assert isinstance(r.error(), RuntimeError)


def test_wait_unwinds_failure_that_races_past_entry():
    # the stage fails only after wait() has started joining: the polling
    # unwind (not a one-shot entry check) must still terminate the network
    import threading
    gate = threading.Event()

    class SlowBoom(FFNode):
        def svc(self, t):
            gate.wait(10)
            raise RuntimeError("late boom")

    r = pipeline(lambda t: t, SlowBoom(), lambda t: t).wrap_around().lower()
    r.run_then_freeze()
    r.offload(1)
    threading.Timer(0.3, gate.set).start()
    assert r.wait(timeout=30) == -1
    assert isinstance(r.error(), RuntimeError)


def test_a2a_early_worker_termination_drains():
    class EarlyStop(FFNode):
        def __init__(self):
            super().__init__()
            self.n = 0

        def svc(self, t):
            self.n += 1
            return None if self.n > 2 else t

    g = all_to_all([lambda x: x], [EarlyStop()], router=lambda i, n: 0)
    r = g.lower(capacity=4)
    r.run_then_freeze()
    for i in range(50):
        r.offload(i)
    r.offload(FF_EOS)
    got = []
    while True:
        ok, v = r.load_result(timeout=30)
        if not ok:
            break
        got.append(v)
    assert r.wait(timeout=30) == 0
    assert got == [0, 1]


# -- host vs device lowering parity -------------------------------------------
def test_host_device_farm_parity(plan):
    xs = [np.float32(x) for x in range(1, 9)]

    def make():
        return pipeline(farm(lambda x: x * 2.0, n=2), lambda x: x + 0.5)

    host = sorted(float(v) for v in make().lower().run(xs))
    dev = sorted(float(v) for v in make().lower(plan).run(xs))
    opt = sorted(float(v) for v in make().optimize().lower(plan).run(xs))
    assert host == dev == opt == [x * 2.0 + 0.5 for x in range(1, 9)]


def test_host_device_parity_pytree_outputs(plan):
    def make():
        return farm(lambda x: (x, x * 2.0), n=2)

    host = sorted((float(a), float(b)) for a, b in make().lower().run([1.0, 2.0, 3.0]))
    dev = sorted((float(a), float(b)) for a, b in make().lower(plan).run([1.0, 2.0, 3.0]))
    assert host == dev == [(1.0, 2.0), (2.0, 4.0), (3.0, 6.0)]


def test_device_lowering_rejects_heterogeneous_worker_list(plan):
    # SPMD replicates ONE function; silently lowering workers[0] would
    # diverge from the host round-robin over distinct workers
    with pytest.raises(GraphError):
        farm([lambda x: x + 1, lambda x: x * 2]).lower(plan)


def test_device_lowering_rejects_custom_balancer(plan):
    from repro.core import BroadcastLB
    with pytest.raises(GraphError):
        farm(lambda x: x, n=2, lb=BroadcastLB()).lower(plan)
    with pytest.raises(GraphError):
        farm(lambda x: x, n=2, ondemand=1).lower(plan)


def test_device_lowering_rejects_stateful_stage(plan):
    with pytest.raises(GraphError):
        pipeline(Gen(3)).lower(plan)


def test_device_lowering_rejects_feedback(plan):
    with pytest.raises(GraphError):
        pipeline(lambda x: x).wrap_around().lower(plan)


# -- ffmap through lower() -----------------------------------------------------
def test_ffmap_via_graph_lowering():
    class Split(FFNode):
        def svc(self, task):
            for i, row in enumerate(task):
                self.ff_send_out(("row", i, row))
            return None

    class Worker(FFNode):
        def svc(self, t):
            _, i, row = t
            return ("res", i, sum(row))

    class Compose(FFNode):
        def __init__(self, n, out):
            super().__init__()
            self.remaining, self.out = n, out

        def svc(self, t):
            _, i, s = t
            self.out[i] = s
            self.remaining -= 1
            return GO_ON

    out = {}
    rows = [[1, 2], [3, 4], [5, 6]]
    m = ffmap(Split(), [Worker(), Worker()], Compose(len(rows), out)).lower()
    m.run_then_freeze()
    m.offload(rows)
    m.offload(FF_EOS)
    assert m.wait() == 0
    assert out == {0: 3, 1: 7, 2: 11}
