"""The process-backed host tier: three-way placement parity, crash
surfacing, runner stats, and the calibrated cost model (PR 3)."""

import os
import signal
import time

import numpy as np
import pytest

from repro.core import (CostEstimate, FFNode, GraphError, HostRunner,
                        Placement, ProcessFarmNode, ProcessRunner,
                        WorkerCrashed, annotate, farm, perf_model as pm,
                        pipeline)
from repro.core.process import fn_picklable


class Gen(FFNode):
    def __init__(self, n):
        super().__init__()
        self.i, self.n = 0, n

    def svc(self, _):
        self.i += 1
        return np.float32(self.i) if self.i <= self.n else None


def _affine(x):
    return x * 2.0 + 1.0


def _gil_bound(x):
    # interpreter-driven numpy-scalar loop: never releases the GIL
    s = 0.0
    v = float(x)
    for i in range(12_000):
        s += (v * i + 1.1) % 7.3
    return np.float32(s % 1000.0)


def _kill_on_five(x):
    if int(x) == 5:
        os.kill(os.getpid(), signal.SIGKILL)
    return float(x)


# -- three-way parity ----------------------------------------------------------
@pytest.mark.shm
def test_farm_parity_thread_process_device(plan):
    heavy = lambda x: x * 2.0 + 1.0
    heavy.ff_flops = 1e9

    n = 11
    expected = [i * 2.0 + 1.0 for i in range(1, n + 1)]

    host = pipeline(Gen(n), farm(heavy, n=2)).compile(mode="host").run()
    proc = pipeline(Gen(n), farm(heavy, n=2)).compile(mode="process").run()
    if plan is not None:                    # device skipped on CPU-less CI
        dev = pipeline(Gen(n), farm(heavy, n=2)).compile(
            plan, device_batch=4).run()
        # the process farm reorders by sequence number and the device path
        # is batch-ordered: both must match the input order exactly
        assert [float(v) for v in dev] == pytest.approx(expected)
    assert [float(v) for v in proc] == pytest.approx(expected)
    # the thread farm's collector is arrival-ordered: same multiset
    assert sorted(float(v) for v in host) == pytest.approx(expected)


@pytest.mark.shm
def test_pipeline_parity_all_three_backends_exact_order(plan):
    # seq stages are FIFO on every backend -> exact order everywhere
    f1 = lambda x: x + 1.0
    f2 = lambda x: x * 3.0
    f1.ff_flops = 1e9
    f2.ff_flops = 1e9
    xs = [np.float32(i) for i in range(9)]
    expected = [(i + 1.0) * 3.0 for i in range(9)]

    host = pipeline(f1, f2).compile(mode="host").run(xs)
    proc = pipeline(f1, f2).compile(mode="process").run(xs)
    assert [float(v) for v in host] == pytest.approx(expected)
    assert [float(v) for v in proc] == pytest.approx(expected)
    if plan is not None:
        dev = pipeline(f1, f2).compile(plan, mode="device").run(xs)
        assert [float(v) for v in dev] == pytest.approx(expected)


@pytest.mark.shm
def test_a2a_process_mode_lowers_to_process_tier(plan):
    # since the MPMC-grid lowering, mode="process" runs an eligible
    # all_to_all on OS-process workers with identical results
    lefts = [lambda x: x * 10.0, lambda x: x + 1.0]
    rights = [lambda y: y - 1.0, lambda y: y * 2.0]
    xs = [np.float32(i) for i in range(10)]

    from repro.core import all_to_all
    host = sorted(float(v) for v in
                  all_to_all(lefts, rights).compile(mode="host").run(xs))
    r = all_to_all(lefts, rights).compile(mode="process")
    assert isinstance(r, ProcessRunner)
    assert [p.target for _, p in r.placements] == ["host_process"]
    proc = sorted(float(v) for v in r.run(xs, timeout=60.0))
    assert host == proc
    if plan is not None:
        dev = sorted(float(v) for v in all_to_all(lefts, rights).compile(
            plan, mode="device").run(xs))
        assert host == dev


@pytest.mark.shm
def test_process_farm_with_absorbed_emitter_collector():
    # normalize absorbs the pure neighbours into the farm; the process
    # lowering runs them in the parent around the shm hop
    n = 8
    r = pipeline(Gen(n), lambda x: x + 0.5, farm(_affine, n=2),
                 lambda y: y - 1.0).compile(mode="process")
    assert isinstance(r, ProcessRunner)
    out = [float(v) for v in r.run()]
    assert out == pytest.approx(
        [(i + 0.5) * 2.0 + 1.0 - 1.0 for i in range(1, n + 1)])


# -- crash surfacing -----------------------------------------------------------
@pytest.mark.shm
def test_crashed_process_worker_surfaces_error_not_wedge():
    r = pipeline(Gen(10), farm(_kill_on_five, n=2)).compile(mode="process")
    t0 = time.monotonic()
    with pytest.raises(WorkerCrashed):
        r.run(timeout=60.0)
    assert time.monotonic() - t0 < 60.0

    err = r.error()
    assert isinstance(err, WorkerCrashed)
    assert "died" in str(err)


@pytest.mark.shm
def test_long_stream_with_poisoned_item_unwinds_not_wedges():
    """Regression: a stream much longer than the ring capacity must still
    surface the worker error — the farm has to stop feeding and drain the
    survivors instead of spinning on their full lanes forever."""
    def boom(x):
        if int(x) == 3:
            raise ValueError("poisoned item")
        return float(x)

    r = pipeline(Gen(400), farm(boom, n=2)).compile(mode="process")
    t0 = time.monotonic()
    with pytest.raises(WorkerCrashed) as ei:
        r.run(timeout=60.0)
    assert time.monotonic() - t0 < 45.0
    assert "ValueError" in str(ei.value)


@pytest.mark.shm
def test_worker_exception_ships_back_with_traceback():
    def boom(x):
        if int(x) == 3:
            raise ValueError("poisoned item")
        return float(x)

    r = pipeline(Gen(6), farm(boom, n=2)).compile(mode="process")
    with pytest.raises(WorkerCrashed) as ei:
        r.run(timeout=60.0)
    assert "ValueError" in str(ei.value)


# -- placement rules and overrides ---------------------------------------------
def test_host_process_override_on_stateful_farm_rejected():
    class St(FFNode):
        def svc(self, t):
            return t

    with pytest.raises(GraphError):
        pipeline(Gen(3), farm([St()])).compile(
            placements={1: "host_process"})


def test_bad_placement_target_still_rejected():
    with pytest.raises(GraphError):
        pipeline(Gen(3), farm(_affine, n=2)).compile(
            placements={1: Placement(target="gpu")})


@pytest.mark.shm
def test_process_override_by_worker_object():
    n = 6
    r = pipeline(Gen(n), farm(_affine, n=2)).compile(
        placements={_affine: "host_process"})
    assert isinstance(r, ProcessRunner)
    assert [p.target for _, p in r.placements][1] == "host_process"
    out = [float(v) for v in r.run()]
    assert out == pytest.approx([i * 2.0 + 1.0 for i in range(1, n + 1)])


def test_fn_picklable_helper():
    assert fn_picklable(_affine)
    assert fn_picklable(len)


# -- calibration + cost-driven auto choice (acceptance criterion) --------------
@pytest.mark.shm
def test_calibrate_measures_and_caches(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_FF_CALIB_CACHE", str(tmp_path / "calib.json"))
    pm.reset_calibration()
    c = pm.calibrate()
    assert c.source == "measured"
    assert c.peak_flops > 1e8
    assert 0 < c.queue_hop_s < 1e-2
    assert 0 < c.proc_hop_s < 1e-1
    assert 0 < c.device_dispatch_s < 1.0
    # a fresh lookup in the same machine state loads the cached file
    pm.reset_calibration()
    c2 = pm.get_calibration()
    assert c2.source == "cached"
    assert c2.proc_hop_s == pytest.approx(c.proc_hop_s)
    pm.reset_calibration()


def test_calib_cache_path_honors_hermetic_env(tmp_path, monkeypatch):
    """CI hermeticity: REPRO_FF_CALIB_CACHE (exact file) > REPRO_FF_CACHE
    (cache dir, what CI sets per job) > XDG_CACHE_HOME > ~/.cache."""
    import os
    from repro.core.perf_model import _calib_cache_path

    monkeypatch.delenv("REPRO_FF_CALIB_CACHE", raising=False)
    monkeypatch.setenv("REPRO_FF_CACHE", str(tmp_path / "ff"))
    assert _calib_cache_path() == str(tmp_path / "ff" / "calibration.json")
    monkeypatch.setenv("REPRO_FF_CALIB_CACHE", str(tmp_path / "exact.json"))
    assert _calib_cache_path() == str(tmp_path / "exact.json")
    monkeypatch.delenv("REPRO_FF_CALIB_CACHE")
    monkeypatch.delenv("REPRO_FF_CACHE")
    monkeypatch.setenv("XDG_CACHE_HOME", str(tmp_path / "xdg"))
    assert _calib_cache_path() == os.path.join(
        str(tmp_path / "xdg"), "repro_ff", "calibration.json")


@pytest.mark.shm
def test_auto_place_picks_process_for_gil_bound_farm():
    """compile() with no placement overrides must choose host_process for a
    CPU-bound numpy farm, from calibrated (not baked-in) constants."""
    g = pipeline(Gen(4), farm(_gil_bound, n=2))
    r = g.compile(sample=np.float32(1.0))
    farm_placement = [p for d, p in r.placements if "farm" in d][0]
    assert farm_placement.target == "host_process"
    assert "calibrated" in farm_placement.reason
    assert pm.get_calibration().source in ("measured", "cached")
    out = r.run()
    assert len(out) == 4


def test_annotate_gil_probe_flags_bound_worker():
    g = farm(_gil_bound, n=2).optimize()
    annotate(g, sample=np.float32(1.0))
    assert g.root.cost.source == "measured"
    assert g.root.cost.releases_gil is False


def test_declared_ff_releases_gil_wins_over_probe():
    def sleeper(x):
        time.sleep(0.001)
        return x
    sleeper.ff_releases_gil = True

    g = farm(sleeper, n=2).optimize()
    annotate(g, sample=np.float32(1.0))
    assert g.root.cost.releases_gil is True
    # a GIL-releasing farm must NOT be process-placed by the cost model
    c = g.root.cost
    assert isinstance(c, CostEstimate)


# -- runner stats ---------------------------------------------------------------
def test_host_runner_stats_shapes():
    r = pipeline(Gen(12), farm(_affine, n=2)).compile(mode="host")
    r.run()
    s = r.stats()
    assert s["backend"] == "HostRunner"
    g = s["graph"]
    assert g["type"] == "pipeline"
    gen_stats = g["stages"][0]
    assert gen_stats["items"] == 13          # 12 items + the terminating call
    assert gen_stats["svc_time_ema_s"] >= 0.0
    farm_stats = g["stages"][1]
    assert farm_stats["type"] == "farm"
    assert sum(w["items"] for w in farm_stats["workers"]) == 12
    assert len(farm_stats["lane_max_depth"]) == 2
    assert max(farm_stats["lane_max_depth"]) >= 1


@pytest.mark.shm
def test_process_runner_stats_include_worker_routing():
    r = pipeline(Gen(10), farm(_affine, n=2)).compile(mode="process")
    r.run()
    s = r.stats()
    assert s["backend"] == "ProcessRunner"
    node = [st for st in s["graph"]["stages"]
            if st.get("backend") == "process"][0]
    assert node["items"] == 10 and node["delivered"] == 10
    assert sum(node["routed_per_worker"]) == 10
    assert node["max_lane_depth"] >= 1


@pytest.mark.shm
def test_process_workers_ship_cpu_time_stats_over_result_lanes():
    """Satellite of the distributed tier: process workers clock their own
    svc CPU time (time.thread_time) and ship WorkerStats records back over
    the result lanes, so node_stats carries a true (GIL-free) per-item
    service time the Supervisor's process->thread policy can compare."""
    r = pipeline(Gen(40), farm(_gil_bound, n=2)).compile(mode="process")
    r.run(timeout=120.0)
    node = [st for st in r.stats()["graph"]["stages"]
            if st.get("backend") == "process"][0]
    # _gil_bound burns ~ms of real CPU per item: the folded worker-side
    # EMA must be positive and plausibly bounded by the wall clock
    assert node["svc_cpu_ema_s"] > 0.0
    assert node["svc_cpu_ema_s"] < 1.0


def test_device_runner_stats(plan):
    f = lambda x: x * 2.0
    f.ff_flops = 1e9
    r = pipeline(f).compile(plan, mode="device")
    r.run([np.float32(i) for i in range(6)])
    s = r.stats()
    assert s["backend"] == "DeviceRunner"
    assert s["items"] == 6 and s["batches"] == 1


@pytest.mark.shm
def test_shutdown_releases_abandoned_process_runner():
    r = pipeline(farm(_affine, n=2)).compile(mode="process")
    r.run_then_freeze()
    r.offload(np.float32(1.0))
    r.shutdown(timeout=30.0)
    # the farm stage wound down: workers exited and segments were unlinked
    nodes = [s for s in r._skel._stages
             if isinstance(s, ProcessFarmNode)]
    assert nodes and nodes[0]._destroyed
    assert all(not p.is_alive() for p in nodes[0]._procs)


def test_shutdown_releases_abandoned_hybrid_runner(plan):
    """Satellite of the overlapped boundary: shutting down a mid-stream
    HybridRunner must drain (then discard) every in-flight device
    microbatch and join the boundary thread — dispatched async work is
    awaited, never leaked, and the boundary never wedges pushing results
    at the dead results queue."""
    from repro.core.compiler import HybridRunner, _DeviceStageNode
    f = lambda x: x * 2.0
    f.ff_flops = 1e9
    r = pipeline(lambda x: float(x) + 1.0, f).compile(
        plan, device_batch=2, inflight=4, normalize=False,
        placements={0: "host", 1: "device"})
    assert isinstance(r, HybridRunner)
    r.run_then_freeze()
    for i in range(9):                   # several microbatches go in flight
        r.offload(np.float32(i))
    r.shutdown(timeout=30.0)
    node = [s for s in r._skel._stages
            if isinstance(s, _DeviceStageNode)][0]
    assert node._abandoned
    assert not node._window              # in-flight window fully drained
    assert not node._buf                 # partial microbatch dropped
    assert not node._alive()             # boundary thread joined


# -- autoscaling process farms ---------------------------------------------------
@pytest.mark.shm
def test_autoscale_process_farm_scales_active_set_without_forking():
    """mode="process" on an autoscale farm lowers to a ProcessFarmNode
    driving an AutoscaleLB over the shm lanes: the full worker set forks
    once at build time, routing starts at one active worker, and depth
    pressure grows the active set (never the process count)."""
    n = 120
    r = pipeline(Gen(n), farm(_gil_bound, n=2, autoscale=True)).compile(
        mode="process", capacity=8)
    assert isinstance(r, ProcessRunner)
    node = [s for s in r._skel._stages if isinstance(s, ProcessFarmNode)][0]
    procs_before = list(node._procs)
    out = [float(v) for v in r.run(timeout=120.0)]
    # order preserved (seq reorder) even while the active boundary moves
    assert out == pytest.approx([float(_gil_bound(np.float32(i)))
                                 for i in range(1, n + 1)])
    st = node.node_stats()["autoscale"]
    assert st["grown"] >= 1                  # a 1-wide start under pressure
    assert node._procs == procs_before       # scaled by routing, not forking
    assert sum(node.node_stats()["routed_per_worker"]) == n


@pytest.mark.shm
def test_auto_place_sends_gil_bound_autoscale_farm_to_process_tier():
    g = pipeline(Gen(4), farm(_gil_bound, n=2, autoscale=True))
    r = g.compile(sample=np.float32(1.0))
    p = [p for d, p in r.placements if "farm" in d][0]
    assert p.target == "host_process"
    assert "autoscale" in p.reason
    out = r.run(timeout=60.0)
    assert len(out) == 4


def test_autoscale_farm_with_unknown_gil_signal_stays_on_threads():
    # no sample, no declaration: the process tier is unreachable on an
    # unknown GIL signal — autoscale keeps scaling threads
    r = pipeline(Gen(4), farm(_affine, n=2, autoscale=True)).compile()
    p = [p for d, p in r.placements if "farm" in d][0]
    assert p.target == "host" and "autoscale" in p.reason
    assert len(r.run(timeout=60.0)) == 4


# -- data pipeline: process-placed augment farm ---------------------------------
def _augment(batch):
    return {k: v * 2 for k, v in batch.items()}


@pytest.mark.shm
def test_data_pipeline_process_farm_keeps_order():
    from repro.data import SyntheticLMSource, make_pipeline

    ref_src = SyntheticLMSource(64, 16, 4, seed=0)
    expected = [ref_src.next_batch() for _ in range(5)]

    src = SyntheticLMSource(64, 16, 4, seed=0)
    pipe = make_pipeline(src, None, n_batches=5, compute=_augment,
                         compute_workers=2)
    assert any(p.target == "host_process" for _, p in pipe.placements)
    for i in range(5):
        batch = pipe.get(timeout=60.0)
        assert batch is not None
        for k, v in batch.items():
            np.testing.assert_array_equal(np.asarray(v),
                                          expected[i][k] * 2)
    assert pipe.get(timeout=60.0) is None       # end of stream
    assert pipe.stats()["backend"] == "ProcessRunner"
