"""Staged graph compiler (normalize -> annotate -> place -> emit): cost-model
sanity, cost-driven hybrid placement, device lowerings for all_to_all (MoE
dispatch/combine) and wrap_around (feedback_scan) with host parity, farm
width selection, and autoscaling host farms."""

import time

import numpy as np
import pytest

from repro.core import (Deliver, FF_EOS, FFNode, GO_ON, GraphError,
                        all_to_all, farm, pipeline)
from repro.core import perf_model as pm
from repro.core.compiler import (CostEstimate, HybridRunner, Placement,
                                 annotate, place)
from repro.core.graph import FarmG
from repro.core.skeletons import AutoscaleLB


class Gen(FFNode):
    def __init__(self, n):
        super().__init__()
        self.i, self.n = 0, n

    def svc(self, _):
        self.i += 1
        return np.float32(self.i) if self.i <= self.n else None


# -- annotate: the cost model ---------------------------------------------------
def test_annotate_measures_and_reads_declarations():
    def slow(x):
        time.sleep(0.002)
        return x

    def declared(x):
        return x
    declared.ff_cost = 0.5
    declared.ff_flops = 1e9

    g = pipeline(slow, farm(declared, n=2)).optimize()
    annotate(g, sample=np.float32(1.0))
    s_slow, s_farm = g.root.stages
    assert s_slow.cost.source == "measured"
    assert 0.0015 < s_slow.cost.t_task < 0.05
    assert s_farm.cost.source == "declared"
    assert s_farm.cost.t_task == 0.5 and s_farm.cost.flops == 1e9


def test_annotate_estimate_matches_measured_farm_time():
    """The paper's Sec. 13 algebra, fed by annotate's measured t_task, must
    predict the HostRunner farm completion time within a loose factor
    (sleep releases the GIL, so workers genuinely overlap)."""
    def slow(x):
        time.sleep(0.002)
        return x

    m, nw = 24, 4
    g = farm(slow, n=nw).optimize()
    annotate(g, sample=np.float32(0.0))
    t_task = g.root.cost.t_task
    predicted = pm.farm_time(m, t_task, nw)

    t0 = time.perf_counter()
    out = farm(slow, n=nw).lower().run([np.float32(i) for i in range(m)])
    measured = time.perf_counter() - t0
    assert len(out) == m
    assert predicted / 5 < measured < predicted * 5, (predicted, measured)


def test_costs_dict_overrides_declarations():
    fn = lambda x: x
    g = farm(fn, n=2).optimize()
    annotate(g, costs={fn: 0.125})
    assert g.root.cost.t_task == 0.125 and g.root.cost.source == "given"


# -- place: cost-driven placement and width selection --------------------------
def test_place_chooses_farm_width_from_cost_model(plan):
    g = farm(lambda x: x, n="auto").optimize()
    g.root.cost = CostEstimate(t_task=1e-4, source="given")
    place(g, plan)
    p = g.root.placement
    assert p.target == "host"
    assert p.width == pm.choose_farm_width(1e-4, __import__("os").cpu_count())
    assert 1 <= p.width <= (__import__("os").cpu_count() or 1)


def test_place_prefers_device_for_declared_flops(plan):
    heavy = lambda x: x * 2.0
    heavy.ff_flops = 1e9
    g = pipeline(Gen(4), farm(heavy, n=2)).optimize()
    annotate(g)
    place(g, plan)
    src, f = g.root.stages
    assert src.placement.target == "host"       # stateful: host-only
    assert f.placement.target == "device"
    assert "roofline" in f.placement.reason


def test_place_overrides_pin_stages(plan):
    heavy = lambda x: x * 2.0
    heavy.ff_flops = 1e9
    g = pipeline(Gen(4), farm(heavy, n=2)).optimize()
    annotate(g)
    place(g, plan, overrides={1: "host"})
    assert g.root.stages[1].placement.target == "host"
    place(g, plan, overrides={heavy: Placement("host", width=2)})
    assert g.root.stages[1].placement.target == "host"
    assert g.root.stages[1].placement.width == 2


# -- emit: the hybrid runner (acceptance criterion) ----------------------------
def test_hybrid_compile_mixes_host_and_device_stages(plan):
    heavy = lambda x: x * 2.0 + 1.0
    heavy.ff_flops = 1e9

    n = 13                                    # not a multiple of the batch
    r = pipeline(Gen(n), farm(heavy, n=2)).compile(plan, device_batch=4)
    assert isinstance(r, HybridRunner)
    targets = [p.target for _, p in r.placements]
    assert "host" in targets and "device" in targets
    out = sorted(float(v) for v in r.run())
    assert out == [i * 2.0 + 1.0 for i in range(1, n + 1)]
    assert r.describe_placements()


def test_hybrid_parity_with_all_host(plan):
    heavy = lambda x: x * 3.0 - 1.0
    heavy.ff_flops = 1e9

    def build():
        return pipeline(Gen(10), farm(heavy, n=2), lambda x: x + 0.5)

    hybrid = sorted(float(v) for v in build().compile(plan).run())
    host = sorted(float(v) for v in build().compile(plan, mode="host").run())
    assert hybrid == host == [i * 3.0 - 0.5 for i in range(1, 11)]


def test_device_stage_error_is_reported_not_hung(plan):
    bad = lambda x: x @ x                     # 0-d matmul: traces then dies
    bad.ff_flops = 1e9
    r = pipeline(Gen(3), farm(bad, n=2)).compile(plan, device_batch=2)
    assert [p.target for _, p in r.placements][1] == "device"
    with pytest.raises(BaseException):
        r.run()


# -- device all_to_all: MoE-style dispatch/combine -----------------------------
def test_a2a_device_parity_default_router(plan):
    lefts = [lambda x: x * 10.0, lambda x: x + 1.0]
    rights = [lambda y: y - 1.0, lambda y: y * 2.0, lambda y: y + 3.0]
    xs = [np.float32(i) for i in range(12)]

    host = sorted(float(v) for v in
                  all_to_all(lefts, rights).compile(mode="host").run(xs))
    dev = sorted(float(v) for v in
                 all_to_all(lefts, rights).compile(plan, mode="device").run(xs))
    assert host == dev


def test_a2a_device_parity_custom_router(plan):
    import jax.numpy as jnp
    lefts = [lambda x: x * 2.0]
    rights = [lambda y: y + 100.0, lambda y: y - 100.0]
    router = lambda y, n: jnp.asarray(y, jnp.int32) % n   # traceable AND host-usable
    xs = [np.float32(i) for i in range(10)]

    host = sorted(float(v) for v in
                  all_to_all(lefts, rights, router).compile(mode="host").run(xs))
    dev = sorted(float(v) for v in
                 all_to_all(lefts, rights, router).compile(plan, mode="device").run(xs))
    assert host == dev


def test_a2a_in_pipeline_compiles_to_device(plan):
    rights = [lambda y: y * 2.0, lambda y: y + 7.0]
    xs = [np.float32(i) for i in range(8)]

    def build():
        return pipeline(lambda x: x + 1.0,
                        all_to_all([lambda x: x * 10.0], rights))
    host = sorted(float(v) for v in build().compile(mode="host").run(xs))
    dev = sorted(float(v) for v in
                 build().compile(plan, mode="device").run(xs))
    assert host == dev


def test_a2a_device_rejects_stateful_workers(plan):
    class St(FFNode):
        def svc(self, t):
            return t

    with pytest.raises(GraphError):
        all_to_all([St()], [lambda x: x]).compile(plan, mode="device")


# -- device wrap_around: feedback_scan -----------------------------------------
def test_feedback_device_parity_with_host_loop(plan):
    K = 4

    def f(x):
        return x * 0.5 + 1.0

    class KLoop(FFNode):
        """Host comparator: each item circles the feedback edge K times,
        then escapes via Deliver; terminates once the drain marker arrives
        and nothing is in flight."""

        def __init__(self):
            super().__init__()
            self.inflight = 0
            self.draining = False

        def svc(self, t):
            if t == "drain":
                self.draining = True
            else:
                if isinstance(t, tuple):
                    self.inflight -= 1
                    x, k = t
                else:
                    x, k = t, 0
                x, k = f(x), k + 1
                if k < K:
                    self.inflight += 1
                    self.ff_send_out((x, k))
                else:
                    self.ff_send_out(Deliver(x))
            if self.draining and self.inflight == 0:
                return None
            return GO_ON

    xs = [np.float32(8.0), np.float32(16.0), np.float32(-4.0)]
    r = pipeline(KLoop()).wrap_around().lower()
    r.run_then_freeze()
    for x in xs:
        r.offload(x)
    r.offload("drain")
    host = []
    while True:
        ok, v = r.load_result(timeout=30)
        if not ok:
            break
        host.append(float(v))
    assert r.wait(timeout=30) == 0

    dev_r = pipeline(f).wrap_around().compile(plan, feedback_steps=K)
    assert all(p.target == "device" for _, p in dev_r.placements)
    dev = [float(v) for v in dev_r.run(xs)]
    assert sorted(host) == pytest.approx(sorted(dev))


def test_feedback_device_needs_step_count(plan):
    # without feedback_steps the loop cannot lower to the mesh: auto mode
    # falls back to host; forced device mode refuses
    r = pipeline(lambda x: x).wrap_around().compile(plan)
    assert all(p.target == "host" for _, p in r.placements)
    with pytest.raises(GraphError):
        pipeline(lambda x: x).wrap_around().compile(plan, mode="device")


# -- autoscaling host farms ----------------------------------------------------
def test_autoscale_lb_grows_on_depth_and_shrinks_when_idle():
    from repro.core.queues import SPMCQueue
    lb = AutoscaleLB(max_workers=4, hi=1.0, lo=0.25, adjust_every=4)
    lanes = SPMCQueue(4, 64)
    lb._attach(lanes)
    assert lb.cur == 1
    for i in range(24):                     # nobody drains: depth builds up
        lb.route(i)
    assert lb.cur > 1 and lb.grown >= 1
    grown_to = lb.cur
    for lane in lanes.lanes:                # consumers catch up
        while lane.try_pop()[0]:
            pass
    for i in range(64):                     # keep lanes empty while routing
        lb.route(i)
        for lane in lanes.lanes:
            lane.try_pop()
    assert lb.shrunk >= 1 and lb.cur < grown_to


def test_autoscale_farm_end_to_end():
    g = farm(lambda x: x + 1, n=3, autoscale=True)
    assert isinstance(g.root, FarmG) and g.root.autoscale
    r = g.lower(capacity=8)
    out = sorted(r.run(range(40)))
    assert out == list(range(1, 41))


def test_autoscale_farm_defaults_to_cpu_count_bound():
    import os
    g = farm(lambda x: x * 2, autoscale=True)     # n omitted -> n_auto
    assert g.root.n_auto
    r = g.lower(capacity=8)
    skel = r._skel
    # lowered as a Farm of cpu_count parked workers behind an AutoscaleLB
    from repro.core.skeletons import Farm as HostFarm
    f = skel._stages[0] if not isinstance(skel, HostFarm) else skel
    assert isinstance(f.getlb(), AutoscaleLB)
    assert len(f._workers) == max(1, os.cpu_count() or 1)
    out = sorted(r.run(range(20)))
    assert out == [x * 2 for x in range(20)]


def test_autoscale_rejects_stateful_and_custom_lb():
    from repro.core import BroadcastLB

    class St(FFNode):
        def svc(self, t):
            return t

    with pytest.raises(GraphError):
        farm([St()], autoscale=True)
    with pytest.raises(GraphError):
        farm(lambda x: x, n=2, autoscale=True, lb=BroadcastLB())
    with pytest.raises(GraphError):
        farm(lambda x: x, n=2, autoscale=True, ondemand=1)


def test_bad_placement_target_rejected(plan):
    with pytest.raises(GraphError):
        pipeline(Gen(2), lambda x: x).compile(
            plan, placements={0: Placement(target="tpu")})


def test_autoscale_farm_stays_host_even_with_flops(plan):
    heavy = lambda x: x * 2.0
    heavy.ff_flops = 1e9
    r = pipeline(Gen(4), farm(heavy, n=2, autoscale=True)).compile(plan)
    p = dict(r.placements)[
        [d for d, _ in r.placements if "farm" in d][0]]
    assert p.target == "host" and "autoscale" in p.reason
    assert sorted(float(v) for v in r.run()) == [i * 2.0 for i in range(1, 5)]


def test_device_mode_without_plan_is_a_graph_error():
    with pytest.raises(GraphError):
        pipeline(lambda x: x).compile(mode="device")


def test_a2a_capacity_factor_bounds_lanes(plan):
    import jax.numpy as jnp
    # everything routes to expert 0; a tight capacity drops the overflow
    # (T=32, nR=2, factor=0.5 -> expert_capacity=8 slots < 32 arrivals)
    T = 32
    router = lambda y, n: jnp.int32(0)
    xs = [np.float32(i + 1) for i in range(T)]
    lossless = all_to_all([lambda x: x], [lambda y: y * 2.0, lambda y: y],
                          router=router).compile(plan, mode="device").run(xs)
    assert sorted(float(v) for v in lossless) == \
        [2.0 * (i + 1) for i in range(T)]
    bounded = all_to_all([lambda x: x], [lambda y: y * 2.0, lambda y: y],
                         router=router).compile(
        plan, mode="device", a2a_capacity_factor=0.5).run(xs)
    kept = [float(v) for v in bounded if float(v) != 0.0]
    assert len(bounded) == T and 0 < len(kept) < T    # overflow -> zeros
    assert kept == [2.0 * (i + 1) for i in range(len(kept))]  # FCFS lanes


def test_fusion_never_drops_auto_width():
    class St(FFNode):
        def svc(self, t):
            return t

    # auto farm followed by an explicit single-worker farm: the composed fn
    # is unavailable, so fusion must be skipped rather than pin width to 1
    g = pipeline(farm(lambda x: x + 1, n="auto"),
                 farm([lambda x: x * 2])).optimize()
    stages = g.root.stages
    assert len(stages) == 2 and stages[0].n_auto
    # two auto farms DO fuse, and the fused farm stays auto
    g2 = pipeline(farm(lambda x: x + 1, n="auto"),
                  farm(lambda x: x * 2, n="auto")).optimize()
    assert isinstance(g2.root, FarmG) and g2.root.n_auto
    assert sorted(g2.root.fn(x) for x in range(5)) == \
        [(x + 1) * 2 for x in range(5)]


# -- a2a hardening: dead left worker never wedges the producer -----------------
def test_a2a_crashed_left_worker_releases_producer():
    def boom(t):
        raise RuntimeError("left worker down")

    g = all_to_all([boom, lambda x: x * 2], [lambda x: x])
    r = g.lower(capacity=4)
    r.run_then_freeze()
    for i in range(60):                     # far beyond every lane capacity
        r.offload(i)
    r.offload(FF_EOS)
    got = []
    while True:
        ok, v = r.load_result(timeout=30)
        if not ok:
            break
        got.append(v)
    assert r.wait(timeout=30) == -1
    assert isinstance(r.error(), RuntimeError)
    assert got == [i * 2 for i in range(1, 60, 2)]   # surviving left worker


# -- data-dependent feedback: feedback_cond / feedback_while --------------------
def test_feedback_cond_host_device_parity(plan):
    """The same ``feedback_cond=`` predicate drives the host re-entry path
    and the device ``feedback_while`` lowering to identical values; host
    wrap output is arrival-ordered, so compare sorted."""

    def f(x):
        return x * np.float32(0.5)

    def still_big(x):
        return x > 1.0

    xs = [np.float32(5.0), np.float32(1.5), np.float32(40.0)]

    host_r = pipeline(f).wrap_around().compile(feedback_cond=still_big)
    host = [float(v) for v in host_r.run(xs)]

    dev_r = pipeline(f).wrap_around().compile(
        plan, feedback_cond=still_big, feedback_steps=64)
    assert all(p.target == "device" for _, p in dev_r.placements)
    dev = [float(v) for v in dev_r.run(xs)]

    assert sorted(host) == pytest.approx(sorted(dev))
    # the exit was data-dependent, not the 64-step cap: every lane stopped
    # as soon as it crossed 1.0 (running to the cap would leave ~1e-18)
    assert all(0.5 < v <= 1.0 for v in dev)


def test_feedback_cond_alone_lowers_to_device(plan):
    # a data-dependent predicate needs no step bound to reach the mesh
    r = pipeline(lambda x: x * np.float32(0.25)).wrap_around().compile(
        plan, feedback_cond=lambda x: x > 1.0)
    assert all(p.target == "device" for _, p in r.placements)
    out = sorted(float(v) for v in r.run([np.float32(8.0),
                                          np.float32(2.0)]))
    assert out == pytest.approx([0.5, 0.5])


def test_feedback_while_counts_steps_and_respects_cap():
    import jax.numpy as jnp
    from repro.core.device import feedback_while

    step = lambda s: (s * 0.5, 0.0)
    final, n = feedback_while(step, jnp.float32(8.0), lambda s: s > 1.0)
    assert float(final) == pytest.approx(1.0) and int(n) == 3
    # do-while: the body always runs at least once
    final, n = feedback_while(step, jnp.float32(0.25), lambda s: s > 1.0)
    assert float(final) == pytest.approx(0.125) and int(n) == 1
    # the cap wins when the predicate would keep going
    final, n = feedback_while(step, jnp.float32(1e9), lambda s: s > 1.0,
                              max_steps=3)
    assert int(n) == 3


# -- CompileConfig: the consolidated compile surface ----------------------------
def test_compile_config_equivalent_to_legacy_kwargs():
    from repro.core import CompileConfig
    xs = [np.float32(i) for i in range(6)]

    def tw(x):
        return x * np.float32(2.0)

    with pytest.warns(DeprecationWarning) as rec:
        old = pipeline(tw).compile(capacity=8).run(xs)
    assert len([w for w in rec if w.category is DeprecationWarning]) == 1

    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        new = pipeline(tw).compile(
            config=CompileConfig(capacity=8)).run(xs)
        bare = pipeline(tw).compile().run(xs)  # no kwargs: no warning
    assert [float(v) for v in old] == [float(v) for v in new]
    assert [float(v) for v in bare] == [float(v) for v in new]


def test_compile_config_rejects_mixing_and_unknown_knobs():
    from repro.core import CompileConfig
    g = pipeline(lambda x: x)
    with pytest.raises(TypeError):
        g.compile(capcity=8)  # typo'd knob: loud, not silently ignored
    with pytest.raises(GraphError):
        g.compile(config=CompileConfig(), capacity=8)
    with pytest.raises(GraphError):
        g.compile("not-none-plan", config=CompileConfig())
