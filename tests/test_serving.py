"""Serving engine: accelerator-mode API, continuous batching, greedy-decode
equivalence with a manual loop."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import FF_EOS
from repro.runtime.steps import (init_state, make_decode_step,
                                 make_prefill_step)
from repro.serving import InferenceEngine, Overloaded, Request

pytestmark = pytest.mark.serving


@pytest.fixture(scope="module")
def served(plan_module=None):
    from repro.core.plan import single_device_plan
    plan = single_device_plan()
    cfg = get("ff-tiny").reduced()
    params = init_state(cfg, plan, jax.random.PRNGKey(0))["params"]
    return cfg, plan, params


def _manual_greedy(cfg, plan, params, prompt, n_new, cache_len=64):
    prefill = jax.jit(make_prefill_step(cfg, plan, cache_len))
    decode = jax.jit(make_decode_step(cfg, plan, cache_len))
    logits, caches = prefill(params, {"tokens": prompt[None]})
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    pos = prompt.shape[0]
    for i in range(n_new - 1):
        tok, _, caches = decode(params, caches,
                                {"token": tok,
                                 "pos": jnp.asarray(pos + i, jnp.int32)})
        out.append(int(tok[0, 0]))
    return out


def test_engine_generates_and_matches_manual_loop(served):
    cfg, plan, params = served
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 8, dtype=np.int32)
    want = _manual_greedy(cfg, plan, params, jnp.asarray(prompt), 6)

    eng = InferenceEngine(cfg, plan, params, max_batch=2, cache_len=64)
    eng.run_then_freeze()
    eng.offload(Request(prompt=prompt, max_new_tokens=6, id=0))
    eng.offload(FF_EOS)
    ok, req = eng.load_result()
    assert ok and req.done
    assert eng.wait() == 0
    assert req.tokens == want


def test_engine_continuous_batching_many_requests(served):
    cfg, plan, params = served
    rng = np.random.default_rng(1)
    eng = InferenceEngine(cfg, plan, params, max_batch=3, cache_len=64)
    eng.run_then_freeze()
    N = 7
    for i in range(N):
        eng.offload(Request(prompt=rng.integers(0, cfg.vocab, 8,
                                                dtype=np.int32),
                            max_new_tokens=4 + (i % 3), id=i))
    eng.offload(FF_EOS)
    done = []
    while True:
        ok, req = eng.load_result()
        if not ok:
            break
        done.append(req)
    assert eng.wait() == 0
    assert sorted(r.id for r in done) == list(range(N))
    for r in done:
        assert len(r.tokens) == r.max_new_tokens
    # batched slots: fewer decode steps than sequential sum of lengths
    assert eng.steps < sum(r.max_new_tokens for r in done)


def test_engine_results_independent_of_batching(served):
    """Each request's tokens are the same whether served alone or packed
    with others (slot isolation)."""
    cfg, plan, params = served
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, 8, dtype=np.int32)
               for _ in range(3)]
    solo = [_manual_greedy(cfg, plan, params, jnp.asarray(p), 5)
            for p in prompts]
    eng = InferenceEngine(cfg, plan, params, max_batch=3, cache_len=64)
    eng.run_then_freeze()
    for i, p in enumerate(prompts):
        eng.offload(Request(prompt=p, max_new_tokens=5, id=i))
    eng.offload(FF_EOS)
    got = {}
    while True:
        ok, req = eng.load_result()
        if not ok:
            break
        got[req.id] = req.tokens
    eng.wait()
    for i in range(3):
        assert got[i] == solo[i], i


# -- typed client API ----------------------------------------------------------
def test_submit_handle_matches_compat_api(served):
    """submit()/result() produce the same greedy tokens as the paper's
    offload/load_result surface and the manual loop."""
    cfg, plan, params = served
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 8, dtype=np.int32)
    want = _manual_greedy(cfg, plan, params, jnp.asarray(prompt), 5)
    with InferenceEngine(cfg, plan, params, max_batch=2,
                         cache_len=64) as eng:
        h = eng.submit(Request(prompt=prompt, max_new_tokens=5))
        assert not h.done() or h.result(0) is not None
        out = h.result(timeout=120)
    assert isinstance(out, Request) and out.done
    assert out.finish_reason == "max_tokens"
    assert out.tokens == want


def test_results_iterator_and_context_manager(served):
    cfg, plan, params = served
    rng = np.random.default_rng(4)
    with InferenceEngine(cfg, plan, params, max_batch=2,
                         cache_len=64) as eng:
        ids = [eng.submit(Request(
            prompt=rng.integers(0, cfg.vocab, 6, dtype=np.int32),
            max_new_tokens=3)).request.id for _ in range(4)]
    # __exit__ drained the engine; results() replays every outcome
    got = {r.id: r for r in eng.results()}
    assert sorted(got) == sorted(ids)
    assert all(len(r.tokens) == 3 for r in got.values())
    # the iterator stays ended on re-iteration
    assert list(eng.results()) == []


def test_continuous_batching_refills_slots_from_ready_queue(served):
    """More requests than slots: the CacheManager refills freed slots
    mid-flight (continuous batching), so every request finishes and the
    cache sees as many inserts+evicts as requests."""
    cfg, plan, params = served
    rng = np.random.default_rng(5)
    N, B = 7, 2
    with InferenceEngine(cfg, plan, params, max_batch=B,
                         cache_len=64) as eng:
        hs = [eng.submit(Request(
            prompt=rng.integers(0, cfg.vocab, 6, dtype=np.int32),
            max_new_tokens=3 + (i % 2))) for i in range(N)]
        outs = [h.result(timeout=180) for h in hs]
    assert all(isinstance(o, Request) and o.done for o in outs)
    cm = eng._cm
    assert cm.inserts == N and cm.evicts == N
    assert len(cm.free) == B and not cm.active
    # batched decode: far fewer ticks than sequential service would take
    assert eng.steps < sum(o.max_new_tokens for o in outs)


# -- SLO policies --------------------------------------------------------------
def test_shed_under_overload_returns_typed_overloaded(served):
    """A burst far past max_pending sheds with a typed Overloaded instead
    of queueing unboundedly; the engine still drains cleanly."""
    from repro.core.runtime import SLOPolicy
    cfg, plan, params = served
    rng = np.random.default_rng(6)
    N = 12
    with InferenceEngine(cfg, plan, params, max_batch=1, cache_len=64,
                         max_pending=2,
                         slo=SLOPolicy(degrade_at=0.5, shed_at=0.9)) as eng:
        hs = [eng.submit(Request(
            prompt=rng.integers(0, cfg.vocab, 6, dtype=np.int32),
            max_new_tokens=6)) for _ in range(N)]
        outs = [h.result(timeout=180) for h in hs]
    shed = [o for o in outs if isinstance(o, Overloaded)]
    done = [o for o in outs if isinstance(o, Request)]
    assert shed and done and len(shed) + len(done) == N
    assert eng.shed_count == len(shed)
    assert all("overloaded" in o.reason or "deadline" in o.reason
               for o in shed)
    # the ledger balances: nothing is silently dropped or still in flight
    assert eng._acct.in_flight() == 0


def test_degrade_caps_tokens_under_pressure(served):
    """At pressure level 1 (backlog past degrade_at) admission caps
    max_new_tokens and flags the request degraded."""
    from repro.core.runtime import SLOPolicy
    cfg, plan, params = served
    rng = np.random.default_rng(7)
    pol = SLOPolicy(degrade_at=0.25, shed_at=0.95, degrade_tokens=2)
    with InferenceEngine(cfg, plan, params, max_batch=1, cache_len=64,
                         max_pending=8, slo=pol) as eng:
        hs = [eng.submit(Request(
            prompt=rng.integers(0, cfg.vocab, 6, dtype=np.int32),
            max_new_tokens=40)) for _ in range(6)]
        outs = [h.result(timeout=180) for h in hs]
    done = [o for o in outs if isinstance(o, Request)]
    degraded = [o for o in done if o.degraded]
    assert degraded, "backlog never crossed degrade_at"
    assert all(len(o.tokens) <= pol.degrade_tokens for o in degraded)


def test_deadline_truncates_admitted_request(served):
    cfg, plan, params = served
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab, 6, dtype=np.int32)
    with InferenceEngine(cfg, plan, params, max_batch=2,
                         cache_len=64) as eng:
        # warm the jits so the deadline budget is spent decoding
        eng.submit(Request(prompt=prompt, max_new_tokens=2)).result(300)
        h = eng.submit(Request(prompt=prompt, max_new_tokens=5000,
                               deadline_s=0.25))
        out = h.result(timeout=120)
    assert isinstance(out, Request)
    assert out.finish_reason == "deadline"
    assert 0 < len(out.tokens) < 5000


# -- early exit ----------------------------------------------------------------
def test_early_exit_fires_and_caps_decode(served):
    """FastBERT-style exit: with a threshold below the model's observed
    confidence the request stops early; with an impossible threshold it
    runs to max_new_tokens."""
    cfg, plan, params = served
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, 6, dtype=np.int32)
    # measure this fixed-seed model's confidence on the first decode turn
    with InferenceEngine(cfg, plan, params, max_batch=1,
                         cache_len=64) as eng:
        eng.submit(Request(prompt=prompt, max_new_tokens=3)).result(300)
        conf = float(eng.state.last_conf[0])
    assert 0.0 < conf < 1.0

    with InferenceEngine(cfg, plan, params, max_batch=1, cache_len=64,
                         exit_threshold=conf * 0.5) as eng:
        out = eng.submit(Request(prompt=prompt,
                                 max_new_tokens=50)).result(300)
    assert out.finish_reason == "early_exit"
    assert len(out.tokens) < 50 and eng.early_exits == 1

    with InferenceEngine(cfg, plan, params, max_batch=1, cache_len=64,
                         exit_threshold=2.0) as eng:  # unreachable
        out = eng.submit(Request(prompt=prompt,
                                 max_new_tokens=4)).result(300)
    assert out.finish_reason == "max_tokens" and len(out.tokens) == 4


def test_per_request_exit_threshold_overrides_engine(served):
    cfg, plan, params = served
    rng = np.random.default_rng(10)
    prompt = rng.integers(0, cfg.vocab, 6, dtype=np.int32)
    with InferenceEngine(cfg, plan, params, max_batch=1, cache_len=64,
                         exit_threshold=2.0) as eng:
        # the request relaxes the engine's unreachable threshold to 0:
        # any confidence exits on the first decode turn
        out = eng.submit(Request(prompt=prompt, max_new_tokens=50,
                                 exit_threshold=1e-9)).result(300)
    assert out.finish_reason == "early_exit"


# -- supervisor integration ----------------------------------------------------
def test_adaptive_engine_supervisor_stop_idempotent(served):
    cfg, plan, params = served
    rng = np.random.default_rng(11)
    eng = InferenceEngine(cfg, plan, params, max_batch=2, cache_len=64,
                          adaptive=True)
    with eng:
        out = eng.submit(Request(
            prompt=rng.integers(0, cfg.vocab, 6, dtype=np.int32),
            max_new_tokens=3)).result(timeout=300)
    assert out.done
    # wait() already stopped the supervisor; stop() again is a no-op, and
    # a second wait() must not wedge or raise
    eng.supervisor.stop()
    assert eng.wait(timeout=10) == 0


def test_cache_manager_stats_surface(served):
    """The CacheManager exposes cache occupancy + SLO blocks through the
    StageHandle surface the Supervisor samples."""
    cfg, plan, params = served
    rng = np.random.default_rng(12)
    with InferenceEngine(cfg, plan, params, max_batch=2,
                         cache_len=64) as eng:
        eng.submit(Request(
            prompt=rng.integers(0, cfg.vocab, 6, dtype=np.int32),
            max_new_tokens=3)).result(timeout=300)
        handles = eng._runner.stage_handles()
        cm = next(h for h in handles
                  if getattr(h, "slo_controllable", False))
        s = cm.stats()
    assert s["cache"]["slots"] == 2
    assert s["slo"]["capacity"] == eng.max_pending
    assert {"backlog", "in_flight", "shed", "pressure"} <= set(s["slo"])
    # pushing a pressure level through the handle reaches admission
    cm.set_pressure(2)
    assert eng._slo.ext_level == 2
