"""Serving engine: accelerator-mode API, continuous batching, greedy-decode
equivalence with a manual loop."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import FF_EOS
from repro.runtime.steps import (init_state, make_decode_step,
                                 make_prefill_step)
from repro.serving import InferenceEngine, Request


@pytest.fixture(scope="module")
def served(plan_module=None):
    from repro.core.plan import single_device_plan
    plan = single_device_plan()
    cfg = get("ff-tiny").reduced()
    params = init_state(cfg, plan, jax.random.PRNGKey(0))["params"]
    return cfg, plan, params


def _manual_greedy(cfg, plan, params, prompt, n_new, cache_len=64):
    prefill = jax.jit(make_prefill_step(cfg, plan, cache_len))
    decode = jax.jit(make_decode_step(cfg, plan, cache_len))
    logits, caches = prefill(params, {"tokens": prompt[None]})
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    pos = prompt.shape[0]
    for i in range(n_new - 1):
        tok, _, caches = decode(params, caches,
                                {"token": tok,
                                 "pos": jnp.asarray(pos + i, jnp.int32)})
        out.append(int(tok[0, 0]))
    return out


def test_engine_generates_and_matches_manual_loop(served):
    cfg, plan, params = served
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 8, dtype=np.int32)
    want = _manual_greedy(cfg, plan, params, jnp.asarray(prompt), 6)

    eng = InferenceEngine(cfg, plan, params, max_batch=2, cache_len=64)
    eng.run_then_freeze()
    eng.offload(Request(prompt=prompt, max_new_tokens=6, id=0))
    eng.offload(FF_EOS)
    ok, req = eng.load_result()
    assert ok and req.done
    assert eng.wait() == 0
    assert req.tokens == want


def test_engine_continuous_batching_many_requests(served):
    cfg, plan, params = served
    rng = np.random.default_rng(1)
    eng = InferenceEngine(cfg, plan, params, max_batch=3, cache_len=64)
    eng.run_then_freeze()
    N = 7
    for i in range(N):
        eng.offload(Request(prompt=rng.integers(0, cfg.vocab, 8,
                                                dtype=np.int32),
                            max_new_tokens=4 + (i % 3), id=i))
    eng.offload(FF_EOS)
    done = []
    while True:
        ok, req = eng.load_result()
        if not ok:
            break
        done.append(req)
    assert eng.wait() == 0
    assert sorted(r.id for r in done) == list(range(N))
    for r in done:
        assert len(r.tokens) == r.max_new_tokens
    # batched slots: fewer decode steps than sequential sum of lengths
    assert eng.steps < sum(r.max_new_tokens for r in done)


def test_engine_results_independent_of_batching(served):
    """Each request's tokens are the same whether served alone or packed
    with others (slot isolation)."""
    cfg, plan, params = served
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, 8, dtype=np.int32)
               for _ in range(3)]
    solo = [_manual_greedy(cfg, plan, params, jnp.asarray(p), 5)
            for p in prompts]
    eng = InferenceEngine(cfg, plan, params, max_batch=3, cache_len=64)
    eng.run_then_freeze()
    for i, p in enumerate(prompts):
        eng.offload(Request(prompt=p, max_new_tokens=5, id=i))
    eng.offload(FF_EOS)
    got = {}
    while True:
        ok, req = eng.load_result()
        if not ok:
            break
        got[req.id] = req.tokens
    eng.wait()
    for i in range(3):
        assert got[i] == solo[i], i
