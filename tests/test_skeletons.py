"""L3 skeleton semantics — the paper's own examples as tests (hello
pipeline, sieve, farms, broadcast/MISD, on-demand, accelerator, feedback
divide&conquer, nesting) + the Sec. 13 performance model."""

import pytest

from repro.core import (BroadcastLB, Farm, FF_EOS, FFMap, FFNode, FnNode,
                        GO_ON, OnDemandLB, Pipeline)
from repro.core import perf_model as pm


class Counter(FFNode):
    def __init__(self, n):
        super().__init__()
        self.i, self.n = 0, n

    def svc(self, _):
        self.i += 1
        return self.i if self.i <= self.n else None


class Collect(FFNode):
    def __init__(self):
        super().__init__()
        self.got = []

    def svc(self, t):
        self.got.append(t)
        return GO_ON


def test_two_stage_pipeline_order():
    sink = Collect()
    p = Pipeline(Counter(5), sink)
    assert p.run_and_wait_end() == 0
    assert sink.got == [1, 2, 3, 4, 5]          # SPSC preserves order


def test_sieve_pipeline_finds_primes():
    class Sieve(FFNode):
        def __init__(self):
            super().__init__()
            self.f = 0

        def svc(self, t):
            if self.f == 0:
                self.f = t
                return GO_ON
            return GO_ON if t % self.f == 0 else t

    class Gen(FFNode):
        def __init__(self, n):
            super().__init__()
            self.i, self.n = 1, n

        def svc(self, _):
            self.i += 1
            return self.i if self.i <= self.n else None

    stages = [Sieve() for _ in range(7)]
    sink = Collect()
    p = Pipeline(Gen(30), *stages, sink)
    assert p.run_and_wait_end() == 0
    assert sorted(s.f for s in stages) == [2, 3, 5, 7, 11, 13, 17]
    assert sink.got == [19, 23, 29]             # survivors past 7 stages


def test_farm_emitter_collector():
    col = Collect()
    f = Farm([FnNode(lambda t: t * 2) for _ in range(4)])
    f.add_emitter(Counter(10)).add_collector(col)
    assert f.run_and_wait_end() == 0
    assert sorted(col.got) == [2 * i for i in range(1, 11)]


def test_farm_no_collector_consolidates_in_memory():
    results = {}

    class W(FFNode):
        def svc(self, t):
            results[t[0]] = t[1] + 1
            return GO_ON

    class Em(FFNode):
        def __init__(self):
            super().__init__()
            self.i = 0

        def svc(self, _):
            self.i += 1
            return (self.i, self.i * self.i) if self.i <= 10 else None

    f = Farm([W(), W()]).add_emitter(Em())
    assert f.run_and_wait_end() == 0
    assert results == {i: i * i + 1 for i in range(1, 11)}


def test_broadcast_misd_farm():
    ws = [Collect(), Collect()]
    f = Farm(ws, lb=BroadcastLB()).add_emitter(Counter(4))
    assert f.run_and_wait_end() == 0
    assert ws[0].got == ws[1].got == [1, 2, 3, 4]


def test_ondemand_scheduling():
    import time

    class SlowW(Collect):
        def svc(self, t):
            time.sleep(0.02)
            return super().svc(t)

    fast, slow = Collect(), SlowW()
    f = Farm([slow, fast]).add_emitter(Counter(20))
    f.set_scheduling_ondemand(threshold=0)      # only feed idle lanes
    assert f.run_and_wait_end() == 0
    assert len(fast.got) > len(slow.got)        # work follows availability
    assert sorted(fast.got + slow.got) == list(range(1, 21))


def test_accelerator_offload_load_result():
    f = Farm([FnNode(lambda t: t + 1) for _ in range(2)])
    f.add_collector(FnNode(lambda t: t))         # pass-through to out stream
    f.run_then_freeze()
    for i in range(8):
        f.offload(i)
    f.offload(FF_EOS)
    got = []
    while True:
        ok, r = f.load_result()
        if not ok:
            break
        got.append(r)
    assert f.wait() == 0
    assert sorted(got) == list(range(1, 9))


def test_feedback_divide_and_conquer():
    class Em(FFNode):
        def __init__(self, seeds):
            super().__init__()
            self.prime = True
            self.pending = list(seeds)
            self.inflight = 0
            self.done = []

        def svc(self, t):
            if t is not None:
                self.inflight -= 1
                if t % 2 == 0:
                    self.pending.append(t)      # split: halve again
                else:
                    self.done.append(t)         # conquer: base case
            while self.pending:
                self.inflight += 1
                self.ff_send_out(self.pending.pop())
            return None if self.inflight == 0 else GO_ON

    em = Em([40, 12, 7])
    f = Farm([FnNode(lambda t: t // 2 if t % 2 == 0 else t),
              FnNode(lambda t: t // 2 if t % 2 == 0 else t)])
    f.add_emitter(em)
    f.wrap_around()
    assert f.run_and_wait_end() == 0
    assert sorted(em.done) == [3, 5, 7]


def test_nesting_farm_of_pipelines():
    col = Collect()
    workers = [Pipeline(FnNode(lambda t: t + 1), FnNode(lambda t: t * 10))
               for _ in range(2)]
    f = Farm(workers).add_emitter(Counter(6)).add_collector(col)
    assert f.run_and_wait_end() == 0
    assert sorted(col.got) == [(i + 1) * 10 for i in range(1, 7)]


def test_pipeline_of_farms():
    col = Collect()
    inner = Farm([FnNode(lambda t: t + 100), FnNode(lambda t: t + 100)])
    p = Pipeline(Counter(5), inner, col)
    assert p.run_and_wait_end() == 0
    assert sorted(col.got) == [101, 102, 103, 104, 105]


# --- paper Sec. 13 performance model -----------------------------------------
def test_pipeline_service_time_is_max_stage():
    assert pm.pipeline_service_time([1.0, 3.0, 2.0]) == 3.0
    # balanced k-stage pipeline speedup ~ k
    k = 5
    sp = pm.pipeline_speedup([1.0] * k, m_tasks=10**6)
    assert abs(sp - k) < 0.01


def test_farm_speedup_near_linear_then_bounded():
    sp = pm.farm_speedup(10**6, t_task=1.0, nw=8)
    assert abs(sp - 8) < 0.01
    # emitter-bound farm saturates at t_task/t_emit
    sp = pm.farm_speedup(10**6, t_task=1.0, nw=64, t_emit=0.25)
    assert abs(sp - 4.0) < 0.01


def test_bubble_fraction_and_microbatch_choice():
    assert pm.pipeline_bubble_fraction(4, 1) == pytest.approx(3 / 4)
    m = pm.choose_microbatches(16, max_bubble=0.1)
    assert pm.pipeline_bubble_fraction(16, m) <= 0.1


def test_amdahl():
    assert pm.amdahl(0.0, 16) == pytest.approx(16.0)
    assert pm.amdahl(1.0, 16) == pytest.approx(1.0)


def test_svc_time_ema_warmup_median_seed():
    """A slow first call (jit trace, cold cache) must not poison the
    service-time EMA: the estimate seeds from the median of the first 5
    samples, so after a handful of fast items it reflects steady state."""
    import time

    class SlowFirst(FFNode):
        def __init__(self):
            super().__init__()
            self.calls = 0

        def svc(self, t):
            self.calls += 1
            time.sleep(0.1 if self.calls == 1 else 0.001)
            return t

    node = SlowFirst()
    p = Pipeline(Counter(10), node, FnNode(lambda t: GO_ON))
    assert p.run_and_wait_end() == 0
    # old first-sample seeding left ~13ms here after 10 items; the median
    # seed lands near the 1ms steady state
    assert node.svc_time_ema < 0.005, node.svc_time_ema
    stats = node.node_stats()
    assert stats["items"] == 10
    assert stats["svc_time_ema_s"] == pytest.approx(node.svc_time_ema)
