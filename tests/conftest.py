# Tests run on the single real CPU device (the dry-run's 512 fake devices
# are set ONLY inside launch/dryrun.py / subprocess tests, never here).
import os
import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)

import jax
import pytest


@pytest.fixture(scope="session")
def plan():
    from repro.core.plan import single_device_plan
    return single_device_plan()


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
