"""End-to-end behaviour: loss decreases through the full stack; grad
accumulation equivalence; deterministic replay (restart/elasticity depends
on it)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.data import SyntheticLMSource, make_pipeline
from repro.optim.schedules import cosine_warmup
from repro.runtime.steps import init_state, make_train_step


def test_end_to_end_training_reduces_loss(plan, rng):
    cfg = get("ff-tiny").reduced()
    state = init_state(cfg, plan, rng)
    src = SyntheticLMSource(cfg.vocab, 32, 4, seed=0)
    pipe = make_pipeline(src, plan, n_batches=25)
    step = jax.jit(make_train_step(cfg, plan, cosine_warmup(3e-3, 5, 25)))
    losses = []
    while True:
        b = pipe.get()
        if b is None:
            break
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert len(losses) == 25
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_grad_accumulation_matches_full_batch(plan, rng):
    """n_micro=4 on batch B == n_micro=1 on the same batch (the feedback-
    loop accumulation is exact up to fp32 summation order)."""
    cfg = get("ff-tiny").reduced()
    state1 = init_state(cfg, plan, rng)
    state2 = jax.tree.map(lambda x: x.copy(), state1)
    batch = {"tokens": jax.random.randint(rng, (8, 32), 0, cfg.vocab)}
    lr = lambda s: 1e-2
    s1, m1 = jax.jit(make_train_step(cfg, plan, lr, n_micro=1))(state1, batch)
    s2, m2 = jax.jit(make_train_step(cfg, plan, lr, n_micro=4))(state2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)
    # bf16 params + AdamW's rsqrt amplify summation-order ulps: bound the
    # mismatch fraction rather than every element
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        close = np.isclose(a, b, rtol=3e-2, atol=3e-3)
        budget = max(2, int(close.size * 1e-3))
        assert (~close).sum() <= budget, \
            f"{(~close).sum()}/{close.size} differ"
        np.testing.assert_allclose(a, b, rtol=0.5, atol=0.05)


def test_deterministic_training(plan):
    """Same seed + same data -> identical loss trajectory."""
    def run():
        cfg = get("ff-tiny").reduced()
        state = init_state(cfg, plan, jax.random.PRNGKey(9))
        src = SyntheticLMSource(cfg.vocab, 16, 2, seed=4)
        step = jax.jit(make_train_step(cfg, plan, lambda s: 1e-3))
        losses = []
        for _ in range(5):
            state, m = step(state, jax.device_put(src.next_batch()))
            losses.append(float(m["loss"]))
        return losses

    assert run() == run()
