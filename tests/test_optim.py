"""Optimizer correctness + gradient compression properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # bare interpreter: deterministic cases still run
    given = settings = st = None

from repro.optim import (Adafactor, AdamW, clip_by_global_norm,
                         ef_compress_grads, int8_compress, int8_decompress)


def _quad_problem(key, n=32):
    a = jax.random.normal(key, (n,)) * 2.0
    params = {"w": jnp.zeros((n,)), "m": jnp.zeros((4, n))}
    def loss(p):
        return jnp.sum((p["w"] - a) ** 2) + jnp.sum(p["m"] ** 2)
    return params, loss, a


def test_adamw_first_step_matches_closed_form():
    opt = AdamW(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    params = {"w": jnp.array([1.0, -2.0])}
    grads = {"w": jnp.array([0.5, -1.0])}
    state = opt.init(params)
    new_p, state = opt.update(grads, state, params, lr=0.1)
    # bias-corrected first step == -lr * sign-ish g/|g|
    expected = params["w"] - 0.1 * grads["w"] / (jnp.abs(grads["w"]) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray(expected), rtol=1e-4)


@pytest.mark.parametrize("make_opt", [lambda: AdamW(weight_decay=0.0),
                                      lambda: Adafactor(min_dim_factored=2)])
def test_optimizers_converge_on_quadratic(make_opt, rng):
    params, loss, a = _quad_problem(rng)
    opt = make_opt()
    state = opt.init(params)
    g = jax.grad(loss)
    l0 = float(loss(params))
    for i in range(200):
        params, state = opt.update(g(params), state, params, lr=0.05)
    assert float(loss(params)) < 0.05 * l0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((9,), 4.0)}
    # ||g|| = sqrt(4*9 + 9*16) = sqrt(180)
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(180), rel=1e-5)
    norm_after = np.sqrt(sum(np.sum(np.square(np.asarray(x)))
                             for x in jax.tree.leaves(clipped)))
    assert norm_after == pytest.approx(1.0, rel=1e-4)


def _check_int8_roundtrip(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.1, 10))
    q, s = int8_compress(x)
    y = int8_decompress(q, s)
    assert float(jnp.max(jnp.abs(y - x))) <= float(s) * 0.5 + 1e-6


def test_int8_roundtrip_deterministic():
    for seed in (0, 1, 7, 1234, 2**31 - 1):
        _check_int8_roundtrip(seed)


if st is not None:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_int8_roundtrip_bounded_error(seed):
        _check_int8_roundtrip(seed)
else:
    def test_int8_roundtrip_bounded_error():
        pytest.importorskip("hypothesis")


def test_error_feedback_preserves_signal():
    """Sum of decompressed grads + final error == sum of true grads
    (error feedback loses nothing over time)."""
    rng = np.random.default_rng(0)
    grads = [jnp.asarray(rng.normal(size=(32,)).astype(np.float32))
             for _ in range(20)]
    err = {"g": jnp.zeros((32,))}
    total_sent = jnp.zeros((32,))
    for g in grads:
        sent, err_tree = ef_compress_grads({"g": g}, err)
        err = err_tree
        total_sent = total_sent + sent["g"]
    true_total = sum(grads)
    np.testing.assert_allclose(np.asarray(total_sent + err["g"]),
                               np.asarray(true_total), rtol=1e-4, atol=1e-4)


def test_adafactor_state_is_factored():
    opt = Adafactor(min_dim_factored=8)
    params = {"big": jnp.zeros((16, 32)), "small": jnp.zeros((4,))}
    st_ = opt.init(params)
    assert set(st_["s"]["big"]) == {"vr", "vc"}
    assert st_["s"]["big"]["vr"].shape == (16,)
    assert st_["s"]["big"]["vc"].shape == (32,)
    assert set(st_["s"]["small"]) == {"v"}
    # memory: factored stats are O(n+m), not O(n*m)
    n_stats = sum(x.size for x in jax.tree.leaves(st_["s"]["big"]))
    assert n_stats == 16 + 32
