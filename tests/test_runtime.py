"""Adaptive runtime: live re-placement (width + tier), the supervisor's
stats -> placement loop, and the online cost-model refinement.

Covers the reconfiguration invariants:
- supervisor-disabled (adaptive=False) runs behave exactly like before;
- a mid-stream tier migration preserves exact input order on streams longer
  than the ring capacity;
- a worker crash during a drain/swap surfaces WorkerCrashed instead of
  wedging;
- ``perf_model.observe()`` measurably shifts a subsequent compile()'s
  placement.

The end-to-end GIL-flip test asserts the acceptance throughput recovery
against a *hardware-scaled* bar: the full 1.5x is demanded wherever a
static thread-vs-process comparison of the same workload demonstrates the
hardware can deliver it (true multicore); on SMT-throttled 2-vCPU
containers — where even PR 4's committed bench baseline shows the process
tier merely matching threads (ratio_best 0.99) — the bar degrades
proportionally, and the test still demands the migration itself, exact
output order, and no pathological slowdown.
"""

import json
import os
import signal
import threading
import time

import pytest

from repro.core import (EOS, GraphError, ProcessRunner, Supervisor,
                        WorkerCrashed, farm, pipeline, seq)
from repro.core import perf_model as pm
from repro.core.compiler import annotate, place, _top_stages
from repro.core.node import FFNode

pytestmark = pytest.mark.runtime


@pytest.fixture(autouse=True)
def _isolated_calibration(tmp_path, monkeypatch):
    """Every test here sees a private calibration/observed cache: the
    supervisor's observe() must never leak test workloads into the real
    cache (or into other tests' placement decisions)."""
    monkeypatch.setenv("REPRO_FF_CALIB_CACHE",
                       str(tmp_path / "calibration.json"))
    pm.reset_calibration()
    pm.reset_observed()
    yield
    pm.reset_calibration()
    pm.reset_observed()


def _write_fake_calibration():
    """Pre-seed the (isolated) cache so place() never pays a measurement."""
    path = os.environ["REPRO_FF_CALIB_CACHE"]
    with open(path, "w") as f:
        json.dump({"version": 1, "cpu_count": os.cpu_count(),
                   "peak_flops": 5e10, "queue_hop_s": 2e-5,
                   "proc_hop_s": 1e-4, "device_dispatch_s": 2e-5}, f)
    pm.reset_calibration()


class _Gen(FFNode):
    def __init__(self, n):
        super().__init__()
        self.i, self.n = 0, n

    def svc(self, _):
        self.i += 1
        return float(self.i) if self.i <= self.n else None


def _double(x):
    return x * 2.0


def _flip_worker(x):
    """GIL-releasing (sleep) until the flip file appears, then GIL-bound
    pure-Python compute.  Output is phase-independent so order/content
    checks stay exact.  Worker processes forked after the flip inherit the
    env var and see the file too."""
    if os.path.exists(os.environ.get("REPRO_FF_TEST_FLIP", "/nonexistent")):
        s = 0.0
        for i in range(100000):
            s += (x * i) % 7.3
    else:
        time.sleep(0.004)
    return x * 2.0


def _sleepy(x):
    time.sleep(0.002)
    return x + 1.0


# ---------------------------------------------------------------------------
# adaptive=False is byte-identical to the static path
# ---------------------------------------------------------------------------
def test_supervisor_disabled_is_static_behavior():
    def build():
        return pipeline(_Gen(64), farm(_double, n=2))

    r_static = build().compile(mode="host")
    out_static = r_static.run()
    # no adaptive machinery anywhere in the static runner
    assert not any(getattr(st, "ff_adaptive", False)
                   for st in r_static._top_members())
    assert all(not h.reconfigurable for h in r_static.stage_handles())
    assert r_static.replacement_events() == []

    r_adaptive = build().compile(mode="host", adaptive=True)
    assert any(getattr(st, "ff_adaptive", False)
               for st in r_adaptive._top_members())
    out_adaptive = r_adaptive.run()
    # identical output values; the adaptive farm is sequence-ordered, which
    # for this 1->1 stage means identical order too
    assert out_adaptive == sorted(out_static) == \
        [2.0 * i for i in range(1, 65)]
    # with no supervisor attached, nothing was re-placed
    assert r_adaptive.replacement_events() == []


def test_adaptive_placement_report_marks_stage():
    r = pipeline(_Gen(4), farm(_double, n=2)).compile(mode="host",
                                                      adaptive=True)
    targets = {desc: p for desc, p in r.placements}
    farm_p = next(p for d, p in targets.items() if "farm" in d)
    assert "adaptive" in farm_p.reason
    r.run()


# ---------------------------------------------------------------------------
# the uniform StageHandle surface
# ---------------------------------------------------------------------------
def test_stage_handles_uniform_across_runners(plan):
    # host threads
    r = pipeline(_Gen(8), farm(_double, n=2)).compile(mode="host")
    r.run()
    hs = r.stage_handles()
    assert len(hs) == 2 and all(isinstance(h.stats(), dict) for h in hs)
    # process tier
    rp = pipeline(_Gen(8), farm(_double, n=2)).compile(mode="process")
    assert isinstance(rp, ProcessRunner)
    rp.run()
    hp = rp.stage_handles()
    assert any(h.stats().get("backend") == "process" for h in hp)
    # device tier: the fused segment is ONE entry whose label lists the
    # composed stages; fuse=False restores the per-stage split
    rd = pipeline(seq(lambda x: x + 1.0, pure=True),
                  seq(lambda x: x * 2.0, pure=True)).compile(
        plan, mode="device")
    out = rd.run([1.0, 2.0, 3.0])
    assert [float(y) for y in out] == [4.0, 6.0, 8.0]
    st = rd.stats()
    assert len(st["stages"]) == 1
    assert " + " in st["stages"][0]["node"]
    assert all(s["items"] == 3 for s in st["stages"])
    hd = rd.stage_handles()
    assert len(hd) == 1
    assert all(h.stats()["backend"] == "device" for h in hd)
    assert all(not h.reconfigurable for h in hd)
    rs = pipeline(seq(lambda x: x + 1.0, pure=True),
                  seq(lambda x: x * 2.0, pure=True)).compile(
        plan, mode="device", fuse=False)
    rs.run([1.0, 2.0, 3.0])
    assert len(rs.stats()["stages"]) == 2
    assert len(rs.stage_handles()) == 2


def test_non_reconfigurable_handle_refuses():
    r = pipeline(_Gen(4), farm(_double, n=2)).compile(mode="host")
    h = r.stage_handles()[0]
    with pytest.raises(GraphError):
        h.resize(2)
    with pytest.raises(GraphError):
        h.migrate("host_process")
    r.run()


# ---------------------------------------------------------------------------
# migration preserves exact order on streams longer than the ring capacity
# ---------------------------------------------------------------------------
@pytest.mark.shm
def test_migration_preserves_order_beyond_ring_capacity():
    N = 400                              # engine lanes are <= 8 slots deep

    def work(x):
        time.sleep(0.001)                # keep the stream alive across swaps
        return x * 2.0

    g = farm(work, n=2)
    r = g.compile(mode="host", adaptive=True, capacity=16)
    r.run_then_freeze()
    h = r.stage_handles()[0]
    got = []
    done = threading.Event()

    def collect():
        while True:
            ok, item = r.load_result(timeout=60.0)
            if not ok:
                break
            got.append(item)
        done.set()

    threading.Thread(target=collect, daemon=True).start()

    def feed():
        for i in range(N):
            r.offload(float(i))
        r.offload(EOS)

    threading.Thread(target=feed, daemon=True).start()
    time.sleep(0.02)
    assert h.migrate("host_process") is True     # mid-stream swap out ...
    time.sleep(0.05)
    h.migrate("host")                            # ... and back
    assert done.wait(120.0)
    assert r.wait(30.0) == 0
    assert got == [2.0 * i for i in range(N)]
    assert len(r.replacement_events()) >= 1


# ---------------------------------------------------------------------------
# crash during a drain/swap surfaces WorkerCrashed instead of wedging
# ---------------------------------------------------------------------------
@pytest.mark.shm
def test_worker_crash_during_drain_swap_surfaces_error():
    g = farm(_sleepy, n=2)
    r = g.compile(mode="process", adaptive=True)
    r.run_then_freeze()
    h = r.stage_handles()[0]
    assert h.tier == "host_process"
    node = h.node
    for i in range(4):
        r.offload(float(i))
    time.sleep(0.3)
    for p in node._engine._procs:                # crash both workers
        os.kill(p.pid, signal.SIGKILL)
    with pytest.raises(WorkerCrashed):
        h.migrate("host")                        # drain hits the crash
    # the runner unwinds instead of wedging, and the error is preserved
    assert r.wait(30.0) == -1
    assert isinstance(r.error(), WorkerCrashed)


# ---------------------------------------------------------------------------
# supervisor width policy: AutoscaleLB generalized
# ---------------------------------------------------------------------------
def test_supervisor_resizes_active_workers_from_lane_depth():
    g = farm(_sleepy, n=2)
    r = g.compile(mode="host", adaptive=True)
    r.run_then_freeze()
    sup = Supervisor(r, interval=0.01, migrate=False).start()
    got = []
    done = threading.Event()

    def collect():
        while True:
            ok, item = r.load_result(timeout=60.0)
            if not ok:
                break
            got.append(item)
        done.set()

    threading.Thread(target=collect, daemon=True).start()
    # trickle: lanes stay empty -> the supervisor retires a worker
    for i in range(12):
        r.offload(float(i))
        time.sleep(0.02)
    deadline = time.monotonic() + 10.0
    while not any(e.kind == "shrink" for e in sup.events) \
            and time.monotonic() < deadline:
        time.sleep(0.01)
    # burst: deep lanes -> the supervisor reactivates it
    for i in range(12, 120):
        r.offload(float(i))
    r.offload(EOS)
    assert done.wait(60.0)
    assert r.wait(30.0) == 0
    sup.stop()
    kinds = {e.kind for e in sup.events}
    assert "shrink" in kinds
    assert "grow" in kinds
    assert got == [i + 1.0 for i in range(120)]  # seq-ordered throughout


# ---------------------------------------------------------------------------
# the acceptance loop: GIL flip mid-stream -> thread->process migration
# ---------------------------------------------------------------------------
@pytest.mark.shm
@pytest.mark.slow
def test_gil_flip_migrates_and_recovers_throughput(tmp_path, monkeypatch):
    flip = tmp_path / "flip"
    monkeypatch.setenv("REPRO_FF_TEST_FLIP", str(flip))
    n1, n2 = 16, 200

    def static_per_item(mode: str) -> float:
        # flipped workload, pinned to one tier, no supervisor: what this
        # hardware can actually deliver per tier
        g = pipeline(_Gen(40), farm(_flip_worker, n=2))
        r = g.compile(mode=mode)
        t0 = time.perf_counter()
        out = r.run(timeout=120.0)
        assert len(out) == 40
        return (time.perf_counter() - t0) / 40

    def run_stream(supervised: bool):
        if flip.exists():
            flip.unlink()
        g = farm(_flip_worker, n=2)
        r = g.compile(mode="host", adaptive=True)
        r.run_then_freeze()
        sup = Supervisor(r, interval=0.02) if supervised else None
        if sup:
            sup.start()
        got = []
        done = threading.Event()

        def collect():
            while True:
                ok, item = r.load_result(timeout=120.0)
                if not ok:
                    break
                got.append(item)
            done.set()

        threading.Thread(target=collect, daemon=True).start()
        for i in range(n1):
            r.offload(float(i))
        time.sleep(0.1)
        flip.touch()                     # the workload turns GIL-bound
        t0 = time.perf_counter()
        for i in range(n1, n1 + n2):
            r.offload(float(i))
        r.offload(EOS)
        assert done.wait(300.0)
        dt2 = time.perf_counter() - t0
        assert r.wait(60.0) == 0
        if sup:
            sup.stop()
        return got, dt2, (list(sup.events) if sup else [])

    # hardware ceiling: static thread vs process on the flipped workload
    flip.touch()
    ratios = []
    for i in range(2):
        th = static_per_item("host")
        pr = static_per_item("process")
        ratios.append(th / pr)
    ceiling = max(ratios)

    got_sup, dt_sup, events = run_stream(True)
    got_ctl, dt_ctl, _ = run_stream(False)
    expected = [2.0 * i for i in range(n1 + n2)]

    # 1. the migration happened, thread -> process, while the stream ran
    migrations = [e for e in events if e.kind == "migrate"]
    assert any("host_process" in e.detail for e in migrations), events
    # 2. exact output order preserved across the swap (and in the control)
    assert got_sup == expected
    assert got_ctl == expected
    # 3. end-to-end throughput recovery vs staying put, against the
    #    hardware-scaled bar: the full acceptance 1.5x wherever the static
    #    comparison shows true multicore headroom; proportionally lower on
    #    SMT-throttled containers where the process tier can only match
    #    threads (there the assertion still rules out a pathological
    #    migration cost)
    speedup = dt_ctl / dt_sup
    required = min(1.5, 0.7 * ceiling)
    assert speedup >= required, (
        f"phase-2 speedup {speedup:.2f}x < required {required:.2f}x "
        f"(static thread/process ceiling {ceiling:.2f}x, "
        f"supervised {dt_sup:.2f}s vs control {dt_ctl:.2f}s, "
        f"events={[str(e) for e in events]})")


# ---------------------------------------------------------------------------
# online cost-model refinement: observe() shifts the next compile
# ---------------------------------------------------------------------------
def _observed_worker(x):
    return x


def test_observe_shifts_subsequent_placement():
    _write_fake_calibration()
    key = pm.fn_key(_observed_worker)

    # before any history: no cost info, the farm stays on threads
    g0 = pipeline(_Gen(4), farm(_observed_worker, n=4)).optimize()
    annotate(g0)
    place(g0)
    farm_stage = _top_stages(g0)[1]
    assert farm_stage.placement.target == "host"
    assert farm_stage.cost.source == "default"

    # a runtime observation: 4ms/item of CPU, demonstrably GIL-serialized
    absorbed = pm.observe({"stages": [{
        "backend": "thread", "fn_key": key, "items": 64, "delivered": 64,
        "svc_cpu_ema_s": 4e-3, "svc_wall_ema_s": 8e-3,
        "gil_ratio": 0.5, "active": 2}]}, write=True)
    assert absorbed == 1
    rec = pm.lookup_observed(key)
    assert rec is not None and rec["releases_gil"] is False

    # the next compile's annotate/place consumes the history: same graph,
    # still no costs=/sample=, now lands on the process tier
    g1 = pipeline(_Gen(4), farm(_observed_worker, n=4)).optimize()
    annotate(g1)
    place(g1)
    farm_stage = _top_stages(g1)[1]
    assert farm_stage.cost.source == "observed"
    assert farm_stage.cost.releases_gil is False
    assert farm_stage.placement.target == "host_process", \
        farm_stage.placement

    # the observed table persists: a fresh in-memory state reloads it
    pm.reset_observed()
    assert pm.lookup_observed(key) is not None


def test_observe_refines_proc_hop_calibration():
    _write_fake_calibration()
    before = pm.get_calibration(measure=False).proc_hop_s
    absorbed = pm.observe({"stages": [{
        "backend": "process", "items": 64, "hop_ema_s": 9e-4}]})
    assert absorbed == 1
    after = pm.get_calibration(measure=False)
    assert after.source == "observed"
    assert before < after.proc_hop_s < 9e-4   # EMA moved toward the sample


def test_observe_ignores_thin_or_foreign_records():
    assert pm.observe({"stages": [
        {"backend": "thread", "fn_key": "x.y", "items": 2,
         "svc_cpu_ema_s": 1e-3},                  # too few items
        {"backend": "process", "items": 64},      # no hop measured
        {"unrelated": True},
    ]}) == 0


# ---------------------------------------------------------------------------
# stats() is safe and consistent mid-stream
# ---------------------------------------------------------------------------
def test_stats_consistent_midstream():
    g = pipeline(_Gen(300), farm(_sleepy, n=2))
    r = g.compile(mode="host", adaptive=True)
    errors = []
    stop = threading.Event()

    def hammer():
        handles = r.stage_handles()
        while not stop.is_set():
            try:
                for h in handles:
                    s = h.stats()
                    if "delivered" in s:
                        assert s["delivered"] <= s["items"]
                r.stats()
            except Exception as e:       # noqa: BLE001
                errors.append(e)
                return

    t = threading.Thread(target=hammer, daemon=True)
    t.start()
    out = r.run(timeout=120.0)
    stop.set()
    t.join(10.0)
    assert not errors
    assert out == [i + 1.0 for i in range(1, 301)]
