"""ShardingPlan invariants (hypothesis): fitted specs always divide, fsdp
toggle drops cleanly, logical-axis resolution is mesh-aware."""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # bare interpreter: deterministic cases still run
    given = settings = st = None

from jax.sharding import Mesh, PartitionSpec as P

from repro.core.plan import ShardingPlan


def _mesh_1dev(names=("data", "model")):
    devs = np.array(jax.devices()[:1]).reshape((1,) * len(names))
    return Mesh(devs, axis_names=names)


def _plan():
    return ShardingPlan(mesh=_mesh_1dev())


def test_logical_resolution_drops_absent_axes():
    plan = _plan()
    assert plan.axes("batch") == "data"       # 'pod' absent -> dropped
    assert plan.axes("tp") == "model"
    assert plan.axes(None) is None
    assert plan.axes("layers") is None


def test_sp_toggle():
    plan = _plan()
    assert plan.axes("sp") == "model"
    plan.sequence_parallel = False
    assert plan.axes("sp") is None


def test_fsdp_toggle():
    plan = _plan()
    spec = plan.param_spec(("fsdp", "tp"))
    assert spec == P("data", "model")
    plan.fsdp_params = False
    assert plan.param_spec(("fsdp", "tp")) == P(None, "model")


def _check_fitted_specs_divide(dims):
    """Property: every mesh axis kept in a fitted spec divides its dim."""
    plan = _plan()
    logicals = ["batch", "tp", "fsdp", None][:len(dims)]
    spec = plan.spec_for_shape(dims, logicals)
    for d, s in zip(dims, spec):
        if s is None:
            continue
        axes = s if isinstance(s, tuple) else (s,)
        n = 1
        for a in axes:
            n *= plan.mesh.shape[a]
        assert d % n == 0


def test_fitted_specs_divide_deterministic():
    for dims in ([8], [3, 5], [1, 1, 1], [64, 7, 2, 9], [2, 64, 32]):
        _check_fitted_specs_divide(dims)


if st is not None:
    @given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4))
    @settings(max_examples=60, deadline=None)
    def test_fitted_specs_always_divide(dims):
        _check_fitted_specs_divide(dims)
else:
    def test_fitted_specs_always_divide():
        pytest.importorskip("hypothesis")


def test_fit_drops_non_dividing_on_multi_axis_mesh():
    """On a fake 4x2 mesh built from repeated single device entries we can't
    test placement, but the pure spec logic is mesh-shape driven; emulate
    via a plan whose mesh reports bigger sizes."""
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}
        devices = np.empty((4, 2), object)
    plan = ShardingPlan.__new__(ShardingPlan)
    plan.mesh = FakeMesh()
    plan.rules = dict(__import__("repro.core.plan", fromlist=["DEFAULT_RULES"])
                      .DEFAULT_RULES)
    plan.sequence_parallel = True
    plan.fsdp_params = True
    plan.constrain_activations = True
    plan._axis_names = {"data", "model"}
    # batch=6: 'data'(4) does not divide -> dropped entirely
    assert plan._fit_dim(6, "batch") is None
    # batch=8: divides 4 -> kept
    assert plan._fit_dim(8, "batch") == "data"
    # dim=2 with tp(2) -> kept; dim=3 -> dropped
    assert plan._fit_dim(2, "tp") == "model"
    assert plan._fit_dim(3, "tp") is None


def test_axis_sizes(plan):
    assert plan.dp == 1 and plan.tp == 1
