"""Device-segment fusion (core/fuse.py + the a2a_fused Pallas kernel):

- fused-vs-unfused byte-identical outputs on pipeline / farm / all_to_all /
  wrap_around device graphs, and on a hybrid graph where a host process farm
  feeds a fused device segment;
- the one-program-per-run invariant: N adjacent device stages lower to
  exactly ONE boundary node (hybrid) / ONE runner part (all-device);
- kernels/a2a_fused.py vs the kernels/ref.py oracle, bit-for-bit, across
  dtypes, block sizes, and capacity-overflow edges;
- the jitted-segment cache: re-compile() of the same graph reuses the
  traced program.
"""

import numpy as np
import pytest

from repro.core import FFNode, all_to_all, farm, pipeline
from repro.core.fuse import (FusedSegment, fuse_device_segments,
                             segment_cache_clear, segment_cache_info)


class Gen(FFNode):
    def __init__(self, n):
        super().__init__()
        self.i, self.n = 0, n

    def svc(self, _):
        self.i += 1
        return np.float32(self.i) if self.i <= self.n else None


# module-level (picklable) stages for the process-tier hybrid test
def _proc_affine(x):
    return x * 2.0 + 1.0


def _bytes(out):
    return [np.asarray(y).tobytes() for y in out]


def _device_entries(r):
    st = r.stats()
    stages = st.get("stages") or st.get("graph", {}).get("stages", [])
    return [s for s in stages if s.get("backend") == "device"]


def _dev_stages():
    import jax.numpy as jnp
    return [lambda x: x * 1.5 + 0.25,
            lambda x: jnp.tanh(x),
            lambda x: x - 0.125,
            lambda x: x * x + x]


# ---------------------------------------------------------------------------
# fused vs unfused: byte-identical outputs
# ---------------------------------------------------------------------------
def test_pipeline_device_fused_unfused_parity(plan):
    xs = [np.linspace(-1.0, 1.0, 16, dtype=np.float32) * (i + 1)
          for i in range(7)]
    a = pipeline(*_dev_stages()).compile(plan, mode="device").run(xs)
    b = pipeline(*_dev_stages()).compile(plan, mode="device",
                                         fuse=False).run(xs)
    assert _bytes(a) == _bytes(b)


def test_farm_in_pipeline_device_fused_unfused_parity(plan):
    xs = [np.float32(i) * 0.5 for i in range(9)]
    def build():
        return pipeline(lambda x: x + 1.0, farm(lambda x: x * 3.0, n=2),
                        lambda x: x - 0.5)
    a = build().compile(plan, mode="device").run(xs)
    b = build().compile(plan, mode="device", fuse=False).run(xs)
    assert _bytes(a) == _bytes(b)


def test_a2a_in_pipeline_device_fused_unfused_parity(plan):
    xs = [np.float32(i) for i in range(8)]
    def build():
        return pipeline(lambda x: x + 1.0,
                        all_to_all([lambda x: x * 10.0],
                                   [lambda y: y * 2.0, lambda y: y + 7.0]),
                        lambda y: y - 0.25)
    a = build().compile(plan, mode="device").run(xs)
    b = build().compile(plan, mode="device", fuse=False).run(xs)
    assert _bytes(a) == _bytes(b)


def test_wrap_around_device_fused_unfused_parity(plan):
    xs = [np.float32(i) for i in range(5)]
    def build():
        return pipeline(lambda x: x * 0.5 + 1.0).wrap_around()
    a = build().compile(plan, mode="device", feedback_steps=4).run(xs)
    b = build().compile(plan, mode="device", feedback_steps=4,
                        fuse=False).run(xs)
    assert _bytes(a) == _bytes(b)


@pytest.mark.shm
def test_hybrid_process_farm_feeds_fused_device_segment(plan):
    """Thread gen -> process farm -> fused device segment, one graph."""
    n = 12
    d1, d2, d3 = (lambda x: x * 1.25, lambda x: x + 0.5,
                  lambda x: x * x - 1.0)

    def build():
        return pipeline(Gen(n), farm(_proc_affine, n=2), d1, d2, d3)

    def compiled(fuse):
        # normalize=False: the optimizer would fold the trailing pure maps
        # into the farm collector, and this test needs them as distinct
        # top-level device stages for the fusion pass to merge
        return build().compile(
            plan, device_batch=4, fuse=fuse, normalize=False,
            placements={1: "host_process", 2: "device", 3: "device",
                        4: "device"})

    rf = compiled(True)
    targets = [p.target for _, p in rf.placements]
    assert targets == ["host", "host_process", "device", "device", "device"]
    a = sorted(_bytes(rf.run()))
    ru = compiled(False)
    b = sorted(_bytes(ru.run()))
    assert a == b
    # the fused run is one boundary node, the unfused one is three
    dev = _device_entries(rf)
    assert len(dev) == 1 and " + " in dev[0]["node"]
    assert len(_device_entries(ru)) == 3


# ---------------------------------------------------------------------------
# one program per device run
# ---------------------------------------------------------------------------
def test_adjacent_device_stages_lower_to_one_node(plan):
    """N adjacent device stages -> exactly ONE _DeviceStageNode."""
    s1, s2, s3, s4 = _dev_stages()
    r = pipeline(Gen(8), s1, s2, s3, s4).compile(
        plan, device_batch=4,
        placements={1: "device", 2: "device", 3: "device", 4: "device"})
    out = r.run()
    assert len(out) == 8
    dev = _device_entries(r)
    assert len(dev) == 1
    assert dev[0]["node"].count(" + ") == 3      # all four stages listed


def test_non_adjacent_device_runs_stay_separate(plan):
    s1, s2, s3, _ = _dev_stages()
    r = pipeline(Gen(6), s1, lambda x: float(x) + 0.0, s2, s3).compile(
        plan, device_batch=2,
        placements={1: "device", 2: "host", 3: "device", 4: "device"})
    assert len(r.run()) == 6
    assert len(_device_entries(r)) == 2          # [s1], host, [s2 + s3]


def test_all_device_graph_is_one_part(plan):
    r = pipeline(*_dev_stages()).compile(plan, mode="device")
    r.run([np.float32(1.0), np.float32(2.0)])
    st = r.stats()
    assert st["backend"] == "DeviceRunner"
    assert len(st["stages"]) == 1
    assert st["stages"][0]["node"].count(" + ") == 3


def test_fuse_pass_grouping_unit():
    import dataclasses

    @dataclasses.dataclass
    class P:
        target: str
        width: int = 1
        reason: str = "r"

    class S:
        def describe(self):
            return "s"

    stages = [S(), S(), S(), S(), S()]
    pl = [P("host"), P("device"), P("device"), P("host"), P("device")]
    grouped = fuse_device_segments(stages, pl)
    kinds = [type(e).__name__ for e, _ in grouped]
    assert kinds == ["S", "FusedSegment", "S", "FusedSegment"]
    assert len(grouped[1][0].stages) == 2
    assert grouped[1][1].reason.startswith("fused run of 2")
    off = fuse_device_segments(stages, pl, enable=False)
    assert all(isinstance(e, FusedSegment) and len(e.stages) == 1
               for e, p in off if p.target == "device")


def test_ffmap_device_lowering_fuses(plan):
    """A pure-splitter ffmap folds into the fused segment as a vmapped
    body (new device capability: host ffmap needs multi-emit nodes)."""
    import jax.numpy as jnp
    from repro.core import ffmap

    def split(x):
        return (x[:4], x[4:])

    def comp(parts):
        return jnp.concatenate(parts)

    def build():
        return pipeline(lambda x: x + 1.0,
                        ffmap(split, [lambda p: p * 2.0,
                                      lambda p: p - 3.0], comp),
                        lambda y: y * 0.5)
    xs = [np.arange(8, dtype=np.float32) * (i + 1) for i in range(5)]
    a = build().compile(plan, mode="device").run(xs)
    b = build().compile(plan, mode="device", fuse=False).run(xs)
    assert _bytes(a) == _bytes(b)
    expect = (np.concatenate([(xs[0] + 1.0)[:4] * 2.0,
                              (xs[0] + 1.0)[4:] - 3.0]) * 0.5)
    np.testing.assert_allclose(np.asarray(a[0]), expect, rtol=1e-6)


def test_ffmap_device_rejects_stateful_splitter(plan):
    from repro.core import GraphError, ffmap

    class Split(FFNode):
        def svc(self, t):
            self.ff_send_out(t)
            return None

    g = pipeline(ffmap(Split(), [lambda p: p], lambda parts: parts[0]))
    with pytest.raises(GraphError, match="pure splitter"):
        g.compile(plan, mode="device")


# ---------------------------------------------------------------------------
# the fused a2a kernel vs its oracle (bit-for-bit)
# ---------------------------------------------------------------------------
@pytest.mark.kernels
@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int32"])
@pytest.mark.parametrize("cap_kind", ["lossless", "overflow", "tight"])
def test_a2a_fused_matches_ref(dtype, cap_kind, rng):
    import jax
    import jax.numpy as jnp
    from repro.kernels.a2a_fused import a2a_fused
    from repro.kernels.ref import a2a_fused_ref

    T, E, D = 32, 3, 5
    k1, k2 = jax.random.split(rng)
    logits = jax.random.normal(k1, (T, E), jnp.float32)
    if dtype == "int32":
        xs = jax.random.randint(k2, (T, D), -50, 50, jnp.int32)
        fns = tuple((lambda x, s=j + 2: x * s + s) for j in range(E))
    else:
        xs = jax.random.normal(k2, (T, D)).astype(dtype)
        fns = tuple((lambda x, s=float(j + 1): x * s - s) for j in range(E))
    cap = {"lossless": T, "overflow": max(1, T // E - 3),
           "tight": 1}[cap_kind]
    out, keep = a2a_fused(logits, xs, fns, cap, block_t=8, interpret=True)
    # jit the oracle too: production always runs both inside a jitted
    # segment, and eager-mode op-by-op rounding differs from ANY jitted
    # program by FMA contraction (a 1-ulp artifact of eager mode, not of
    # the kernel)
    import functools
    ro, rk = jax.jit(functools.partial(a2a_fused_ref, expert_fns=fns,
                                       capacity=cap))(logits, xs)
    assert out.dtype == ro.dtype
    assert np.asarray(out).tobytes() == np.asarray(ro).tobytes()
    assert np.array_equal(np.asarray(keep), np.asarray(rk))
    if cap_kind == "lossless":
        assert bool(np.all(np.asarray(keep)))
    else:
        assert not bool(np.all(np.asarray(keep)))      # some tokens dropped
        assert np.all(np.asarray(out)[~np.asarray(keep)] == 0)


@pytest.mark.kernels
def test_a2a_fused_scalar_output_experts(rng):
    import jax
    import jax.numpy as jnp
    from repro.kernels.a2a_fused import a2a_fused
    from repro.kernels.ref import a2a_fused_ref

    T, E = 16, 2
    k1, k2 = jax.random.split(rng)
    logits = jax.random.normal(k1, (T, E))
    xs = jax.random.normal(k2, (T, 4))
    fns = (lambda x: jnp.sum(x), lambda x: jnp.prod(x))
    import functools
    out, keep = a2a_fused(logits, xs, fns, T, interpret=True)
    ro, rk = jax.jit(functools.partial(a2a_fused_ref, expert_fns=fns,
                                       capacity=T))(logits, xs)
    assert out.shape == (T,)
    assert np.asarray(out).tobytes() == np.asarray(ro).tobytes()


@pytest.mark.kernels
def test_a2a_fused_rejects_mismatched_experts(rng):
    import jax
    import jax.numpy as jnp
    from repro.kernels.a2a_fused import a2a_fused

    logits = jax.random.normal(rng, (8, 2))
    xs = jax.random.normal(rng, (8, 4))
    with pytest.raises(ValueError, match="agree on output"):
        a2a_fused(logits, xs, (lambda x: x, lambda x: jnp.sum(x)), 8)
    with pytest.raises(ValueError, match="experts"):
        a2a_fused(logits, xs, (lambda x: x,), 8)


# ---------------------------------------------------------------------------
# the jitted-segment cache
# ---------------------------------------------------------------------------
def test_recompile_reuses_jitted_segment(plan):
    segment_cache_clear()
    g = pipeline(*_dev_stages())
    xs = [np.float32(1.0), np.float32(2.0)]
    a = g.compile(plan, mode="device").run(xs)
    assert segment_cache_info()["misses"] >= 1
    before = segment_cache_info()["hits"]
    b = g.compile(plan, mode="device").run(xs)   # the Supervisor's re-place
    assert segment_cache_info()["hits"] > before
    assert _bytes(a) == _bytes(b)


def test_distinct_graphs_do_not_share_segments(plan):
    segment_cache_clear()
    xs = [np.float32(3.0)]
    a = pipeline(lambda x: x + 1.0).compile(plan, mode="device").run(xs)
    b = pipeline(lambda x: x + 2.0).compile(plan, mode="device").run(xs)
    assert float(a[0]) == 4.0 and float(b[0]) == 5.0
    assert segment_cache_info()["size"] >= 2
