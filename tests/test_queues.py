"""L1/L2 channel tests incl. hypothesis FIFO/linearizability properties."""

import threading

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # bare interpreter: deterministic cases still run
    given = settings = st = None

from repro.core.queues import (MPMCQueue, MPSCQueue, QueueClosed, SPMCQueue,
                               SPSCQueue)


def test_spsc_basic():
    q = SPSCQueue(8)
    assert q.empty()
    for i in range(7):
        assert q.try_push(i)
    assert not q.try_push(99)          # full at capacity-1
    got = [q.try_pop()[1] for _ in range(7)]
    assert got == list(range(7))
    assert q.try_pop() == (False, None)


def _check_spsc_fifo(ops, cap):
    """FIFO + no loss + no duplication under arbitrary interleaving."""
    q = SPSCQueue(cap)
    pushed, popped = [], []
    n = 0
    for op in ops:
        if op == "push":
            if q.try_push(n):
                pushed.append(n)
            n += 1
        else:
            ok, item = q.try_pop()
            if ok:
                popped.append(item)
    while True:
        ok, item = q.try_pop()
        if not ok:
            break
        popped.append(item)
    assert popped == pushed


def test_spsc_fifo_deterministic():
    _check_spsc_fifo(["push"] * 20 + ["pop"] * 25, 4)
    _check_spsc_fifo(["push", "push", "pop"] * 30, 2)
    _check_spsc_fifo(["push", "pop"] * 50, 16)
    _check_spsc_fifo(["pop", "pop", "push"] * 20, 3)


if st is not None:
    @given(st.lists(st.one_of(st.just("push"), st.just("pop")), max_size=200),
           st.integers(min_value=2, max_value=16))
    @settings(max_examples=50, deadline=None)
    def test_spsc_fifo_property(ops, cap):
        _check_spsc_fifo(ops, cap)
else:
    def test_spsc_fifo_property():
        pytest.importorskip("hypothesis")


def test_spsc_threaded_stream():
    q = SPSCQueue(16)
    N = 5000
    out = []

    def producer():
        for i in range(N):
            q.push(i)

    def consumer():
        for _ in range(N):
            out.append(q.pop())

    tp, tc = threading.Thread(target=producer), threading.Thread(target=consumer)
    tp.start(); tc.start(); tp.join(); tc.join()
    assert out == list(range(N))


def test_spmc_round_robin():
    q = SPMCQueue(3, 16)
    for i in range(9):
        q.push_rr(i)
    lanes = [[q.lanes[j].pop() for _ in range(3)] for j in range(3)]
    assert lanes[0] == [0, 3, 6]
    assert lanes[1] == [1, 4, 7]
    assert lanes[2] == [2, 5, 8]


def test_spmc_ondemand_prefers_short_lanes():
    q = SPMCQueue(2, 16)
    q.lanes[0].push("busy1")
    q.lanes[0].push("busy2")
    idx = q.push_ondemand("task", threshold=1)
    assert idx == 1


def test_mpsc_fair_drain():
    q = MPSCQueue(2, 8)
    q.lane(0).push("a0")
    q.lane(1).push("b0")
    q.lane(0).push("a1")
    got = [q.pop_any()[0] for _ in range(3)]
    assert set(got) == {"a0", "b0", "a1"}


def test_mpmc_routing():
    q = MPMCQueue(2, 2, 8)
    q.push(0, 1, "x")
    q.push(1, 1, "y")
    items = {q.pop(1)[0] for _ in range(2)}
    assert items == {"x", "y"}


# -- close propagation (PR 3 satellite) ----------------------------------------
def test_spsc_push_refused_on_closed_queue_with_space():
    q = SPSCQueue(8)
    q.push(1)
    q.close()
    assert len(q) == 1 and q.capacity == 7      # space remains...
    with pytest.raises(QueueClosed):
        q.push(2)                               # ...but the stream is ended
    assert q.pop() == 1                         # queued items still drain
    with pytest.raises(QueueClosed):
        q.pop()
    assert q.drained()


def test_spmc_close_all_propagates_to_lanes():
    q = SPMCQueue(3, 8)
    q.push_rr("a")
    q.close_all()
    with pytest.raises(QueueClosed):
        q.lanes[1].push("late")
    assert q.lanes[0].pop() == "a"
    for lane in q.lanes:
        with pytest.raises(QueueClosed):
            lane.pop()


def test_mpsc_pop_any_raises_queueclosed_after_drain():
    q = MPSCQueue(2, 8)
    q.lane(0).push("a")
    q.lane(1).push("b")
    q.close_all()
    got = {q.pop_any()[0], q.pop_any()[0]}      # drain first
    assert got == {"a", "b"}
    with pytest.raises(QueueClosed):            # then closed, not TimeoutError
        q.pop_any(timeout=5.0)


def test_mpmc_pop_raises_queueclosed_after_drain():
    q = MPMCQueue(2, 2, 8)
    q.push(0, 0, "x")
    q.close_all()
    assert q.pop(0)[0] == "x"
    with pytest.raises(QueueClosed):
        q.pop(0, timeout=5.0)
    # the other consumer's column is empty and closed too
    with pytest.raises(QueueClosed):
        q.pop(1, timeout=5.0)


def test_max_depth_high_water_mark():
    q = SPSCQueue(8)
    for i in range(5):
        q.push(i)
    for _ in range(5):
        q.pop()
    q.push(9)
    assert q.max_depth == 5
