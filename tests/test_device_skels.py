"""Device-side skeleton lowerings.  Multi-device cases run in a subprocess
with fake XLA devices (the main test process keeps 1 device)."""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.device import (expert_capacity, farm_map,
                               flash_decode_combine, feedback_scan,
                               tensor_map)


def test_farm_map_single_device(plan):
    f = farm_map(lambda x: x * 2, plan.mesh, axis="data")
    x = jnp.arange(8.0)
    np.testing.assert_array_equal(np.asarray(f(x)), np.arange(8.0) * 2)


def test_tensor_map_reduce(plan):
    f = tensor_map(lambda a, b: a @ b, plan.mesh, axis="model",
                   split_spec=(P(None, "model"), P("model", None)),
                   compose="reduce")
    a = jnp.ones((4, 8))
    b = jnp.ones((8, 4))
    np.testing.assert_allclose(np.asarray(f(a, b)), np.full((4, 4), 8.0))


def test_feedback_scan_decode_loop():
    def step(state):
        return state + 1, state * 10
    final, emitted = feedback_scan(step, jnp.asarray(0), 5)
    assert int(final) == 5
    np.testing.assert_array_equal(np.asarray(emitted), [0, 10, 20, 30, 40])


def test_expert_capacity_bounds():
    c = expert_capacity(1024, 8, 2, 1.25)
    assert c % 8 == 0 and 0 < c <= 1024
    assert expert_capacity(16, 64, 8, 1.0) >= 8     # floor


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.core.device import pipeline_shard, flash_decode_combine
    from jax.experimental.shard_map import shard_map

    mesh = make_mesh((4, 2), ("stage", "model"))

    # --- pipeline skeleton: 4 stages, affine stage fn, vs serial oracle ----
    S, M, F = 4, 8, 16
    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, F, F)) * 0.3
    bs = jnp.zeros((S, F))
    params = {"w": ws, "b": bs}
    x_mb = jax.random.normal(jax.random.PRNGKey(1), (M, 4, F))

    run = pipeline_shard(stage_fn, mesh, "stage", n_microbatches=M)
    got = run(params, x_mb)

    ref = x_mb
    for s in range(S):
        ref = jax.vmap(lambda xx: stage_fn({"w": ws[s], "b": bs[s]}, xx))(ref)
    ok_pipe = bool(jnp.allclose(got, ref, atol=1e-5))

    # --- flash-decode combine: sharded-KV partial softmax == full softmax --
    B, H, Sk, D = 2, 4, 64, 16
    q = jax.random.normal(jax.random.PRNGKey(2), (B, H, D))
    k = jax.random.normal(jax.random.PRNGKey(3), (B, Sk, H, D))
    v = jax.random.normal(jax.random.PRNGKey(4), (B, Sk, H, D))

    def local_attn(q, kl, vl):
        s = jnp.einsum("bhd,bkhd->bhk", q, kl) / jnp.sqrt(D)
        m = jnp.max(s, -1)
        p = jnp.exp(s - m[..., None])
        out = jnp.einsum("bhk,bkhd->bhd", p, vl) / jnp.maximum(
            jnp.sum(p, -1), 1e-30)[..., None]
        lse = jnp.log(jnp.sum(p, -1)) + m
        return flash_decode_combine(out, lse, "model")

    f = shard_map(local_attn, mesh=mesh,
                  in_specs=(P(), P(None, "model", None, None),
                            P(None, "model", None, None)),
                  out_specs=P(), check_rep=False)
    got2 = f(q, k, v)
    s = jnp.einsum("bhd,bkhd->bhk", q, k) / jnp.sqrt(D)
    p = jax.nn.softmax(s, -1)
    ref2 = jnp.einsum("bhk,bkhd->bhd", p, v)
    ok_fd = bool(jnp.allclose(got2, ref2, atol=1e-5))

    print(json.dumps({"pipe": ok_pipe, "flash_decode": ok_fd}))
""")


@pytest.mark.slow
def test_multi_device_pipeline_and_flash_decode():
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, cwd=".",
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["pipe"], "pipeline skeleton mismatch vs serial oracle"
    assert res["flash_decode"], "flash-decode combine mismatch"
