"""Shared-memory ring tests (core/shm.py) — the L1/L2 channels of the
process-backed host tier."""

import multiprocessing as mp
import os
import time

import numpy as np
import pytest

from repro.core.node import EOS
from repro.core.queues import QueueClosed
from repro.core.shm import (ShmError, ShmMPMCGrid, ShmMPSCQueue,
                            ShmSPMCQueue, ShmSPSCQueue)

_CTX = mp.get_context("fork" if "fork" in mp.get_all_start_methods()
                      else "spawn")


def test_shm_roundtrip_payload_kinds():
    q = ShmSPSCQueue(8, 1 << 12)
    try:
        q.push({"a": 1, "b": [2, 3]})               # pickle fallback
        q.push(np.arange(10, dtype=np.float32).reshape(2, 5))  # raw slab
        q.push(np.int64(7))                         # numpy scalar -> pickle
        q.push_eos()
        assert q.pop() == {"a": 1, "b": [2, 3]}
        arr = q.pop()
        assert arr.dtype == np.float32 and arr.shape == (2, 5)
        np.testing.assert_array_equal(
            arr, np.arange(10, dtype=np.float32).reshape(2, 5))
        assert q.pop() == np.int64(7)
        assert q.pop() is EOS                       # identity survives
    finally:
        q.destroy()


def test_shm_fifo_and_capacity():
    q = ShmSPSCQueue(4, 1 << 10)
    try:
        assert q.capacity == 3
        for i in range(3):
            assert q.try_push(i)
        assert not q.try_push(99)                   # full at capacity-1
        assert [q.try_pop()[1] for _ in range(3)] == [0, 1, 2]
        assert q.try_pop() == (False, None)
    finally:
        q.destroy()


def test_shm_oversize_item_raises():
    q = ShmSPSCQueue(4, 256)
    try:
        with pytest.raises(ValueError):
            q.try_push(np.zeros(1024, dtype=np.float64))
        with pytest.raises(ValueError):
            q.try_push(b"x" * 4096)
    finally:
        q.destroy()


def test_shm_close_semantics_match_thread_tier():
    q = ShmSPSCQueue(8, 1 << 10)
    try:
        q.push(1)
        q.close()
        with pytest.raises(QueueClosed):
            q.push(2)                   # refused even though slots remain
        assert q.pop() == 1             # drain what was queued
        with pytest.raises(QueueClosed):
            q.pop()
        assert q.drained()
    finally:
        q.destroy()


def test_shm_mpsc_close_all_raises_after_drain():
    m = ShmMPSCQueue(2, 8, 1 << 10)
    try:
        m.lane(0).push("a")
        m.close_all()
        assert m.pop_any()[0] == "a"
        with pytest.raises(QueueClosed):
            m.pop_any()
    finally:
        m.destroy()


def _echo_child(in_lane, out_lane):
    while True:
        item = in_lane.pop()
        if item is EOS:
            break
        out_lane.push(item)
    out_lane.push_eos()


@pytest.mark.shm
def test_shm_ring_cross_process_fifo():
    inq, outq = ShmSPSCQueue(16, 1 << 12), ShmSPSCQueue(16, 1 << 12)
    p = _CTX.Process(target=_echo_child, args=(inq, outq), daemon=True)
    p.start()
    try:
        n = 200
        sent = recv = 0
        got = []
        deadline = time.monotonic() + 30
        while recv < n:
            if sent < n and inq.try_push(sent):
                sent += 1
            ok, item = outq.try_pop()
            if ok:
                got.append(item)
                recv += 1
            assert time.monotonic() < deadline, "echo stalled"
        assert got == list(range(n))
        inq.push_eos()
        assert outq.pop(timeout=10.0) is EOS
        p.join(timeout=10.0)
        assert not p.is_alive()
    finally:
        if p.is_alive():
            p.terminate()
        inq.destroy()
        outq.destroy()


@pytest.mark.shm
def test_shm_spmc_fans_out_over_core_count_processes():
    """Exercise the L2 SPMC/MPSC composition with one worker process per
    actual core (the runner's real width)."""
    n_workers = max(2, os.cpu_count() or 2)
    spmc = ShmSPMCQueue(n_workers, 16, 1 << 12)
    mpsc = ShmMPSCQueue(n_workers, 16, 1 << 12)
    procs = [_CTX.Process(target=_echo_child,
                          args=(spmc.lanes[i], mpsc.lanes[i]), daemon=True)
             for i in range(n_workers)]
    for p in procs:
        p.start()
    try:
        # feed and drain interleaved: the rings are bounded (capacity 16),
        # so pushing the whole stream before draining would deadlock —
        # exactly the back-pressure the fixed-slot design is meant to exert
        n = 40 * n_workers
        sent = 0
        got = []
        deadline = time.monotonic() + 60
        while len(got) < n:
            if sent < n and spmc.lanes[sent % n_workers].try_push(
                    np.float64(sent)):
                sent += 1
            ok, item, _lane = mpsc.try_pop_any()
            if ok and item is not EOS:
                got.append(float(item))
            assert time.monotonic() < deadline, "fan-out stalled"
        assert sorted(got) == [float(i) for i in range(n)]
        spmc.broadcast_eos()
        eos = 0
        while eos < n_workers:
            if mpsc.pop_any(timeout=10.0)[0] is EOS:
                eos += 1
        for p in procs:
            p.join(timeout=10.0)
            assert not p.is_alive()
    finally:
        for p in procs:
            if p.is_alive():
                p.terminate()
        spmc.destroy()
        mpsc.destroy()


@pytest.mark.shm
def test_shm_queue_pickles_to_same_segment():
    import pickle
    q = ShmSPSCQueue(8, 1 << 10)
    try:
        q.push("hello")
        q2 = pickle.loads(pickle.dumps(q))
        assert q2.name == q.name
        assert q2.pop() == "hello"      # same ring, attached by name
        q2.detach()
    finally:
        q.destroy()


def test_shm_structured_and_object_dtypes_take_pickle_path():
    q = ShmSPSCQueue(8, 1 << 12)
    try:
        rec = np.zeros(4, dtype=[("x", "f4"), ("y", "i4")])
        rec["x"] = [1, 2, 3, 4]
        q.push(rec)
        got = q.pop()
        assert got.dtype.names == ("x", "y")        # field names survive
        np.testing.assert_array_equal(got["x"], rec["x"])
        obj = np.array([{"a": 1}, None], dtype=object)
        q.push(obj)
        got = q.pop()
        assert got.dtype.kind == "O" and got[0] == {"a": 1}
    finally:
        q.destroy()


def test_shm_error_record_roundtrip():
    q = ShmSPSCQueue(4, 1 << 12)
    try:
        q.push_err(ShmError(3, "ValueError('x')", "tb"))
        got = q.pop()
        assert isinstance(got, ShmError)
        assert got.worker == 3 and "ValueError" in got.exc
    finally:
        q.destroy()


# -- sequence numbers in the slot header ----------------------------------------
def test_shm_seq_rides_the_slot_header_on_both_payload_paths():
    q = ShmSPSCQueue(8, 1 << 12)
    try:
        q.push({"k": 1}, seq=41)                        # pickle path
        q.push(np.arange(6, dtype=np.float32), seq=42)  # raw-slab path
        item, seq = q.pop_seq()
        assert item == {"k": 1} and seq == 41
        item, seq = q.pop_seq()
        np.testing.assert_array_equal(item, np.arange(6, dtype=np.float32))
        assert seq == 42
        # seq-less pop still works (farm protocol unchanged)
        q.push("plain")
        assert q.pop() == "plain"
    finally:
        q.destroy()


def test_shm_push_eos_raises_on_closed_lane():
    # the a2a EOS fan-out must unwind (not wedge) on a lane the parent
    # closed because its consumer died
    q = ShmSPSCQueue(4, 1 << 10)
    try:
        q.close()
        with pytest.raises(QueueClosed):
            q.push_eos(timeout=1.0)
    finally:
        q.destroy()


# -- the MPMC lane grid ----------------------------------------------------------
def test_shm_mpmc_grid_routes_rows_to_columns():
    g = ShmMPMCGrid(2, 3, 8, 1 << 10)
    try:
        g.push(0, 2, "a", seq=1)
        g.push(1, 2, "b", seq=2)
        g.push(0, 0, "c", seq=3)
        # column 2 drains fairly across its two producer lanes
        got = {g.pop(2, timeout=5.0) for _ in range(2)}
        assert got == {("a", 0, 1), ("b", 1, 2)}
        assert g.pop(0, timeout=5.0) == ("c", 0, 3)
        ok, _, _, _ = g.try_pop(1)
        assert not ok
    finally:
        g.destroy()


def test_shm_mpmc_grid_close_all_raises_after_drain():
    g = ShmMPMCGrid(2, 2, 8, 1 << 10)
    try:
        g.push(0, 0, "x")
        g.close_all()
        assert g.pop(0, timeout=5.0)[0] == "x"
        with pytest.raises(QueueClosed):
            g.pop(0, timeout=5.0)
        with pytest.raises(QueueClosed):
            g.push(0, 1, "y")
    finally:
        g.destroy()


def _grid_producer_child(i, row_lanes, n_items):
    # producer i owns row i: route item k to column k % n_cols, seq rides
    for k in range(n_items):
        row_lanes[k % len(row_lanes)].push(np.float64(i * 1000 + k),
                                           seq=i * 1000 + k)
    for lane in row_lanes:
        lane.push_eos()


@pytest.mark.shm
def test_shm_mpmc_grid_cross_process_fan_in_fan_out():
    nP, nC, n_items = 2, 2, 60
    g = ShmMPMCGrid(nP, nC, 8, 1 << 10)
    procs = [_CTX.Process(target=_grid_producer_child,
                          args=(i, g.row(i), n_items), daemon=True)
             for i in range(nP)]
    for p in procs:
        p.start()
    try:
        got = []
        eos = 0
        deadline = time.monotonic() + 60
        while eos < nP * nC:
            for j in range(nC):
                ok, item, prod, seq = g.try_pop(j)
                if not ok:
                    continue
                if item is EOS:
                    eos += 1
                else:
                    got.append((j, prod, float(item), seq))
            assert time.monotonic() < deadline, "grid fan-in stalled"
        assert len(got) == nP * n_items
        for j, prod, v, seq in got:
            assert v == seq                      # seq survived the wire
            assert int(v) % nC == j              # landed in the routed column
            assert int(v) // 1000 == prod        # came from the owning row
        for p in procs:
            p.join(timeout=10.0)
            assert p.exitcode == 0
    finally:
        for p in procs:
            if p.is_alive():
                p.terminate()
        g.destroy()
