"""Shared-memory ring tests (core/shm.py) — the L1/L2 channels of the
process-backed host tier."""

import multiprocessing as mp
import os
import time

import numpy as np
import pytest

from repro.core.node import EOS
from repro.core.queues import QueueClosed
from repro.core.shm import (ShmError, ShmMPSCQueue, ShmSPMCQueue,
                            ShmSPSCQueue)

_CTX = mp.get_context("fork" if "fork" in mp.get_all_start_methods()
                      else "spawn")


def test_shm_roundtrip_payload_kinds():
    q = ShmSPSCQueue(8, 1 << 12)
    try:
        q.push({"a": 1, "b": [2, 3]})               # pickle fallback
        q.push(np.arange(10, dtype=np.float32).reshape(2, 5))  # raw slab
        q.push(np.int64(7))                         # numpy scalar -> pickle
        q.push_eos()
        assert q.pop() == {"a": 1, "b": [2, 3]}
        arr = q.pop()
        assert arr.dtype == np.float32 and arr.shape == (2, 5)
        np.testing.assert_array_equal(
            arr, np.arange(10, dtype=np.float32).reshape(2, 5))
        assert q.pop() == np.int64(7)
        assert q.pop() is EOS                       # identity survives
    finally:
        q.destroy()


def test_shm_fifo_and_capacity():
    q = ShmSPSCQueue(4, 1 << 10)
    try:
        assert q.capacity == 3
        for i in range(3):
            assert q.try_push(i)
        assert not q.try_push(99)                   # full at capacity-1
        assert [q.try_pop()[1] for _ in range(3)] == [0, 1, 2]
        assert q.try_pop() == (False, None)
    finally:
        q.destroy()


def test_shm_oversize_item_raises():
    q = ShmSPSCQueue(4, 256)
    try:
        with pytest.raises(ValueError):
            q.try_push(np.zeros(1024, dtype=np.float64))
        with pytest.raises(ValueError):
            q.try_push(b"x" * 4096)
    finally:
        q.destroy()


def test_shm_close_semantics_match_thread_tier():
    q = ShmSPSCQueue(8, 1 << 10)
    try:
        q.push(1)
        q.close()
        with pytest.raises(QueueClosed):
            q.push(2)                   # refused even though slots remain
        assert q.pop() == 1             # drain what was queued
        with pytest.raises(QueueClosed):
            q.pop()
        assert q.drained()
    finally:
        q.destroy()


def test_shm_mpsc_close_all_raises_after_drain():
    m = ShmMPSCQueue(2, 8, 1 << 10)
    try:
        m.lane(0).push("a")
        m.close_all()
        assert m.pop_any()[0] == "a"
        with pytest.raises(QueueClosed):
            m.pop_any()
    finally:
        m.destroy()


def _echo_child(in_lane, out_lane):
    while True:
        item = in_lane.pop()
        if item is EOS:
            break
        out_lane.push(item)
    out_lane.push_eos()


@pytest.mark.shm
def test_shm_ring_cross_process_fifo():
    inq, outq = ShmSPSCQueue(16, 1 << 12), ShmSPSCQueue(16, 1 << 12)
    p = _CTX.Process(target=_echo_child, args=(inq, outq), daemon=True)
    p.start()
    try:
        n = 200
        sent = recv = 0
        got = []
        deadline = time.monotonic() + 30
        while recv < n:
            if sent < n and inq.try_push(sent):
                sent += 1
            ok, item = outq.try_pop()
            if ok:
                got.append(item)
                recv += 1
            assert time.monotonic() < deadline, "echo stalled"
        assert got == list(range(n))
        inq.push_eos()
        assert outq.pop(timeout=10.0) is EOS
        p.join(timeout=10.0)
        assert not p.is_alive()
    finally:
        if p.is_alive():
            p.terminate()
        inq.destroy()
        outq.destroy()


@pytest.mark.shm
def test_shm_spmc_fans_out_over_core_count_processes():
    """Exercise the L2 SPMC/MPSC composition with one worker process per
    actual core (the runner's real width)."""
    n_workers = max(2, os.cpu_count() or 2)
    spmc = ShmSPMCQueue(n_workers, 16, 1 << 12)
    mpsc = ShmMPSCQueue(n_workers, 16, 1 << 12)
    procs = [_CTX.Process(target=_echo_child,
                          args=(spmc.lanes[i], mpsc.lanes[i]), daemon=True)
             for i in range(n_workers)]
    for p in procs:
        p.start()
    try:
        # feed and drain interleaved: the rings are bounded (capacity 16),
        # so pushing the whole stream before draining would deadlock —
        # exactly the back-pressure the fixed-slot design is meant to exert
        n = 40 * n_workers
        sent = 0
        got = []
        deadline = time.monotonic() + 60
        while len(got) < n:
            if sent < n and spmc.lanes[sent % n_workers].try_push(
                    np.float64(sent)):
                sent += 1
            ok, item, _lane = mpsc.try_pop_any()
            if ok and item is not EOS:
                got.append(float(item))
            assert time.monotonic() < deadline, "fan-out stalled"
        assert sorted(got) == [float(i) for i in range(n)]
        spmc.broadcast_eos()
        eos = 0
        while eos < n_workers:
            if mpsc.pop_any(timeout=10.0)[0] is EOS:
                eos += 1
        for p in procs:
            p.join(timeout=10.0)
            assert not p.is_alive()
    finally:
        for p in procs:
            if p.is_alive():
                p.terminate()
        spmc.destroy()
        mpsc.destroy()


@pytest.mark.shm
def test_shm_queue_pickles_to_same_segment():
    import pickle
    q = ShmSPSCQueue(8, 1 << 10)
    try:
        q.push("hello")
        q2 = pickle.loads(pickle.dumps(q))
        assert q2.name == q.name
        assert q2.pop() == "hello"      # same ring, attached by name
        q2.detach()
    finally:
        q.destroy()


def test_shm_structured_and_object_dtypes_take_pickle_path():
    q = ShmSPSCQueue(8, 1 << 12)
    try:
        rec = np.zeros(4, dtype=[("x", "f4"), ("y", "i4")])
        rec["x"] = [1, 2, 3, 4]
        q.push(rec)
        got = q.pop()
        assert got.dtype.names == ("x", "y")        # field names survive
        np.testing.assert_array_equal(got["x"], rec["x"])
        obj = np.array([{"a": 1}, None], dtype=object)
        q.push(obj)
        got = q.pop()
        assert got.dtype.kind == "O" and got[0] == {"a": 1}
    finally:
        q.destroy()


def test_shm_error_record_roundtrip():
    q = ShmSPSCQueue(4, 1 << 12)
    try:
        q.push_err(ShmError(3, "ValueError('x')", "tb"))
        got = q.pop()
        assert isinstance(got, ShmError)
        assert got.worker == 3 and "ValueError" in got.exc
    finally:
        q.destroy()
