"""Inject the generated roofline table + perf log into EXPERIMENTS.md.

    PYTHONPATH=src python tools/update_experiments.py
"""

import json
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from benchmarks.roofline import load, table  # noqa: E402


def main():
    exp = (ROOT / "EXPERIMENTS.md").read_text()
    rows = load("sp")
    md = table(rows, "md")
    n_ok = sum(1 for r in rows if r.get("ok"))
    n_skip = sum(1 for r in rows if r.get("skipped"))
    header = (f"\n*{n_ok} compiled cells + {n_skip} documented skips "
              f"(single-pod 16x16; per-chip peak vs 16 GiB HBM).*\n\n")
    exp = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n## |\Z)",
                 "<!-- ROOFLINE_TABLE -->" + header + md + "\n\n",
                 exp, flags=re.S)
    (ROOT / "EXPERIMENTS.md").write_text(exp)
    print(f"injected roofline table ({n_ok} ok, {n_skip} skip)")


if __name__ == "__main__":
    main()
