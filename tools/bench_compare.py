#!/usr/bin/env python
"""Gate CI on benchmark regressions: compare a fresh ``BENCH_graph.json``
against the committed ``benchmarks/BENCH_baseline.json``.

Gated fields, by shape:

- ``items_per_s`` and ``goodput_items_per_s`` (the serving bench's
  finished-requests-per-second under 2x-overload Poisson replay — higher
  is better) and ``ratio_best`` (the best demonstrated pair ratio of an
  interleaved comparison run — process-vs-thread farm/a2a, vectored-vs-
  per-item shm lane, fused-vs-per-stage device segments, async-window-vs-
  sync device boundary — higher is better) fail below
  ``(1 - max_regression)`` of the baseline;
- ``reconfig_latency_ms`` (lower is better — the adaptive runtime's live
  drain-and-swap cost), ``net_rtt_us`` (lower is better — the distributed
  tier's loopback lane round-trip, the per-item price of leaving the
  host), and ``latency_ms`` (the serving bench's p50 admitted-request
  latency under overload — lower is better) fail above
  ``(1 + max_latency_increase)`` of the baseline; the default bound is
  generous (2.0 = 3x) because the swap forks worker processes and the
  loopback RTT rides the kernel scheduler, both noisy on shared hosts.
  Latency fields are machine-normalized the same way throughput is
  (divided by the reference metric's speed ratio).

Raw ``us_per_call`` latencies are deliberately ignored.  Two mechanisms
keep the gate from flapping on heterogeneous/noisy CI runners:

- ``ratio_best`` values are machine-relative by construction (best of
  interleaved thread-vs-process pairs, both sides sharing the same noise
  phases), so they are compared raw;
- absolute ``items_per_s`` values are first *normalized by a reference
  metric* (default: ``graph_pipeline_host``, the single-threaded host
  pipeline) measured in both runs — a uniformly faster or slower runner
  divides out, and only metrics that moved relative to the machine's own
  speed can trip the gate.

A metric fails when its (normalized) value lands below
``(1 - max_regression)`` of the baseline (default: a >30% regression
fails).

Usage::

    python tools/bench_compare.py BENCH_graph.json benchmarks/BENCH_baseline.json
    python tools/bench_compare.py NEW BASELINE --max-regression 0.30
    python tools/bench_compare.py NEW BASELINE --update   # rewrite baseline

Exit status: 0 when every shared metric holds (or only informational
differences exist), 1 on any regression past the threshold, 2 on unusable
input files.
"""

from __future__ import annotations

import argparse
import json
import sys

DEFAULT_REFERENCE = "graph_pipeline_host"


def load(path: str) -> dict:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench-compare: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    results = doc.get("results")
    if not isinstance(results, dict):
        print(f"bench-compare: {path} has no 'results' table",
              file=sys.stderr)
        sys.exit(2)
    return results


def _ref_scale(new: dict, base: dict, reference: str) -> tuple[float, str]:
    """baseline/new speed ratio of the reference metric (1.0 = same-speed
    machine), or 1.0 with a warning when either run lacks it."""
    try:
        n_ref = float(new[reference]["items_per_s"])
        b_ref = float(base[reference]["items_per_s"])
        if n_ref > 0 and b_ref > 0:
            return b_ref / n_ref, (f"machine-speed normalization via "
                                   f"{reference}: x{b_ref / n_ref:.3f}")
    except (KeyError, TypeError, ValueError):
        pass
    return 1.0, (f"reference metric {reference!r} missing — comparing "
                 "absolute throughput (cross-machine noise not divided out)")


def compare(new: dict, base: dict, max_regression: float,
            reference: str,
            max_latency_increase: float = 2.0) -> list:
    """Compare every gated metric; returns the list of failing metric names
    (ALL of them — one run reports the full damage, never just the first
    regression encountered)."""
    scale, note = _ref_scale(new, base, reference)
    print(f"bench-compare: {note}")
    failed = []
    rows = []
    for name in sorted(set(new) | set(base)):
        n_rec, b_rec = new.get(name), base.get(name)
        if n_rec is None:
            # a metric the baseline knows but this run did not record: a
            # silently dropped bench would otherwise un-gate itself
            rows.append((name, "-", "MISSING from new run", "FAIL"))
            failed.append(name)
            continue
        if b_rec is None:
            rows.append((name, "-", "new metric (no baseline)", "info"))
            continue
        # (field, machine-speed normalization, higher-is-better?)
        for field, norm, hib in (("items_per_s", scale, True),
                                 ("goodput_items_per_s", scale, True),
                                 ("ratio_best", 1.0, True),
                                 ("reconfig_latency_ms", 1.0 / scale, False),
                                 ("net_rtt_us", 1.0 / scale, False),
                                 ("latency_ms", 1.0 / scale, False)):
            if field not in n_rec or field not in b_rec:
                continue
            if field == "items_per_s" and name == reference:
                rows.append((f"{name}.{field}",
                             f"{float(b_rec[field]):g} -> "
                             f"{float(n_rec[field]):g}",
                             "reference metric", "info"))
                continue
            b_val = float(b_rec[field])
            n_val = float(n_rec[field])
            if b_val <= 0:
                continue
            rel = (n_val * norm) / b_val
            status = "ok"
            if hib and rel < 1.0 - max_regression:
                status = "FAIL"
            elif not hib and rel > 1.0 + max_latency_increase:
                status = "FAIL"
            if status == "FAIL":
                failed.append(f"{name}.{field}")
            rows.append((f"{name}.{field}",
                         f"{b_val:g} -> {n_val:g}",
                         f"{(rel - 1.0) * 100:+.1f}% normalized", status))
    width = max((len(r[0]) for r in rows), default=10)
    for name, vals, delta, status in rows:
        print(f"  {name:<{width}}  {vals:>24}  {delta:>26}  [{status}]")
    return failed


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("new", help="fresh bench JSON (BENCH_graph.json)")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--max-regression", type=float, default=0.30,
                    help="relative (normalized) throughput drop that fails "
                         "the gate (default 0.30 = 30%%)")
    ap.add_argument("--reference", default=DEFAULT_REFERENCE,
                    help="metric whose items_per_s serves as the machine-"
                         "speed yardstick both runs are normalized by "
                         f"(default: {DEFAULT_REFERENCE})")
    ap.add_argument("--max-latency-increase", type=float, default=2.0,
                    help="relative (normalized) growth of a lower-is-better "
                         "latency metric (reconfig_latency_ms) that fails "
                         "the gate (default 2.0 = fails above 3x baseline)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline file from the new run "
                         "instead of gating")
    args = ap.parse_args()

    if args.update:
        with open(args.new) as f:
            doc = json.load(f)
        with open(args.baseline, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"bench-compare: baseline {args.baseline} updated from "
              f"{args.new}")
        return

    new, base = load(args.new), load(args.baseline)
    print(f"bench-compare: {args.new} vs {args.baseline} "
          f"(fail below {(1 - args.max_regression) * 100:.0f}% of baseline)")
    failed = compare(new, base, args.max_regression, args.reference,
                     args.max_latency_increase)
    if failed:
        print(f"bench-compare: {len(failed)} metric(s) regressed past "
              f"tolerance — failing the gate: {', '.join(failed)}",
              file=sys.stderr)
        print("bench-compare: if this change is intended (new tradeoff, "
              "new hardware), refresh the baseline with:\n"
              f"  python tools/bench_compare.py {args.new} {args.baseline} "
              "--update", file=sys.stderr)
        sys.exit(1)
    print("bench-compare: all gated metrics within tolerance")


if __name__ == "__main__":
    main()
