"""Optimizers: AdamW (fp32 moments) and Adafactor (factored second moment,
bf16-friendly — used for the >=100B configs so optimizer state fits 16 GB/chip;
see DESIGN.md §5).

Self-contained (no optax dependency), pytree-structured, shard-friendly:
every state leaf inherits its parameter's sharding (factored Adafactor stats
drop the corresponding dim's axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), grads), gn


@dataclasses.dataclass
class Optimizer:
    init: Callable
    update: Callable          # (grads, state, params, lr) -> (params, state)
    state_axes: Callable      # param_defs -> state logical-axes tree


# ---------------------------------------------------------------------------
def AdamW(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        b1c = 1 - b1 ** c.astype(jnp.float32)
        b2c = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
            if p.ndim >= 2:   # decoupled weight decay on matrices only
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p
               in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "count": c}

    def state_axes(param_defs):
        from ..models.params import ParamDef, is_def
        ax = lambda d: jax.tree.map(
            lambda dd: tuple(dd.axes), param_defs, is_leaf=is_def)
        return {"m": ax(param_defs), "v": ax(param_defs), "count": ()}

    return Optimizer(init, update, state_axes)


# ---------------------------------------------------------------------------
def Adafactor(eps=1e-30, clip_threshold=1.0, decay=0.8,
              weight_decay=0.0, min_dim_factored=128) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern, 2018).  Matrices
    with both trailing dims >= min_dim_factored get row/col factored stats;
    everything else falls back to a full fp32 second moment."""

    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored \
            and p.shape[-2] >= min_dim_factored

    def init(params):
        def st(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"s": jax.tree.map(st, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        beta = 1.0 - (c.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, -1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, -2)
                # V ~= (vr / mean(vr)) outer vc  (Shazeer & Stern eq. 4)
                vr_n = vr / jnp.maximum(jnp.mean(vr, -1, keepdims=True), eps)
                step = g * jax.lax.rsqrt(vr_n + eps)[..., None] \
                         * jax.lax.rsqrt(vc + eps)[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                step = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(step * step) + 1e-30)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and p.ndim >= 2:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["s"])
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_s = tdef.unflatten([o[1] for o in out])
        return new_p, {"s": new_s, "count": c}

    def state_axes(param_defs):
        from ..models.params import is_def
        def st(d):
            shape, axes = d.shape, tuple(d.axes)
            if len(shape) >= 2 and shape[-1] >= min_dim_factored \
                    and shape[-2] >= min_dim_factored:
                return {"vr": axes[:-1], "vc": axes[:-2] + axes[-1:]}
            return {"v": axes}
        return {"s": jax.tree.map(st, param_defs, is_leaf=is_def),
                "count": ()}

    return Optimizer(init, update, state_axes)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return AdamW(**kw)
    if name == "adafactor":
        return Adafactor(**kw)
    raise ValueError(name)
