"""Gradient compression for cross-pod (DCI) reduction.

int8 stochastic-free symmetric quantization with per-tensor scale + error
feedback (the residual is carried in the optimizer state and re-added next
step), shrinking the pod-axis all-reduce 4x on bf16 / 2x on fp32 grads.
Compression happens *before* the pod all-reduce and decompression after —
wired in runtime/steps.py when the mesh has a 'pod' axis and
``grad_compression='int8'``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(x):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads, errors):
    """Error-feedback compression: returns (quantized tree as fp32 values
    ready for all-reduce, new error tree).  The quantization error
    (g+e) - deq(q) is fed back next step, preserving convergence."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = int8_compress(gf)
        deq = int8_decompress(q, s)
        return deq.astype(g.dtype), gf - deq
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])
