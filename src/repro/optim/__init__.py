from .optimizers import (AdamW, Adafactor, Optimizer, make_optimizer,
                         clip_by_global_norm)
from .schedules import cosine_warmup, linear_warmup
from .compression import int8_compress, int8_decompress, ef_compress_grads

__all__ = ["AdamW", "Adafactor", "Optimizer", "make_optimizer",
           "clip_by_global_norm", "cosine_warmup", "linear_warmup",
           "int8_compress", "int8_decompress", "ef_compress_grads"]
