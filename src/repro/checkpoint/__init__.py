from .ckpt import (CheckpointManager, load_checkpoint, save_checkpoint,
                   latest_step)
from .reshard import reshard_state

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "latest_step", "reshard_state"]
