"""Sharded, atomic, async checkpointing.

Layout:  <dir>/step_<N>/
            manifest.json          tree structure + dtypes + shapes + extras
            arr_<i>.npy            one file per leaf (host-gathered)
         <dir>/step_<N>.tmp...     staged then os.replace()'d — a crash mid-
                                   save never corrupts the latest checkpoint.

Async: ``save_async`` snapshots leaves to host memory synchronously (cheap,
device->host copy) and writes files on a background thread — the SPSC
double-buffer idea again: the training loop never blocks on the filesystem.

On restore, arrays are ``jax.device_put`` against the *current* mesh's
shardings — combined with checkpoint/reshard.py this gives elastic restart
on a different mesh shape (DESIGN.md §8).
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(directory, step: int, state, extras: Optional[dict] = None,
                    keep: int = 3) -> pathlib.Path:
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(state)
    def to_host(l):
        a = np.asarray(jax.device_get(l))
        # non-native dtypes (bfloat16, fp8) -> widen losslessly for .npy
        if a.dtype.kind == "V" or str(a.dtype) in ("bfloat16",):
            a = a.astype(np.float32)
        return a
    host = [to_host(l) for l in leaves]
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(state).serialize_using_proto().hex()
        if hasattr(treedef, "serialize_using_proto") else None,
        "tree_repr": str(treedef),
        "n_leaves": len(host),
        "dtypes": [str(a.dtype) for a in host],
        "shapes": [list(a.shape) for a in host],
        "extras": extras or {},
        "time": time.time(),
    }
    for i, a in enumerate(host):
        np.save(tmp / f"arr_{i}.npy", a)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic publish
    _gc_old(directory, keep)
    return final


def _gc_old(directory: pathlib.Path, keep: int) -> None:
    steps = sorted(p for p in directory.glob("step_????????")
                   if p.is_dir() and not p.name.endswith(".tmp"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    steps = sorted(p.name for p in directory.glob("step_????????"))
    if not steps:
        return None
    return int(steps[-1].split("_")[1])


def load_checkpoint(directory, state_like, step: Optional[int] = None,
                    shardings=None):
    """Restore into the structure of ``state_like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree of
    NamedShardings for the *current* mesh (elastic restart)."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(state_like)
    assert manifest["n_leaves"] == len(leaves), \
        (manifest["n_leaves"], len(leaves))
    arrays = [np.load(d / f"arr_{i}.npy") for i in range(len(leaves))]
    # cast through jnp (handles bfloat16 and other ml_dtypes)
    arrays = [jax.numpy.asarray(a, dtype=l.dtype)
              for a, l in zip(arrays, leaves)]
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
    return treedef.unflatten(arrays), manifest.get("extras", {})


class CheckpointManager:
    """Background (async) saver with double buffering + restore helper."""

    def __init__(self, directory, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None
        self.error: Optional[BaseException] = None

    def save_async(self, step: int, state, extras: Optional[dict] = None):
        self.wait()                          # one in flight at a time
        # snapshot to host NOW (state may be donated/mutated next step)
        host_state = jax.tree.map(lambda l: np.asarray(jax.device_get(l)),
                                  state)

        def work():
            try:
                save_checkpoint(self.directory, step, host_state, extras,
                                self.keep)
                self.last_saved = step
            except BaseException as e:       # noqa: BLE001
                self.error = e

        self._thread = threading.Thread(target=work, daemon=True,
                                        name="ckpt-saver")
        self._thread.start()

    def save(self, step: int, state, extras: Optional[dict] = None):
        save_checkpoint(self.directory, step, state, extras, self.keep)
        self.last_saved = step

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            e, self.error = self.error, None
            raise e

    def restore(self, state_like, step: Optional[int] = None, shardings=None):
        return load_checkpoint(self.directory, state_like, step, shardings)

    def latest(self) -> Optional[int]:
        return latest_step(self.directory)
