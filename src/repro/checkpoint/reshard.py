"""Elastic resharding: restore any checkpoint onto any mesh.

Checkpoints store full (host-gathered) arrays, so resharding is just
``device_put`` with the new plan's shardings — shrink 512 -> 256 chips or
grow 256 -> 512 without conversion tools.  For states whose *structure*
depends on the mesh (none of ours do — factored Adafactor stats are
mesh-independent) a transform hook is provided.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax

from ..core.plan import ShardingPlan
from ..runtime.steps import state_shardings, state_structs


def reshard_state(cfg, old_state_host, new_plan: ShardingPlan,
                  transform: Optional[Callable] = None):
    """old_state_host: pytree of host numpy arrays (from load_checkpoint
    without shardings).  Returns the state placed on new_plan's mesh."""
    if transform is not None:
        old_state_host = transform(old_state_host)
    sh = state_shardings(cfg, new_plan)
    leaves, treedef = jax.tree.flatten(old_state_host)
    sh_leaves = treedef.flatten_up_to(sh)
    placed = [jax.device_put(a, s) for a, s in zip(leaves, sh_leaves)]
    return treedef.unflatten(placed)
