"""Device-side skeleton lowering: the FastFlow patterns expressed as SPMD
programs over a TPU mesh.

==================  ==========================================================
FastFlow skeleton    device lowering here
==================  ==========================================================
farm (DP)           ``farm_map`` — batch scatter (emitter) + psum collector
map  (Sec. 12.1)    ``tensor_map`` — shard_map Split/Compose over an axis
farm (EP/MoE)       dispatch/combine in models/moe.py (MPMC all-to-all);
                    helpers ``expert_capacity`` here
pipeline            ``pipeline_shard`` — stages on a mesh axis, microbatches
                    streamed over collective_permute edges (SPSC channels),
                    GPipe schedule with fill/drain bubbles
farm+collector      ``flash_decode_combine`` — partial-softmax workers +
                    logsumexp-combining collector for sharded-KV decode
feedback            ``feedback_scan`` — wrap_around as lax.scan carrying the
                    stream back (decode loop, divide&conquer);
                    ``feedback_while`` — the data-dependent variant as
                    lax.while_loop (per-item early exit, FastBERT-style)
==================  ==========================================================
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax
try:
    from jax import shard_map as _shard_map_fn
    def shard_map(f, mesh, in_specs, out_specs, check_rep=False):
        return _shard_map_fn(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_rep)
except ImportError:  # older jax
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# farm over the data axis (the plain DP farm)
# ---------------------------------------------------------------------------
def farm_map(fn: Callable, mesh: Mesh, axis: str = "data",
             in_specs=None, out_specs=None, reduce_outputs: bool = False):
    """Run ``fn`` as farm workers over ``axis``; round-robin scheduling is the
    even batch sharding.  If ``reduce_outputs``, the collector psums results
    (gradient consolidation 'in memory', paper Sec. 8.2)."""
    in_specs = in_specs if in_specs is not None else P(axis)
    out_specs = out_specs if out_specs is not None else (P() if reduce_outputs else P(axis))

    def worker(*args):
        out = fn(*args)
        if reduce_outputs:
            out = jax.tree.map(lambda t: lax.pmean(t, axis), out)
        return out

    return shard_map(worker, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# map skeleton (Split -> workers -> Compose) over the model axis
# ---------------------------------------------------------------------------
def tensor_map(fn: Callable, mesh: Mesh, axis: str = "model",
               split_spec=None, compose: str = "gather", out_axis: int = -1):
    """Paper Sec. 12.1 map on a farm template: Split partitions the input over
    ``axis``; workers compute partitions; Compose rebuilds the result —
    ``gather`` (concatenate partitions, e.g. row-parallel) or ``reduce``
    (psum partial results, e.g. col-parallel matmul contributions)."""
    split_spec = split_spec if split_spec is not None else P(None, axis)

    def worker(*args):
        out = fn(*args)
        if compose == "reduce":
            out = jax.tree.map(lambda t: lax.psum(t, axis), out)
        return out

    if compose == "reduce":
        out_specs = P()
    else:  # gather: partitions concatenated along out_axis by the Compose
        ndim = (-out_axis) if out_axis < 0 else out_axis + 1
        spec = [None] * ndim
        spec[out_axis] = axis
        out_specs = P(*spec)
    return shard_map(worker, mesh=mesh, in_specs=split_spec,
                     out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# pipeline skeleton over a mesh axis (pipeline parallelism)
# ---------------------------------------------------------------------------
def pipeline_shard(stage_fn: Callable, mesh: Mesh, axis: str,
                   n_microbatches: int):
    """GPipe-style pipeline: each shard along ``axis`` owns one stage's
    parameters; microbatches stream through ``collective_permute`` edges —
    the device SPSC channels.  Total steps = M + S - 1 (fill/drain bubble,
    cf. paper Sec. 13: service time = max stage time).

    ``stage_fn(stage_params, x) -> x`` must keep the activation shape.

    Returns ``run(stacked_stage_params, x_microbatches)`` where
    ``stacked_stage_params`` has a leading stage dim sharded over ``axis`` and
    ``x_microbatches`` is ``(M, mb, ...)`` replicated along ``axis``.
    """
    S = mesh.shape[axis]
    M = n_microbatches

    def body(params, x_mb):
        # params: this stage's slice (leading dim 1); x_mb: (M, mb, ...)
        params = jax.tree.map(lambda t: t[0], params)
        idx = lax.axis_index(axis)
        mb_shape = x_mb.shape[1:]
        state = jnp.zeros(mb_shape, x_mb.dtype)          # in-flight microbatch
        out = jnp.zeros_like(x_mb)                       # drained results
        fwd_perm = [(i, (i + 1) % S) for i in range(S)]

        def step(t, carry):
            state, out = carry
            # stage 0 ingests microbatch t (when available)
            ingress = x_mb[jnp.minimum(t, M - 1)]
            state = jnp.where((idx == 0) & (t < M), ingress, state)
            state = stage_fn(params, state)
            # last stage drains microbatch t-(S-1)
            done = t - (S - 1)
            take = (idx == S - 1) & (done >= 0)
            out = lax.dynamic_update_slice(
                out,
                jnp.where(take, state, lax.dynamic_slice(
                    out, (jnp.maximum(done, 0),) + (0,) * len(mb_shape),
                    (1,) + mb_shape)[0])[None],
                (jnp.maximum(done, 0),) + (0,) * len(mb_shape))
            # SPSC edge: push my state to the next stage
            state = lax.ppermute(state, axis, fwd_perm)
            return state, out

        state, out = lax.fori_loop(0, M + S - 1, step, (state, out))
        # Compose: broadcast the last stage's buffer (collector gather)
        if S > 1:
            out = lax.psum(jnp.where(idx == S - 1, out, jnp.zeros_like(out)),
                           axis)
        return out

    in_specs = (jax.tree.map(lambda _: P(axis), jax.tree.structure(0)), P())

    def run(stage_params, x_mb):
        specs = jax.tree.map(lambda _: P(axis), stage_params)
        f = shard_map(body, mesh=mesh, in_specs=(specs, P()),
                      out_specs=P(), check_rep=False)
        return f(stage_params, x_mb)

    return run


# ---------------------------------------------------------------------------
# farm-with-collector for sharded-KV decode (flash decoding)
# ---------------------------------------------------------------------------
def flash_decode_combine(partial_out: jnp.ndarray, partial_lse: jnp.ndarray,
                         axis: str):
    """Collector for context-parallel decode attention: workers hold KV
    shards and produce (softmax-partial output, logsumexp); the collector
    renormalizes — a farm whose collector implements a numerically exact
    gather policy.  Runs inside shard_map over ``axis``.

    partial_out: (..., d) local unnormalized-softmax output
    partial_lse: (...,)   local logsumexp of scores
    """
    m = lax.pmax(partial_lse, axis)
    w = jnp.exp(partial_lse - m)
    num = lax.psum(partial_out * w[..., None], axis)
    den = lax.psum(w, axis)
    return num / den[..., None]


# ---------------------------------------------------------------------------
# feedback channel (wrap_around) as a scan
# ---------------------------------------------------------------------------
def feedback_scan(step_fn: Callable, init_state, n_steps: int,
                  collect: bool = True):
    """Route the stream back to the input: ``state -> step_fn -> state``.
    Used for autoregressive decode (token fed back) and iterative
    divide&conquer refinement.  ``step_fn(state) -> (state, emit)``."""
    def body(state, _):
        state, emit = step_fn(state)
        return state, (emit if collect else None)

    return lax.scan(body, init_state, None, length=n_steps)


def feedback_while(step_fn: Callable, init_state, cond_fn: Callable,
                   max_steps: Optional[int] = None):
    """Data-dependent feedback channel: ``do {state = step(state)} while
    (cond(state))`` as a ``lax.while_loop`` — the device lowering of a
    ``wrap_around`` loop whose exit is decided per item per turn
    (``compile(feedback_cond=...)``), e.g. FastBERT-style confidence exit.

    The step always runs at least once, matching the host path where an
    item traverses the loop body before the runner evaluates the predicate
    on the feedback edge.  ``max_steps`` optionally caps the turn count
    (``feedback_steps`` riding along as a safety bound).

    vmap-safe by construction: under ``jax.vmap`` the batched loop keeps
    iterating until every lane's predicate is false, but a finished lane's
    state is frozen by the ``active`` mask — extra turns cannot corrupt it.
    ``step_fn(state) -> (state, emit)`` (emit discarded, as in
    ``feedback_scan(collect=False)``).  Returns ``(final_state, n_steps)``
    with ``n_steps`` the number of turns this item actually ran."""
    def body(carry):
        state, active, k = carry
        new_state, _ = step_fn(state)
        state = jax.tree.map(
            lambda old, new: jnp.where(active, new, old), state, new_state)
        k = k + jnp.asarray(active, jnp.int32)
        go = jnp.asarray(cond_fn(state), bool)
        if max_steps is not None:
            go = jnp.logical_and(go, k < max_steps)
        active = jnp.logical_and(active, go)
        return state, active, k

    init = (init_state, jnp.asarray(True), jnp.asarray(0, jnp.int32))
    state, _, k = lax.while_loop(lambda c: jnp.any(c[1]), body, init)
    return state, k


# ---------------------------------------------------------------------------
# all-to-all (ff_a2a) as MoE-style dispatch/combine
# ---------------------------------------------------------------------------
def a2a_dispatch(left_fns: Sequence[Callable], right_fns: Sequence[Callable],
                 router: Optional[Callable] = None, mesh: Optional[Mesh] = None,
                 axis: str = "data", capacity_factor: Optional[float] = None,
                 interpret: Optional[bool] = None):
    """Device lowering of ``ff_a2a``: left workers map the batch, then the
    whole dispatch/combine hop — route, capacity position, expert compute,
    combine — runs as ONE fused Pallas kernel
    (:func:`~repro.kernels.a2a_fused.a2a_fused`, the ``router_topk``
    lane-occupancy math extended with in-kernel expert compute), sized by
    :func:`expert_capacity`.  The ``(nR, cap)`` lane buffer the old
    router-scatter-loop-gather lowering materialized in HBM no longer
    exists; only the per-expert VMEM cursors remain.

    Semantics mirror the host :class:`~repro.core.graph.A2ASkeleton`: item
    ``t`` enters left worker ``t % nL`` (the feeder's round-robin); without a
    ``router`` the default schedule matches the host's per-producer staggered
    round-robin ``(i + k) % nR``.  A ``router(item, n_right) -> int`` must be
    jax-traceable here (the host runtime accepts any Python callable).

    ``capacity_factor=None`` sizes every lane to the whole batch (lossless —
    the host runtime never drops, it blocks); with a factor, lanes are sized
    by :func:`expert_capacity` and items beyond capacity produce zeros, the
    bounded-lane drop policy of the synchronous SPMD rendering.

    Returns ``batched(xs, t_idx)`` mapping a stacked batch ``(T, ...)`` plus
    absolute stream indices ``(T,)`` to stacked outputs ``(T, ...)``; right
    workers must agree on output shape/dtype.  With a ``mesh``, the left map
    runs sharded over ``axis`` — and in the lossless case the fused
    dispatch/combine kernel runs sharded too (expert compute where the
    tokens already live: per-shard lane cursors reproduce the global
    first-come outcome exactly because nothing can overflow).  A bounded
    ``capacity_factor`` keeps the dispatch batch-global: first-come lane
    occupancy across shards needs the one set of cursors.
    """
    from ..kernels.a2a_fused import a2a_fused
    from ..kernels.backend import default_interpret

    interpret = default_interpret(interpret)
    nL, nR = len(left_fns), len(right_fns)

    def left_apply(x, t):
        if nL == 1:
            return left_fns[0](x)
        return lax.switch(t % nL, list(left_fns), x)

    def batched(xs, t_idx):
        T = xs.shape[0]
        axis_size = dict(mesh.shape).get(axis, 1) if mesh is not None else 1
        if axis_size > 1 and T % axis_size == 0:
            ys = farm_map(lambda a, b: jax.vmap(left_apply)(a, b), mesh,
                          axis=axis, in_specs=(P(axis), P(axis)),
                          out_specs=P(axis))(xs, t_idx)
        else:
            ys = jax.vmap(left_apply)(xs, t_idx)
        if router is not None:
            e = jax.vmap(lambda y: router(y, nR))(ys)
            e = jnp.asarray(e, jnp.int32) % nR
        else:  # host default: producer i's k-th output goes to (i + k) % nR
            e = (((t_idx % nL) + (t_idx // nL)) % nR).astype(jnp.int32)
        cap = T if capacity_factor is None else \
            expert_capacity(T, nR, 1, capacity_factor)
        logits = jax.nn.one_hot(e, nR, dtype=jnp.float32)
        if (mesh is not None and axis_size > 1 and capacity_factor is None
                and T % axis_size == 0):
            # sharded expert compute: every shard runs the fused kernel on
            # its own tokens (capacity is lossless, so per-shard cursors
            # cannot diverge from the batch-global first-come outcome)
            out = farm_map(
                lambda lg, y: a2a_fused(lg, y, right_fns, cap,
                                        interpret=interpret)[0],
                mesh, axis=axis, in_specs=(P(axis), P(axis)),
                out_specs=P(axis))(logits, ys)
            return out
        out, _keep = a2a_fused(logits, ys, right_fns, cap,
                               interpret=interpret)
        return out

    return batched


# ---------------------------------------------------------------------------
# MoE farm helpers (emitter = learned load balancer)
# ---------------------------------------------------------------------------
def expert_capacity(tokens_per_shard: int, n_experts: int, top_k: int,
                    capacity_factor: float, multiple_of: int = 8) -> int:
    """Slots per expert per token-shard — the bounded SPSC lane depth of the
    MoE farm.  Tasks beyond capacity are dropped (FastFlow would block; a
    synchronous SPMD program must bound the lane)."""
    cap = int(tokens_per_shard * top_k * capacity_factor / n_experts)
    cap = max(multiple_of, (cap + multiple_of - 1) // multiple_of * multiple_of)
    return min(cap, tokens_per_shard)
