"""The process-backed host tier: farm workers as OS processes over the
shared-memory rings of ``core/shm.py``.

CPython threads share one GIL, so the thread-backed host farm of
``core/skeletons.py`` only parallelizes stages that release it (I/O, large
BLAS calls, jitted device steps).  This module is FastFlow's actual
multicore claim: a farm whose workers are *processes*, wired emitter ->
workers -> collector over true shared-memory SPSC lanes, so CPU-bound
Python/numpy ``svc`` stages scale with cores.

:class:`ProcessFarmNode` is the bridge into the thread tier: it is itself an
``ff_node`` that sits in an ordinary host streaming network.  Its ``svc``
routes items round-robin onto per-worker shm lanes (the SPMC side); a
collector thread drains the per-worker result lanes (the MPSC side),
restores input order from sequence numbers, and forwards downstream via
``ff_send_out``.  Worker processes receive their (picklable) ``svc``
callable once at startup and then only raw items.  A worker that raises
ships an error record back; a worker that *dies* (crash, kill) is detected
by liveness polling — either way the surrounding runner surfaces the error
instead of wedging.
"""

from __future__ import annotations

import collections
import contextlib
import multiprocessing as mp
import os
import pickle
import threading
import time
import traceback
import warnings
from typing import Any, Callable, Dict, List, Optional

from .node import EOS, FFNode, GO_ON
from .queues import QueueClosed
from .shm import ShmError, ShmMPSCQueue, ShmSPMCQueue

# fork keeps worker start cheap and lets closures ride along; spawn is the
# fallback where fork does not exist (the callables must then pickle by
# reference, which place() already checks before choosing this tier)
_START_METHOD = "fork" if "fork" in mp.get_all_start_methods() else "spawn"


def _mp_context():
    return mp.get_context(_START_METHOD)


@contextlib.contextmanager
def _quiet_fork():
    # jax warns on any fork from a multithreaded process; our children never
    # touch jax (they run pure-python/numpy svc callables), so the warning
    # is noise here
    with warnings.catch_warnings():
        warnings.filterwarnings("ignore", message=r"os\.fork\(\) was called",
                                category=RuntimeWarning)
        yield


def fn_picklable(fn: Callable) -> bool:
    """Can this callable be shipped to a worker process at startup?"""
    try:
        pickle.dumps(fn)
        return True
    except Exception:   # noqa: BLE001 - unpicklable closures, lambdas (spawn)
        return _START_METHOD == "fork" and callable(fn)


class WorkerCrashed(RuntimeError):
    """A farm worker process exited without finishing its stream."""


def _worker_main(idx: int, fn: Callable, in_lane, out_lane) -> None:
    """Child process body: pop an item, push ``fn(item)``.

    Items ride the lanes bare — each lane is FIFO, so the parent matches
    results to sequence numbers by arrival order and nothing extra crosses
    the wire (bare ndarrays keep the raw-slab fast path).  EOS (or a closed
    input lane) terminates; an exception in ``fn`` ships an error record
    followed by EOS so the parent collector both surfaces the error and
    stops waiting on this lane."""
    try:
        # FastFlow pins its farm threads round-robin onto cores
        # (ff_mapping_utils); do the same for worker processes — schedulers
        # on shared hosts otherwise stack them onto one core
        os.sched_setaffinity(0, {idx % (os.cpu_count() or 1)})
    except (AttributeError, OSError):
        pass
    try:
        while True:
            try:
                got = in_lane.pop()
            except QueueClosed:                     # parent unwound the farm
                break
            if got is EOS:
                break
            try:
                out = fn(got)
            except BaseException as e:  # noqa: BLE001 - shipped to the parent
                out_lane.push_err(ShmError(idx, repr(e),
                                           traceback.format_exc()))
                return
            out_lane.push(out)
    finally:
        try:
            out_lane.push_eos()
        except BaseException:   # noqa: BLE001 - parent may be gone
            pass
        in_lane.detach()
        out_lane.detach()


class ProcessFarmNode(FFNode):
    """A farm stage whose workers are processes, embedded as one host node.

    ``fns`` is one picklable per-item callable per worker (a replicated pure
    farm passes the same function N times).  ``pre``/``post`` are the pure
    emitter/collector callables the graph normal form absorbed into the farm
    — they run in the parent, around the shm hop.  Output order follows
    *input* order (a sequence-number reorder buffer), which is stricter than
    the thread farm's arrival order and matches the device lowering."""

    def __init__(self, fns: List[Callable], pre: Optional[Callable] = None,
                 post: Optional[Callable] = None, capacity: int = 64,
                 slot_bytes: int = 1 << 16, label: str = "process_farm"):
        super().__init__()
        if not fns:
            raise ValueError("process farm with no workers")
        self._fns = list(fns)
        self._pre = pre
        self._post = post
        self._label = label
        self._n = len(self._fns)
        self._spmc = ShmSPMCQueue(self._n, capacity, slot_bytes)
        self._mpsc = ShmMPSCQueue(self._n, capacity, slot_bytes)
        ctx = _mp_context()
        # workers spawn at build time (before the runner's thread network and
        # any device work start) and park on their empty input lanes
        self._procs = [
            ctx.Process(target=_worker_main,
                        args=(i, fn, self._spmc.lanes[i], self._mpsc.lanes[i]),
                        daemon=True, name=f"ff-proc-worker-{i}")
            for i, fn in enumerate(self._fns)]
        with _quiet_fork():
            for p in self._procs:
                p.start()
        self._seq = 0
        self._delivered = 0
        self._routed = [0] * self._n
        # lane i is FIFO, so its results map to these seqs in arrival order
        # (deque append/popleft from opposite ends is GIL-atomic)
        self._lane_seqs = [collections.deque() for _ in range(self._n)]
        self._eos_seen = [False] * self._n
        self._collector: Optional[threading.Thread] = None
        self._destroyed = False

    @property
    def width(self) -> int:
        return self._n

    # -- parent-side emitter -------------------------------------------------
    def _push_alive(self, idx: int, payload: Any) -> bool:
        """Blocking push to worker ``idx`` that fails over instead of
        wedging when the worker process has died with a full lane — or when
        the collector has already flagged the farm as failed (a live worker
        blocked on its full result lane never drains its input again)."""
        lane = self._spmc.lanes[idx]
        delay = 1e-6
        while not lane.try_push(payload):
            if self.error is not None:
                return False
            # liveness only once the lane stays full for ~1ms (a waitpid
            # syscall per spin would otherwise dominate the hop cost)
            if delay >= 1e-3 and not self._procs[idx].is_alive():
                return False
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)
        return True

    def svc(self, item: Any) -> Any:
        if self.error is not None:      # collector flagged a failed farm
            raise self.error
        if self._pre is not None:
            item = self._pre(item)
        seq = self._seq
        self._seq += 1
        for off in range(self._n):
            idx = (seq + off) % self._n
            # record the seq before publishing the item: lane FIFO order is
            # the seq order, and the collector must never see an unmapped
            # result
            self._lane_seqs[idx].append(seq)
            if self._push_alive(idx, item):
                self._routed[idx] += 1
                return GO_ON
            self._lane_seqs[idx].pop()  # un-record the failed attempt
        # every worker is gone; the collector (or this) surfaces the crash
        if self.error is None:
            self.error = WorkerCrashed(
                f"{self._label}: all {self._n} worker processes died")
        raise self.error

    # -- parent-side collector ----------------------------------------------
    def _collect(self) -> None:
        hold: Dict[int, Any] = {}       # out-of-order results by sequence
        nxt = 0
        delay = 1e-6
        last_liveness = time.monotonic()
        while not all(self._eos_seen):
            ok, got, lane = self._mpsc.try_pop_any()
            if not ok:
                # adaptive backoff: a hard poll here steals CPU from the
                # very workers it waits on (they share the machine's cores)
                now = time.monotonic()
                if now - last_liveness > 0.05:
                    last_liveness = now
                    if self._check_crashed():
                        self._fail()
                        return
                time.sleep(delay)
                delay = min(delay * 2, 1e-3)
                continue
            delay = 1e-6
            if got is EOS:
                self._eos_seen[lane] = True
                continue
            if isinstance(got, ShmError):
                self.error = WorkerCrashed(
                    f"{self._label}: worker {got.worker} raised "
                    f"{got.exc}\n{got.tb}")
                self._fail()
                return
            hold[self._lane_seqs[lane].popleft()] = got
            while nxt in hold:
                out = hold.pop(nxt)
                nxt += 1
                if self._post is not None:
                    out = self._post(out)
                self._delivered += 1
                self.ff_send_out(out)

    def _check_crashed(self) -> bool:
        for i, p in enumerate(self._procs):
            if not self._eos_seen[i] and not p.is_alive() \
                    and self._mpsc.lanes[i].empty():
                self.error = WorkerCrashed(
                    f"{self._label}: worker {i} died "
                    f"(exitcode={p.exitcode}) before end of stream")
                return True
        return False

    def _fail(self) -> None:
        """Unwind a failed farm without wedging: stop accepting input
        (``svc`` raises once ``self.error`` is set), release workers parked
        on their input lanes (closing them makes their ``pop`` raise after
        the drain), and keep the result lanes draining so a worker blocked
        mid-push can reach its EOS and exit."""
        self._spmc.close_all()
        deadline = time.monotonic() + 10.0
        while not all(self._eos_seen) and time.monotonic() < deadline:
            ok, got, lane = self._mpsc.try_pop_any()
            if ok:
                if got is EOS:
                    self._eos_seen[lane] = True
                continue
            if all(self._eos_seen[i] or not p.is_alive()
                   for i, p in enumerate(self._procs)):
                break
            time.sleep(1e-4)

    # -- lifecycle -----------------------------------------------------------
    def svc_init(self) -> int:
        self._collector = threading.Thread(target=self._collect, daemon=True,
                                           name=f"{self._label}-collector")
        self._collector.start()
        return 0

    def svc_end(self) -> None:
        try:
            for i in range(self._n):
                if self._procs[i].is_alive() or not self._spmc.lanes[i].empty():
                    try:
                        self._spmc.lanes[i].push_eos(timeout=2.0)
                    except (TimeoutError, QueueClosed):
                        pass
            if self._collector is not None:
                self._collector.join(timeout=30.0)
            for p in self._procs:
                p.join(timeout=5.0)
                if p.is_alive():
                    p.terminate()
        finally:
            # errors stay on self.error (the runner's _error() walk finds
            # them); raising here would only kill the node thread noisily
            self._destroy()

    def _destroy(self) -> None:
        if not self._destroyed:
            self._destroyed = True
            self._spmc.destroy()
            self._mpsc.destroy()

    def __del__(self):
        # a compiled-but-never-run or abandoned (e.g. run() timed out and
        # the runner was discarded) node must still release its segments
        try:
            if self._destroyed:
                return
            self._spmc.close_all()      # parked workers drain, then exit
            for p in self._procs:
                p.join(timeout=1.0)
                if p.is_alive():
                    p.terminate()
            self._destroy()
        except Exception:   # noqa: BLE001 - interpreter teardown
            pass

    # -- stats ---------------------------------------------------------------
    def node_stats(self) -> dict:
        return {
            "node": self._label,
            "backend": "process",
            "workers": self._n,
            "items": self._seq,
            "delivered": self._delivered,
            "routed_per_worker": list(self._routed),
            "svc_time_ema_s": self.svc_time_ema,
            "max_lane_depth": max((l.max_depth for l in self._spmc.lanes),
                                  default=0),
        }
