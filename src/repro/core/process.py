"""The process-backed host tier: farm workers as OS processes over the
shared-memory rings of ``core/shm.py``.

CPython threads share one GIL, so the thread-backed host farm of
``core/skeletons.py`` only parallelizes stages that release it (I/O, large
BLAS calls, jitted device steps).  This module is FastFlow's actual
multicore claim: a farm whose workers are *processes*, wired emitter ->
workers -> collector over true shared-memory SPSC lanes, so CPU-bound
Python/numpy ``svc`` stages scale with cores.

:class:`ProcessFarmNode` is the bridge into the thread tier: it is itself an
``ff_node`` that sits in an ordinary host streaming network.  Its ``svc``
routes items round-robin onto per-worker shm lanes (the SPMC side); a
collector thread drains the per-worker result lanes (the MPSC side),
restores input order from sequence numbers, and forwards downstream via
``ff_send_out``.  Worker processes receive their (picklable) ``svc``
callable once at startup and then only raw items.  A worker that raises
ships an error record back; a worker that *dies* (crash, kill) is detected
by liveness polling — either way the surrounding runner surfaces the error
instead of wedging.

With ``autoscale=True`` the farm reuses the thread tier's
:class:`~repro.core.skeletons.AutoscaleLB` over its *shm* lanes: the full
worker set forks once at build time, and scaling moves the round-robin
routing boundary from observed lane depth.  An inactive worker is parked on
its idle gate — the blocking ``pop`` on its empty input lane (microsecond
backoff capped at 1 ms) — so growing the active set never forks a process,
it just starts routing to a parked one.

:class:`ProcessA2ANode` is the same bridge for FastFlow 3's ``ff_a2a``: left
worker processes apply their ``svc`` callable and route each result through
an :class:`~repro.core.shm.ShmMPMCGrid` lane selected by the graph's router;
right worker processes drain their grid column fairly and ship results back
over per-worker result lanes.  Sequence numbers ride the slot headers (the
grid's routing is data-dependent, so arrival order alone cannot restore
stream order), the parent reorders, EOS fans out row-wise (each right worker
terminates after one EOS per left worker), and crashes on either side
surface as :class:`WorkerCrashed`.
"""

from __future__ import annotations

import collections
import contextlib
import multiprocessing as mp
import os
import pickle
import threading
import time
import traceback
import warnings
from typing import Any, Callable, Dict, List, Optional

from .node import EOS, FFNode, GO_ON
from .queues import QueueClosed
from .shm import (BatchedLaneWriter, ShmError, ShmMPMCGrid, ShmMPSCQueue,
                  ShmSPMCQueue, ShmSPSCQueue, ShmUSPSCQueue, TransportConfig,
                  WorkerStats, as_transport)
from .skeletons import AutoscaleLB

# ship a WorkerStats CPU-time record back every this many processed items
# (plus one final record before EOS, so short streams still report)
_STATS_EVERY = 32

# fork keeps worker start cheap and lets closures ride along; spawn is the
# fallback where fork does not exist (the callables must then pickle by
# reference, which place() already checks before choosing this tier)
_START_METHOD = "fork" if "fork" in mp.get_all_start_methods() else "spawn"


def _mp_context():
    return mp.get_context(_START_METHOD)


@contextlib.contextmanager
def _quiet_fork():
    # jax warns on any fork from a multithreaded process; our children never
    # touch jax (they run pure-python/numpy svc callables), so the warning
    # is noise here
    with warnings.catch_warnings():
        warnings.filterwarnings("ignore", message=r"os\.fork\(\) was called",
                                category=RuntimeWarning)
        yield


def fn_picklable(fn: Callable) -> bool:
    """Can this callable be shipped to a worker process at startup?"""
    try:
        pickle.dumps(fn)
        return True
    except Exception:   # noqa: BLE001 - unpicklable closures, lambdas (spawn)
        return _START_METHOD == "fork" and callable(fn)


class WorkerCrashed(RuntimeError):
    """A farm worker process exited without finishing its stream."""


_NUMA_SYSFS = "/sys/devices/system/node"
_numa_cache: Optional[List[List[int]]] = None


def _parse_cpulist(text: str) -> List[int]:
    """Kernel cpulist format: ``0-3,8-11`` -> [0,1,2,3,8,9,10,11]."""
    cpus: List[int] = []
    for part in text.strip().split(","):
        if not part:
            continue
        if "-" in part:
            lo, hi = part.split("-", 1)
            cpus.extend(range(int(lo), int(hi) + 1))
        else:
            cpus.append(int(part))
    return cpus


def _numa_topology(refresh: bool = False) -> List[List[int]]:
    """CPU ids per NUMA node from sysfs, or ``[]`` when the topology is
    unreadable or trivial (a single node — e.g. the 2-vCPU CI container),
    in which case every NUMA-aware path degrades to the plain behaviour."""
    global _numa_cache
    if _numa_cache is not None and not refresh:
        return _numa_cache
    nodes: List[List[int]] = []
    try:
        for entry in sorted(os.listdir(_NUMA_SYSFS)):
            if not (entry.startswith("node") and entry[4:].isdigit()):
                continue
            with open(os.path.join(_NUMA_SYSFS, entry, "cpulist")) as f:
                cpus = _parse_cpulist(f.read())
            if cpus:
                nodes.append(cpus)
    except OSError:
        nodes = []
    _numa_cache = nodes if len(nodes) >= 2 else []
    return _numa_cache


def _pin(idx: int) -> None:
    # FastFlow pins its farm threads round-robin onto cores
    # (ff_mapping_utils); do the same for worker processes — schedulers
    # on shared hosts otherwise stack them onto one core.  With a readable
    # multi-node NUMA topology, spread workers round-robin across nodes
    # first (one memory controller each, matching their lanes' first-touch
    # placement), then round-robin cores within the node.
    try:
        nodes = _numa_topology()
        if nodes:
            cpus = sorted(nodes[idx % len(nodes)])
            os.sched_setaffinity(0, {cpus[(idx // len(nodes)) % len(cpus)]})
        else:
            os.sched_setaffinity(0, {idx % (os.cpu_count() or 1)})
    except (AttributeError, OSError):
        pass


@contextlib.contextmanager
def _node_affinity(cpus: Optional[List[int]]):
    """Temporarily bind the calling (parent) process to one NUMA node's
    CPUs while it creates and first-touches a worker's lane segments, so
    the pages land on the node the worker will be pinned to.  No-op when
    ``cpus`` is falsy or affinity syscalls are unavailable."""
    if not cpus:
        yield
        return
    try:
        prev = os.sched_getaffinity(0)
        os.sched_setaffinity(0, set(cpus))
    except (AttributeError, OSError):
        yield
        return
    try:
        yield
    finally:
        try:
            os.sched_setaffinity(0, prev)
        except OSError:
            pass


def _first_touch(lane: Any) -> None:
    """Write one byte per page of a lane's segments so the (tmpfs) pages
    are allocated now, on the creating thread's current node, instead of
    wherever the first pushing process happens to run."""
    bufs = []
    for seg in (lane, getattr(lane, "_w", None)):
        buf = getattr(seg, "_buf", None)
        if buf is not None:
            bufs.append(buf)
    arena = getattr(lane, "_arena", None)
    if arena is not None and arena._buf is not None:
        bufs.append(arena._buf)
    for buf in bufs:
        for off in range(0, len(buf), 4096):
            buf[off] = 0


def _worker_main(idx: int, fn: Callable, in_lane, out_lane,
                 batch: int = 16, flush_s: float = 2e-3) -> None:
    """Child process body: pop a *batch* of items, push a batch of results.

    Items ride the lanes bare — each lane is FIFO, so the parent matches
    results to sequence numbers by arrival order and nothing extra crosses
    the wire (bare ndarrays keep the raw-slab / arena fast path).  The loop
    is vectored end to end: ``pop_many`` takes whatever the emitter has
    published (one head write for the lot — naturally latency-adaptive,
    batch size tracks the backlog), results buffer in a
    :class:`~repro.core.shm.BatchedLaneWriter` that flushes on batch-full,
    on the ``flush_s`` age timeout, and always before this worker would
    block on an empty input lane — so a stalled stream never strands
    results in the buffer.  Every ``_STATS_EVERY`` items (and once more
    before EOS) the worker also ships a
    :class:`~repro.core.shm.WorkerStats` record — true per-item CPU seconds
    from ``time.thread_time`` — which the parent collector folds into its
    stats *without* consuming a sequence slot.  EOS (or a closed input
    lane) terminates; an exception in ``fn`` ships an error record (after
    flushing results already computed) followed by EOS so the parent
    collector both surfaces the error and stops waiting on this lane."""
    _pin(idx)
    writer = BatchedLaneWriter(out_lane, batch=batch, flush_s=flush_s)
    done = 0
    cpu_ema = 0.0
    eos = False
    try:
        while not eos:
            got = in_lane.try_pop_many(batch)
            if not got:
                # going idle: ship buffered results before parking on the
                # lane (the EOS/timeout side of the adaptive flush)
                try:
                    writer.flush()
                except QueueClosed:
                    break
                try:
                    got = in_lane.pop_many(batch)
                except QueueClosed:                 # parent unwound the farm
                    break
            for item, _seq in got:
                if item is EOS:
                    eos = True
                    break
                try:
                    c0 = time.thread_time()
                    out = fn(item)
                    cpu = time.thread_time() - c0
                except BaseException as e:  # noqa: BLE001 - to the parent
                    writer.push_err(ShmError(idx, repr(e),
                                             traceback.format_exc()))
                    return
                writer.put(out)
                done += 1
                cpu_ema = cpu if cpu_ema == 0.0 \
                    else 0.9 * cpu_ema + 0.1 * cpu
                if done % _STATS_EVERY == 0:
                    # rides the result batch; consumes no sequence slot
                    writer.put(WorkerStats(idx, done, cpu_ema))
                writer.maybe_flush()
    finally:
        try:
            if done:
                writer.put(WorkerStats(idx, done, cpu_ema))
            writer.push_eos()       # flushes pending results first
        except BaseException:   # noqa: BLE001 - parent may be gone
            pass
        in_lane.detach()
        out_lane.detach()


class ProcessFarmNode(FFNode):
    """A farm stage whose workers are processes, embedded as one host node.

    ``fns`` is one picklable per-item callable per worker (a replicated pure
    farm passes the same function N times).  ``pre``/``post`` are the pure
    emitter/collector callables the graph normal form absorbed into the farm
    — they run in the parent, around the shm hop.  Output order follows
    *input* order (a sequence-number reorder buffer), which is stricter than
    the thread farm's arrival order and matches the device lowering.

    ``autoscale=True`` routes through an :class:`AutoscaleLB` over the shm
    input lanes: every worker process forks at build time and parks on its
    idle gate (the blocking pop on an empty lane); the balancer grows or
    shrinks the *active* round-robin set from observed lane depth, so
    scaling up never forks — it resumes a parked worker."""

    def __init__(self, fns: List[Callable], pre: Optional[Callable] = None,
                 post: Optional[Callable] = None, capacity: int = 64,
                 slot_bytes: int = 1 << 16, label: str = "process_farm",
                 autoscale: bool = False, min_workers: int = 1,
                 transport: Optional[TransportConfig] = None):
        super().__init__()
        if not fns:
            raise ValueError("process farm with no workers")
        tc = as_transport(transport)
        if transport is not None:
            # explicit transport knobs clamp/override the legacy params
            capacity = max(2, min(capacity, tc.ring_slots))
            slot_bytes = tc.slot_bytes
        self._fns = list(fns)
        self._pre = pre
        self._post = post
        self._label = label
        self._n = len(self._fns)
        self._batch = tc.batch
        self._flush_s = tc.flush_s
        # lanes build one worker at a time so each pair's pages can
        # first-touch on the node the worker will be pinned to (a no-op
        # without a readable multi-node topology — e.g. the CI container)
        nodes = _numa_topology()
        in_lanes: List[Any] = []
        out_lanes: List[Any] = []
        for i in range(self._n):
            with _node_affinity(nodes[i % len(nodes)] if nodes else None):
                if tc.bounded:
                    in_lane: Any = ShmSPSCQueue(capacity, slot_bytes,
                                                arena_bytes=tc.arena_bytes)
                else:
                    in_lane = ShmUSPSCQueue(max(capacity, 4), slot_bytes,
                                            arena_bytes=tc.arena_bytes)
                out_lane = ShmSPSCQueue(capacity, slot_bytes,
                                        arena_bytes=tc.arena_bytes)
                if nodes:
                    _first_touch(in_lane)
                    _first_touch(out_lane)
            in_lanes.append(in_lane)
            out_lanes.append(out_lane)
        self._spmc = ShmSPMCQueue.from_lanes(in_lanes)
        self._mpsc = ShmMPSCQueue.from_lanes(out_lanes)
        self._lb: Optional[AutoscaleLB] = None
        if autoscale:
            self._lb = AutoscaleLB(min_workers=min_workers,
                                   max_workers=self._n)
            self._lb._attach(self._spmc)    # shm lanes expose the same
            #                                 len()-able lane surface
        ctx = _mp_context()
        # workers spawn at build time (before the runner's thread network and
        # any device work start) and park on their empty input lanes
        self._procs = [
            ctx.Process(target=_worker_main,
                        args=(i, fn, self._spmc.lanes[i], self._mpsc.lanes[i],
                              self._batch, self._flush_s),
                        daemon=True, name=f"ff-proc-worker-{i}")
            for i, fn in enumerate(self._fns)]
        with _quiet_fork():
            for p in self._procs:
                p.start()
        self._seq = 0
        self._delivered = 0
        self._routed = [0] * self._n
        self._active = self._n      # routing boundary when no balancer
        self._hop_ema = 0.0         # parent-side per-item shm push cost
        self._gap_ema = 0.0         # collector-side inter-delivery gap
        self._last_delivery: Optional[float] = None
        # lane i is FIFO, so its results map to these seqs in arrival order
        # (deque append/popleft from opposite ends is GIL-atomic)
        self._lane_seqs = [collections.deque() for _ in range(self._n)]
        self._worker_cpu: Dict[int, tuple] = {}   # idx -> (items, cpu_ema_s)
        self._eos_seen = [False] * self._n
        self._collector: Optional[threading.Thread] = None
        self._destroyed = False

    @property
    def width(self) -> int:
        return self._n

    @property
    def active_workers(self) -> int:
        return self._lb.cur if self._lb is not None else self._active

    def set_active(self, k: int) -> None:
        """Move the routing boundary: new items go to workers [0, k).  The
        full worker set forked at build time; an inactive worker parks on
        the blocking pop of its empty shm lane, so growing the active set
        never forks — it resumes a parked worker.  This is the AutoscaleLB
        mechanism exposed to an external policy (the adaptive supervisor)."""
        k = max(1, min(int(k), self._n))
        if self._lb is not None:
            self._lb.cur = min(max(k, self._lb.min_workers),
                               self._lb.max_workers or self._n)
        self._active = k

    # -- parent-side emitter -------------------------------------------------
    def _push_alive(self, idx: int, payload: Any) -> bool:
        """Blocking push to worker ``idx`` that fails over instead of
        wedging when the worker process has died with a full lane — or when
        the collector has already flagged the farm as failed (a live worker
        blocked on its full result lane never drains its input again)."""
        lane = self._spmc.lanes[idx]
        delay = 1e-6
        self._push_waited = False
        while not lane.try_push(payload):
            self._push_waited = True
            if self.error is not None:
                return False
            # liveness only once the lane stays full for ~1ms (a waitpid
            # syscall per spin would otherwise dominate the hop cost)
            if delay >= 1e-3 and not self._procs[idx].is_alive():
                return False
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)
        return True

    def svc(self, item: Any) -> Any:
        if self.error is not None:      # collector flagged a failed farm
            raise self.error
        if self._pre is not None:
            item = self._pre(item)
        with self._stats_lock:
            seq = self._seq
            self._seq += 1
        # autoscale: the balancer picks within the active set (and adjusts
        # it from lane depth); the failover scan below may route past the
        # active boundary, but only when the chosen worker has died
        start = self._lb.selectworker(item) if self._lb is not None \
            else seq % max(1, min(self._active, self._n))
        t0 = time.perf_counter()
        for off in range(self._n):
            idx = (start + off) % self._n
            # record the seq before publishing the item: lane FIFO order is
            # the seq order, and the collector must never see an unmapped
            # result
            self._lane_seqs[idx].append(seq)
            if self._push_alive(idx, item):
                hop = time.perf_counter() - t0
                with self._stats_lock:
                    self._routed[idx] += 1
                    # the hop EMA is the *channel* cost — a push that waited
                    # on a full lane measured back-pressure, not the hop
                    if not self._push_waited:
                        self._hop_ema = hop if self._hop_ema == 0.0 \
                            else 0.9 * self._hop_ema + 0.1 * hop
                return GO_ON
            self._lane_seqs[idx].pop()  # un-record the failed attempt
        # every worker is gone; the collector (or this) surfaces the crash
        if self.error is None:
            self.error = WorkerCrashed(
                f"{self._label}: all {self._n} worker processes died")
        raise self.error

    # -- parent-side collector ----------------------------------------------
    def _collect(self) -> None:
        hold: Dict[int, Any] = {}       # out-of-order results by sequence
        nxt = 0
        delay = 1e-6
        last_liveness = time.monotonic()
        while not all(self._eos_seen):
            # vectored drain: one head publish per visited lane, the whole
            # published backlog in one call
            batch = self._mpsc.try_pop_any_many(4 * self._batch)
            if not batch:
                # adaptive backoff: a hard poll here steals CPU from the
                # very workers it waits on (they share the machine's cores)
                now = time.monotonic()
                if now - last_liveness > 0.05:
                    last_liveness = now
                    if self._check_crashed():
                        self._fail()
                        return
                time.sleep(delay)
                delay = min(delay * 2, 1e-3)
                continue
            delay = 1e-6
            for got, lane, _seq in batch:
                if got is EOS:
                    self._eos_seen[lane] = True
                    continue
                if isinstance(got, ShmError):
                    self.error = WorkerCrashed(
                        f"{self._label}: worker {got.worker} raised "
                        f"{got.exc}\n{got.tb}")
                    self._fail()
                    return
                if isinstance(got, WorkerStats):
                    # a stats record, not a stream item: it consumed no
                    # sequence slot, so fold it in *before* touching the
                    # lane's seq map
                    with self._stats_lock:
                        self._worker_cpu[got.worker] = (got.items,
                                                        got.cpu_ema_s)
                    continue
                hold[self._lane_seqs[lane].popleft()] = got
                while nxt in hold:
                    out = hold.pop(nxt)
                    nxt += 1
                    if self._post is not None:
                        out = self._post(out)
                    now = time.perf_counter()
                    with self._stats_lock:
                        if self._last_delivery is not None:
                            gap = now - self._last_delivery
                            self._gap_ema = gap if self._gap_ema == 0.0 \
                                else 0.8 * self._gap_ema + 0.2 * gap
                        self._last_delivery = now
                        self._delivered += 1
                    self.ff_send_out(out)

    def _check_crashed(self) -> bool:
        for i, p in enumerate(self._procs):
            if not self._eos_seen[i] and not p.is_alive() \
                    and self._mpsc.lanes[i].empty():
                self.error = WorkerCrashed(
                    f"{self._label}: worker {i} died "
                    f"(exitcode={p.exitcode}) before end of stream")
                return True
        return False

    def _fail(self) -> None:
        """Unwind a failed farm without wedging: stop accepting input
        (``svc`` raises once ``self.error`` is set), release workers parked
        on their input lanes (closing them makes their ``pop`` raise after
        the drain), and keep the result lanes draining so a worker blocked
        mid-push can reach its EOS and exit."""
        self._spmc.close_all()
        deadline = time.monotonic() + 10.0
        while not all(self._eos_seen) and time.monotonic() < deadline:
            ok, got, lane = self._mpsc.try_pop_any()
            if ok:
                if got is EOS:
                    self._eos_seen[lane] = True
                continue
            if all(self._eos_seen[i] or not p.is_alive()
                   for i, p in enumerate(self._procs)):
                break
            time.sleep(1e-4)

    # -- lifecycle -----------------------------------------------------------
    def svc_init(self) -> int:
        self._collector = threading.Thread(target=self._collect, daemon=True,
                                           name=f"{self._label}-collector")
        self._collector.start()
        return 0

    def svc_end(self) -> None:
        if self._destroyed:             # idempotent: already drained
            return
        try:
            for i in range(self._n):
                if self._procs[i].is_alive() or not self._spmc.lanes[i].empty():
                    try:
                        self._spmc.lanes[i].push_eos(timeout=2.0)
                    except (TimeoutError, QueueClosed):
                        pass
            if self._collector is not None:
                self._collector.join(timeout=30.0)
            for p in self._procs:
                p.join(timeout=5.0)
                if p.is_alive():
                    p.terminate()
        finally:
            # errors stay on self.error (the runner's _error() walk finds
            # them); raising here would only kill the node thread noisily
            self._destroy()

    def _destroy(self) -> None:
        if not self._destroyed:
            self._destroyed = True
            self._spmc.destroy()
            self._mpsc.destroy()

    def __del__(self):
        # a compiled-but-never-run or abandoned (e.g. run() timed out and
        # the runner was discarded) node must still release its segments
        try:
            if self._destroyed:
                return
            self._spmc.close_all()      # parked workers drain, then exit
            for p in self._procs:
                p.join(timeout=1.0)
                if p.is_alive():
                    p.terminate()
            self._destroy()
        except Exception:   # noqa: BLE001 - interpreter teardown
            pass

    # -- stats ---------------------------------------------------------------
    def node_stats(self) -> dict:
        from .perf_model import fn_key
        # after the run the shm segments are released: report empty lanes
        # (max_depth is a process-local attribute and stays valid)
        depths = [0] * self._n if self._destroyed \
            else [len(l) for l in self._spmc.lanes]
        with self._stats_lock:
            cpu_recs = list(self._worker_cpu.values())
            total = sum(i for i, _ in cpu_recs)
            s = {
                "node": self._label,
                "backend": "process",
                "workers": self._n,
                "active": self.active_workers,
                "items": self._seq,
                "delivered": self._delivered,
                "routed_per_worker": list(self._routed),
                "svc_time_ema_s": self.svc_time_ema,
                # items-weighted worker-side CPU seconds per item (true
                # service time, measured in the children); 0.0 until the
                # first WorkerStats record lands
                "svc_cpu_ema_s": (sum(i * c for i, c in cpu_recs) / total
                                  if total else 0.0),
                "hop_ema_s": self._hop_ema,
                "delivery_gap_ema_s": self._gap_ema,
                "lane_depths": depths,
                "max_lane_depth": max(
                    (l.max_depth for l in self._spmc.lanes), default=0),
                "fn_key": fn_key(self._fns[0]),
            }
        if self._lb is not None:
            s["autoscale"] = {"active": self._lb.cur,
                              "grown": self._lb.grown,
                              "shrunk": self._lb.shrunk}
        return s


def _a2a_left_main(idx: int, fn: Callable,
                   router: Optional[Callable[[Any, int], int]],
                   in_lane: ShmSPSCQueue,
                   row_lanes: List[ShmSPSCQueue]) -> None:
    """Left-side a2a child: pop ``(item, seq)``, push ``fn(item)`` onto the
    grid lane the router selects, seq riding the slot header.

    Every exit path fans EOS out row-wise (one mark per right worker) and
    leaves with exit code 0; only an *abnormal* death (crash, kill) skips
    the fan-out, which is exactly what the parent's liveness poll keys on.
    A graceful-but-early exit (an exception in ``fn``) first ships an error
    record through the grid — a right worker relays it to the parent."""
    _pin(idx)
    nR = len(row_lanes)
    rr = idx % nR                   # stagger round-robin per producer,
    #                                 matching the thread A2ASkeleton
    try:
        while True:
            try:
                got, seq = in_lane.pop_seq()
            except QueueClosed:                 # parent unwound the a2a
                break
            if got is EOS:
                break
            try:
                y = fn(got)
                if router is not None:
                    # int() so jax/numpy-scalar routers (shared with the
                    # device lowering) index the grid
                    j = int(router(y, nR)) % nR
                else:
                    j, rr = rr, (rr + 1) % nR
            except BaseException as e:  # noqa: BLE001 - relayed to parent
                try:
                    row_lanes[idx % nR].push_err(
                        ShmError(idx, repr(e), traceback.format_exc()),
                        timeout=5.0)
                except BaseException:   # noqa: BLE001 - dead/closed column
                    pass
                break
            try:
                row_lanes[j].push(y, seq=seq)
            except QueueClosed:                 # parent unwound the a2a
                break
    finally:
        for lane in row_lanes:
            try:
                lane.push_eos()
            except BaseException:   # noqa: BLE001 - closed lane on unwind
                pass
        in_lane.detach()
        for lane in row_lanes:
            lane.detach()


def _a2a_right_main(idx: int, pin_idx: int, fn: Callable,
                    col_lanes: List[ShmSPSCQueue],
                    out_lane: ShmSPSCQueue) -> None:
    """Right-side a2a child: drain the grid column fairly, push ``fn(item)``
    (seq preserved) onto this worker's result lane.  Terminates after one
    EOS per left worker; relays left-side error records unchanged."""
    _pin(pin_idx)
    nL = len(col_lanes)
    eos = [False] * nL
    nxt = 0
    delay = 1e-6
    try:
        while not all(eos):
            got = None
            for off in range(nL):
                i = (nxt + off) % nL
                if eos[i]:
                    continue
                ok, item, seq = col_lanes[i].try_pop_seq()
                if ok:
                    nxt = (i + 1) % nL
                    got = (item, seq, i)
                    break
            if got is None:
                if all(eos[i] or col_lanes[i].drained() for i in range(nL)):
                    break               # parent unwound the a2a
                time.sleep(delay)
                delay = min(delay * 2, 1e-3)
                continue
            delay = 1e-6
            item, seq, lane = got
            if item is EOS:
                eos[lane] = True
                continue
            if isinstance(item, ShmError):      # left-side failure: relay
                out_lane.push_err(item, timeout=5.0)
                return
            try:
                z = fn(item)
            except BaseException as e:  # noqa: BLE001 - shipped to parent
                try:
                    out_lane.push_err(ShmError(idx, repr(e),
                                               traceback.format_exc()),
                                      timeout=5.0)
                except BaseException:   # noqa: BLE001 - parent may be gone
                    pass
                return
            out_lane.push(z, seq=seq)
    finally:
        try:
            out_lane.push_eos()
        except BaseException:   # noqa: BLE001 - parent may be gone
            pass
        for lane in col_lanes:
            lane.detach()
        out_lane.detach()


class ProcessA2ANode(FFNode):
    """FastFlow 3's ``ff_a2a`` on the process tier, embedded as one host node.

    ``left_fns``/``right_fns`` are picklable per-item callables, one per
    worker process on each side.  The parent's ``svc`` round-robins inputs
    onto the left workers' shm lanes; each left worker routes its result
    through the :class:`~repro.core.shm.ShmMPMCGrid` lane chosen by
    ``router(y, n_right)`` (default: per-producer staggered round-robin,
    matching the thread :class:`~repro.core.graph.A2ASkeleton`); right
    workers drain their column fairly and ship results back.  Sequence
    numbers ride the slot headers end to end, so output order follows
    *input* order — stricter than the thread a2a's arrival order and
    matching the process farm / device lowerings.

    Crash surfacing mirrors :class:`ProcessFarmNode`: exceptions ship back
    as error records (left-side ones relayed through a right worker); a
    killed worker on either side is caught by exit-code liveness polling.
    Failure unwinds by closing the input lanes *and* the grid — the
    process-tier equivalent of the thread a2a's drainer fix: a dead right
    worker's full column can no longer wedge the EOS fan-out, because a
    closed lane makes the fan-out push raise instead of spin."""

    def __init__(self, left_fns: List[Callable], right_fns: List[Callable],
                 router: Optional[Callable[[Any, int], int]] = None,
                 capacity: int = 64, slot_bytes: int = 1 << 16,
                 label: str = "process_a2a",
                 transport: Optional[TransportConfig] = None):
        super().__init__()
        if not left_fns or not right_fns:
            raise ValueError("process a2a needs workers on both sides")
        tc = as_transport(transport)
        if transport is not None:
            capacity = max(2, min(capacity, tc.grid_slots))
            slot_bytes = tc.slot_bytes
        self._nL = len(left_fns)
        self._nR = len(right_fns)
        self._label = label
        self._spmc = ShmSPMCQueue(self._nL, capacity, slot_bytes,
                                  arena_bytes=tc.arena_bytes)
        self._grid = ShmMPMCGrid(self._nL, self._nR, capacity, slot_bytes,
                                 arena_bytes=tc.arena_bytes)
        self._mpsc = ShmMPSCQueue(self._nR, capacity, slot_bytes,
                                  arena_bytes=tc.arena_bytes)
        ctx = _mp_context()
        self._left_procs = [
            ctx.Process(target=_a2a_left_main,
                        args=(i, fn, router, self._spmc.lanes[i],
                              self._grid.row(i)),
                        daemon=True, name=f"ff-a2a-left-{i}")
            for i, fn in enumerate(left_fns)]
        self._right_procs = [
            ctx.Process(target=_a2a_right_main,
                        args=(j, self._nL + j, fn, self._grid.col(j),
                              self._mpsc.lanes[j]),
                        daemon=True, name=f"ff-a2a-right-{j}")
            for j, fn in enumerate(right_fns)]
        with _quiet_fork():
            for p in (*self._left_procs, *self._right_procs):
                p.start()
        self._seq = 0
        self._delivered = 0
        self._routed = [0] * self._nL
        self._eos_seen = [False] * self._nR
        self._collector: Optional[threading.Thread] = None
        self._destroyed = False

    @property
    def width(self) -> int:
        return self._nL + self._nR

    # -- parent-side emitter -------------------------------------------------
    def _push_alive(self, idx: int, payload: Any, seq: int) -> bool:
        lane = self._spmc.lanes[idx]
        delay = 1e-6
        while not lane.try_push(payload, seq=seq):
            if self.error is not None:
                return False
            if delay >= 1e-3 and not self._left_procs[idx].is_alive():
                return False
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)
        return True

    def svc(self, item: Any) -> Any:
        if self.error is not None:      # collector flagged a failed a2a
            raise self.error
        with self._stats_lock:
            seq = self._seq
            self._seq += 1
        for off in range(self._nL):
            idx = (seq + off) % self._nL
            if self._push_alive(idx, item, seq):
                self._routed[idx] += 1
                return GO_ON
        if self.error is None:
            self.error = WorkerCrashed(
                f"{self._label}: all {self._nL} left worker processes died")
        raise self.error

    # -- parent-side collector ----------------------------------------------
    def _collect(self) -> None:
        hold: Dict[int, Any] = {}       # out-of-order results by sequence
        nxt = 0
        delay = 1e-6
        last_liveness = time.monotonic()
        while not all(self._eos_seen):
            ok, got, lane, seq = self._mpsc.try_pop_any_seq()
            if not ok:
                now = time.monotonic()
                if now - last_liveness > 0.05:
                    last_liveness = now
                    if self._check_crashed():
                        self._fail()
                        return
                time.sleep(delay)
                delay = min(delay * 2, 1e-3)
                continue
            delay = 1e-6
            if got is EOS:
                self._eos_seen[lane] = True
                continue
            if isinstance(got, ShmError):
                self.error = WorkerCrashed(
                    f"{self._label}: worker {got.worker} raised "
                    f"{got.exc}\n{got.tb}")
                self._fail()
                return
            hold[seq] = got
            while nxt in hold:
                with self._stats_lock:
                    self._delivered += 1
                self.ff_send_out(hold.pop(nxt))
                nxt += 1
        # completeness invariant: on a clean end of stream every routed item
        # must have produced exactly one output.  A gap means a worker died
        # without its error record reaching us (e.g. a push_err that timed
        # out on a wedged column was swallowed) — surface it rather than
        # returning a silently truncated stream.
        if self.error is None and self._delivered < self._seq:
            self.error = WorkerCrashed(
                f"{self._label}: stream truncated — only {self._delivered} "
                f"of {self._seq} items delivered (a worker failed without "
                "its error record reaching the collector)")

    def _check_crashed(self) -> bool:
        # every graceful exit path in the worker mains ends with exit code 0
        # (normal EOS, closed lanes on unwind, an exception shipped as an
        # error record); a nonzero/signal exit therefore means a real crash
        for i, p in enumerate(self._left_procs):
            if not p.is_alive() and p.exitcode != 0:
                self.error = WorkerCrashed(
                    f"{self._label}: left worker {i} died "
                    f"(exitcode={p.exitcode}) before end of stream")
                return True
        for j, p in enumerate(self._right_procs):
            if not self._eos_seen[j] and not p.is_alive() \
                    and p.exitcode != 0:
                self.error = WorkerCrashed(
                    f"{self._label}: right worker {j} died "
                    f"(exitcode={p.exitcode}) before end of stream")
                return True
        return False

    def _fail(self) -> None:
        """Unwind a failed a2a without wedging: refuse new input (``svc``
        raises once ``self.error`` is set), close the left input lanes
        (parked left workers' pops raise) and the whole grid (left workers
        blocked pushing into a dead right worker's column raise instead of
        spinning; right workers see closed-and-drained columns and exit),
        then keep the result lanes draining so every survivor reaches its
        EOS."""
        self._spmc.close_all()
        self._grid.close_all()
        deadline = time.monotonic() + 10.0
        while not all(self._eos_seen) and time.monotonic() < deadline:
            ok, got, lane, _seq = self._mpsc.try_pop_any_seq()
            if ok:
                if got is EOS:
                    self._eos_seen[lane] = True
                continue
            if all(self._eos_seen[j] or not p.is_alive()
                   for j, p in enumerate(self._right_procs)):
                break
            time.sleep(1e-4)

    # -- lifecycle -----------------------------------------------------------
    def svc_init(self) -> int:
        self._collector = threading.Thread(target=self._collect, daemon=True,
                                           name=f"{self._label}-collector")
        self._collector.start()
        return 0

    def svc_end(self) -> None:
        try:
            for i in range(self._nL):
                if self._left_procs[i].is_alive() \
                        or not self._spmc.lanes[i].empty():
                    try:
                        # generous timeout: a full input lane drains as long
                        # as the grid is moving, and the collector is
                        # concurrently draining the far end
                        self._spmc.lanes[i].push_eos(timeout=10.0)
                    except (TimeoutError, QueueClosed):
                        pass
            if self._collector is not None:
                self._collector.join(timeout=30.0)
            for p in (*self._left_procs, *self._right_procs):
                p.join(timeout=5.0)
                if p.is_alive():
                    p.terminate()
        finally:
            self._destroy()

    def _destroy(self) -> None:
        if not self._destroyed:
            self._destroyed = True
            self._spmc.destroy()
            self._grid.destroy()
            self._mpsc.destroy()

    def __del__(self):
        # a compiled-but-never-run or abandoned node must still release its
        # workers and segments (same contract as ProcessFarmNode)
        try:
            if self._destroyed:
                return
            self._spmc.close_all()
            self._grid.close_all()
            for p in (*self._left_procs, *self._right_procs):
                p.join(timeout=1.0)
                if p.is_alive():
                    p.terminate()
            self._destroy()
        except Exception:   # noqa: BLE001 - interpreter teardown
            pass

    # -- stats ---------------------------------------------------------------
    def node_stats(self) -> dict:
        with self._stats_lock:
            return {
                "node": self._label,
                "backend": "process",
                "left_workers": self._nL,
                "right_workers": self._nR,
                "items": self._seq,
                "delivered": self._delivered,
                "routed_per_left_worker": list(self._routed),
                "svc_time_ema_s": self.svc_time_ema,
                # grid high-water marks are producer-local (they live in the
                # left children), so only the parent-fed input lanes report
                "max_lane_depth": max(
                    (l.max_depth for l in self._spmc.lanes), default=0),
            }
