"""Building-blocks graph IR — the single front door to every skeleton.

FastFlow 3 evolved the tutorial's skeleton zoo (``ff_pipeline``, ``ff_farm``,
``ff_map``, feedback, ``ff_a2a``) into a uniform *building blocks* composition
API: programs are graphs of sequential / parallel building blocks, normalised
by rewrite rules, then lowered onto a runtime.  This module is that layer for
this framework:

- **IR**: :func:`seq`, :func:`pipeline`, :func:`farm`, :func:`ffmap`,
  :func:`all_to_all` build an :class:`FFGraph` of small declarative nodes
  (``SeqG``/``PipeG``/``FarmG``/``MapG``/``A2AG``).  ``wrap_around()`` marks
  the feedback channel.
- **optimize()**: normal-form rewrites — nested-pipeline flattening,
  collector–emitter collapse (pure stages adjacent to a farm are absorbed
  into its emitter/collector), and farm/pipeline fusion
  (``pipe(farm(f), farm(g)) -> farm(pipe(f, g))`` for pure workers).
- **lower(plan)**: ONE polymorphic entry point.  ``plan=None`` targets host
  threads over the SPSC networks of core/queues.py (via core/skeletons.py);
  a :class:`~repro.core.plan.ShardingPlan` targets the JAX mesh lowering of
  core/device.py.  Both return a :class:`Runner` with the same surface:
  batch ``run(stream)`` plus the paper-verbatim accelerator mode
  (``run_then_freeze`` / ``offload`` / ``load_result`` / ``wait``).

The host skeletons in core/skeletons.py remain the execution substrate; this
module is the declarative layer every subsystem (data, serving, launch,
examples) programs against.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
import traceback
import warnings
from typing import Any, Callable, List, Optional, Sequence

from .node import EOS, GO_ON, FFNode, FnNode, spawn_drainer
from .queues import MPMCQueue, MPSCQueue, SPMCQueue, SPSCQueue
from .skeletons import (AutoscaleLB, Farm, FFMap, LoadBalancer, Pipeline,
                        Skeleton, _CollectorRunner)


class GraphError(Exception):
    """Raised for malformed graphs or unlowerable target combinations."""


class Deliver:
    """Marks an item as a *result* even inside a feedback loop: with
    ``wrap_around()`` active, plain outputs re-enter the input stream while
    ``Deliver(x)`` escapes to ``load_result``."""

    __slots__ = ("value",)

    def __init__(self, value: Any):
        self.value = value


# ---------------------------------------------------------------------------
# IR nodes
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SeqG:
    """A sequential building block: an FFNode/Skeleton instance, or a plain
    callable (``pure=True`` — assumed a stateless 1->1 map, which licenses
    the optimizer to move/compose it and the device path to jit it).

    ``cost``/``placement`` are filled in by the staged compiler's
    ``annotate``/``place`` passes (core/compiler.py) — None until compiled."""
    node: Any
    pure: bool = False
    cost: Any = None
    placement: Any = None

    def describe(self) -> str:
        name = self.node.__name__ if self.pure and hasattr(self.node, "__name__") \
            else type(self.node).__name__
        return f"seq({name})"


@dataclasses.dataclass
class PipeG:
    stages: List[Any]
    cost: Any = None
    placement: Any = None

    def describe(self) -> str:
        return "pipe(" + " -> ".join(s.describe() for s in self.stages) + ")"


@dataclasses.dataclass
class FarmG:
    workers: List[Any]
    emitter: Optional[Any] = None
    collector: Optional[Any] = None
    lb: Optional[LoadBalancer] = None
    ondemand: Optional[int] = None
    fn: Optional[Callable] = None    # set when built from one replicated pure fn
    n_auto: bool = False             # width left to the compiler's cost model
    autoscale: bool = False          # host workers grow/shrink from queue depth
    cost: Any = None
    placement: Any = None

    def describe(self) -> str:
        width = "auto" if self.n_auto else str(len(self.workers))
        bits = [f"farm[{width}]({self.workers[0].describe()})"]
        if self.emitter is not None:
            bits.insert(0, f"E:{self.emitter.describe()}")
        if self.collector is not None:
            bits.append(f"C:{self.collector.describe()}")
        return " ".join(bits)


@dataclasses.dataclass
class MapG:
    splitter: Any
    workers: List[Any]
    composer: Any
    cost: Any = None
    placement: Any = None

    def describe(self) -> str:
        return f"map[{len(self.workers)}]({self.workers[0].describe()})"


@dataclasses.dataclass
class A2AG:
    """FastFlow 3's ``ff_a2a``: every left-side worker may send each output
    to any right-side worker, selected by ``router(item, n_right)``."""
    left: List[Any]
    right: List[Any]
    router: Optional[Callable[[Any, int], int]] = None
    cost: Any = None
    placement: Any = None

    def describe(self) -> str:
        return f"a2a[{len(self.left)}x{len(self.right)}]"


def _to_g(obj: Any) -> Any:
    """Coerce user objects into IR nodes."""
    if isinstance(obj, FFGraph):
        if obj._wrap:
            raise GraphError(
                "wrap_around is only honored on the top-level graph: compose "
                "the unwrapped subgraph and call wrap_around() on the result")
        return obj.root
    if isinstance(obj, (SeqG, PipeG, FarmG, MapG, A2AG)):
        return obj
    if isinstance(obj, (FFNode, Skeleton)):
        return SeqG(obj, pure=False)
    if callable(obj):
        return SeqG(obj, pure=True)
    raise GraphError(f"cannot use {obj!r} as a graph building block")


# ---------------------------------------------------------------------------
# Constructors (the public building-blocks vocabulary)
# ---------------------------------------------------------------------------
def seq(obj: Any, *, pure: Optional[bool] = None) -> "FFGraph":
    g = _to_g(obj)
    if pure is not None:
        if not isinstance(g, SeqG):
            raise GraphError("pure= applies only to a single node/callable, "
                             f"not {type(g).__name__}")
        if pure and not callable(g.node):
            raise GraphError("pure=True requires a callable: lowering calls "
                             f"it as a function, and {type(g.node).__name__} "
                             "is not one")
        # copy before overriding: _to_g may alias a node owned by another
        # graph, whose purity must not silently change under it
        g = dataclasses.replace(g, pure=pure)
    return FFGraph(g)


def pipeline(*stages: Any) -> "FFGraph":
    if not stages:
        raise GraphError("empty pipeline")
    return FFGraph(PipeG([_to_g(s) for s in stages]))


def farm(workers: Any, n: Any = None, *, emitter: Any = None,
         collector: Any = None, lb: Optional[LoadBalancer] = None,
         ondemand: Optional[int] = None, autoscale: bool = False) -> "FFGraph":
    """``farm(fn, n)`` replicates a pure worker; ``farm([w0, w1, ...])``
    takes explicit (possibly stateful) workers.

    ``n="auto"`` leaves the width to the compiler's cost model (``place``
    picks it from the annotated per-item time, ``Placement(width=...)``
    overrides).  ``autoscale=True`` (replicated pure workers only) makes the
    host farm grow/shrink its active worker set at runtime from observed
    queue depth, between 1 and ``n`` (or ``os.cpu_count()`` when ``n`` is
    omitted)."""
    fn = None
    n_auto = n == "auto" or (n is None and autoscale)
    if n_auto:
        n = None
    if isinstance(workers, (FFNode, Skeleton, FFGraph, SeqG, PipeG, FarmG,
                            MapG, A2AG)):
        g = _to_g(workers)
        if isinstance(g, SeqG) and g.pure:   # pure blocks replicate freely
            fn = g.node
            ws = [SeqG(fn, pure=True) for _ in range(n if n is not None else 1)]
        else:
            ws = [g]                         # a single stateful worker
            if n is not None and n != 1:
                raise GraphError("cannot replicate a stateful worker; pass a "
                                 "list of instances or farm(fn, n=...)")
    elif callable(workers):
        if n is None and not n_auto:
            raise GraphError("farm(fn) needs n=<replicas> (or n=\"auto\" / "
                             "autoscale=True to let the compiler choose)")
        fn = workers
        ws = [SeqG(workers, pure=True) for _ in range(n if n is not None else 1)]
    else:
        try:
            ws = [_to_g(w) for w in list(workers)]
        except TypeError as e:
            raise GraphError(f"farm workers must be a callable, a node, or "
                             f"a sequence of them (got {workers!r})") from e
        if n is not None and n != len(ws):
            raise GraphError("n disagrees with explicit worker list")
    if not ws:
        raise GraphError("farm with no workers")
    if (autoscale or n_auto) and fn is None:
        raise GraphError("n=\"auto\"/autoscale farms need one replicated pure "
                         "worker: farm(fn, autoscale=True)")
    if autoscale and (lb is not None or ondemand is not None):
        raise GraphError("autoscale installs its own load balancer; "
                         "drop lb=/ondemand= or autoscale=")
    return FFGraph(FarmG(ws, emitter=None if emitter is None else _to_g(emitter),
                         collector=None if collector is None else _to_g(collector),
                         lb=lb, ondemand=ondemand, fn=fn, n_auto=n_auto,
                         autoscale=autoscale))


def ffmap(splitter: Any, workers: Sequence, composer: Any) -> "FFGraph":
    return FFGraph(MapG(_to_g(splitter), [_to_g(w) for w in workers],
                        _to_g(composer)))


def all_to_all(left: Sequence, right: Sequence,
               router: Optional[Callable[[Any, int], int]] = None) -> "FFGraph":
    ls = [_to_g(l) for l in left]
    rs = [_to_g(r) for r in right]
    for g in (*ls, *rs):
        # the a2a runtime drives ff_node workers (svc/svc_init/svc_end);
        # composite blocks have no such surface
        if not isinstance(g, SeqG) or isinstance(g.node, Skeleton):
            raise GraphError("all_to_all workers must be plain nodes or "
                             f"callables, not {g.describe()}")
    return FFGraph(A2AG(ls, rs, router))


# ---------------------------------------------------------------------------
# Host runtime for the all-to-all stage (over the L2 MPMC network)
# ---------------------------------------------------------------------------
class A2ASkeleton(Skeleton):
    """Host lowering of ``ff_a2a``: left workers route every output through an
    MPMC grid of SPSC lanes to a router-selected right worker; right outputs
    are gathered by a collector thread.  EOS fans out row-wise so each right
    worker terminates after seeing EOS from every left worker."""

    def __init__(self, left: Sequence[FFNode], right: Sequence[FFNode],
                 router: Optional[Callable[[Any, int], int]] = None,
                 capacity: int = 512):
        super().__init__()
        self._left = list(left)
        self._right = list(right)
        self._router = router
        self._cap = capacity
        self._threads: List[threading.Thread] = []
        self._col: Optional[_CollectorRunner] = None

    def _left_loop(self, i: int, node: FFNode, has_input: bool) -> None:
        nR = len(self._right)
        rr = [i % nR]                       # stagger round-robin per producer

        def send(y: Any) -> None:
            if self._router is not None:
                # int() so jax/numpy-scalar-returning routers (shared with
                # the device lowering, where they must trace) index the grid
                j = int(self._router(y, nR)) % nR
            else:
                j, rr[0] = rr[0], (rr[0] + 1) % nR
            self._grid.push(i, j, y)

        input_eos = not has_input
        try:
            node._bind(send, i)
            if node.svc_init() < 0:
                raise RuntimeError("a2a left svc_init failed")
            while True:
                if has_input:
                    t = self._spmc.lanes[i].pop()
                    if t is EOS:
                        input_eos = True
                        break
                else:
                    t = None
                node.svc_calls += 1
                r = node.svc(t)
                if r is None or r is EOS:
                    break
                if r is not GO_ON:
                    send(r)
        except BaseException as e:          # noqa: BLE001
            node.error = e
            traceback.print_exc()
        finally:
            try:
                node.svc_end()
            finally:
                if not input_eos:
                    # early exit (voluntary or crash): hand the lane to a
                    # detached drainer FIRST — the grid EOS fan-out below can
                    # block on a dead right worker's full column, and the
                    # feeder must never wedge on this worker's input lane
                    # while that resolves
                    spawn_drainer(self._spmc.lanes[i].pop)
                for j in range(nR):
                    self._grid.push(i, j, EOS)

    def _right_loop(self, j: int, node: FFNode) -> None:
        nL = len(self._left)
        lane_out = self._mpsc.lane(j)
        eos_seen = 0
        try:
            node._bind(lane_out.push, j)
            if node.svc_init() < 0:
                raise RuntimeError("a2a right svc_init failed")
            while eos_seen < nL:
                item, _src = self._grid.pop(j)
                if item is EOS:
                    eos_seen += 1
                    continue
                node.svc_calls += 1
                r = node.svc(item)
                if r is None or r is EOS:
                    break
                if r is not GO_ON:
                    lane_out.push(r)
        except BaseException as e:          # noqa: BLE001
            node.error = e
            traceback.print_exc()
        finally:
            try:
                node.svc_end()
            finally:
                lane_out.push(EOS)
                if eos_seen < nL:
                    # early exit: keep the grid column draining so left
                    # producers never block on this dead worker's lanes
                    spawn_drainer(lambda: self._grid.pop(j)[0],
                                  nL - eos_seen)

    def _start(self, in_q: Optional[SPSCQueue]) -> None:
        nL, nR = len(self._left), len(self._right)
        self._grid = MPMCQueue(nL, nR, self._cap)
        self._mpsc = MPSCQueue(nR, self._cap)
        out = self._out if self._out is not None else (lambda item: None)
        self._col = _CollectorRunner(None, self._mpsc, out, nR)
        self._col.start()
        for j, node in enumerate(self._right):
            t = threading.Thread(target=self._right_loop, args=(j, node),
                                 daemon=True, name=f"a2a-right-{j}")
            t.start()
            self._threads.append(t)
        has_input = in_q is not None
        if has_input:
            self._spmc = SPMCQueue(nL, self._cap)
        for i, node in enumerate(self._left):
            t = threading.Thread(target=self._left_loop,
                                 args=(i, node, has_input), daemon=True,
                                 name=f"a2a-left-{i}")
            t.start()
            self._threads.append(t)
        if has_input:
            def feed() -> None:
                while True:
                    item = in_q.pop()
                    if item is EOS:
                        self._spmc.broadcast(EOS)
                        break
                    self._spmc.push_rr(item)
            t = threading.Thread(target=feed, daemon=True, name="a2a-feed")
            t.start()
            self._threads.append(t)

    def _join(self, timeout: Optional[float] = None) -> None:
        for t in self._threads:
            t.join(timeout)
        if self._col is not None:
            self._col.join(timeout)

    def _error(self) -> Optional[BaseException]:
        for n in (*self._left, *self._right):
            if n.error is not None:
                return n.error
        if self._col is not None:
            return self._col.error
        return None

    def _alive(self) -> bool:
        if any(t.is_alive() for t in self._threads):
            return True
        return self._col is not None and self._col.thread.is_alive()

    def stats(self) -> dict:
        grid = getattr(self, "_grid", None)
        return {"type": "a2a",
                "left": [n.node_stats() for n in self._left],
                "right": [n.node_stats() for n in self._right],
                "grid_max_depth": max(
                    (l.max_depth for row in grid.grid for l in row),
                    default=0) if grid is not None else 0}


# ---------------------------------------------------------------------------
# The graph
# ---------------------------------------------------------------------------
class FFGraph:
    def __init__(self, root: Any):
        self.root = root
        self._wrap = False

    def wrap_around(self) -> "FFGraph":
        """Feedback channel: the graph's output stream re-enters its input
        (paper Sec. 11); use :class:`Deliver` to emit true results."""
        self._wrap = True
        return self

    def describe(self) -> str:
        d = self.root.describe()
        return d + (" +feedback" if self._wrap else "")

    # -- normal form ---------------------------------------------------------
    def optimize(self) -> "FFGraph":
        g = FFGraph(_normalize(self.root))
        g._wrap = self._wrap
        return g

    # -- the staged compiler entry point -------------------------------------
    def compile(self, plan: Any = None, *, config: Any = None,
                **kwargs: Any) -> "Runner":
        """The staged compile pipeline ``normalize -> annotate -> place ->
        emit`` (core/compiler.py).

        The supported call shape is ``compile(config=CompileConfig(...))`` —
        every knob (plan, mode, placements, capacities, transport, adaptive,
        remote_workers, feedback bounds, ...) is a field of
        :class:`~repro.core.compiler.CompileConfig`.  ``compile()`` and
        ``compile(plan)`` stay as-is (cost-driven auto placement); passing
        any of the old flat kwargs still works but emits one
        ``DeprecationWarning`` per call naming the CompileConfig spelling.

        The four stages:

        * ``normalize`` — the :meth:`optimize` rewrites;
        * ``annotate`` — per-node :class:`~repro.core.compiler.CostEstimate`
          from ``costs=``, ``ff_cost``/``ff_flops`` attributes, or timing the
          node on ``sample=`` (which also probes GIL sensitivity unless the
          worker declares ``ff_releases_gil``);
        * ``place`` — a :class:`~repro.core.compiler.Placement` per top-level
          stage across host *threads*, host *processes* (true shared-memory
          parallelism for GIL-bound farms and ``all_to_all`` stages, costed
          with the startup-calibrated constants of ``perf_model.calibrate``;
          GIL-bound ``autoscale`` farms scale their active *process* set
          from shm lane depth), host *remote* (``host_remote`` — a farm's
          workers on other hosts, unlocked by ``remote_workers=`` and
          costed against the calibrated network hop), and the *device*;
          farm widths from the cost model; overridable via
          ``placements={stage_index_or_worker_object: ...}``;
        * ``emit`` — :class:`HostRunner`, :class:`DeviceRunner`,
          :class:`~repro.core.compiler.ProcessRunner` (farm workers as OS
          processes over shared-memory SPSC rings; a2a left/right workers
          over the ``ShmMPMCGrid`` lane grid with sequence-ordered
          collection), :class:`~repro.core.compiler.RemoteRunner` (farm
          workers on remote hosts over the credit-windowed TCP lanes of
          ``core/net.py``), or the hybrid runner (host stages over SPSC
          queues feeding device segments through device-put boundary
          nodes).

        ``feedback_steps=K`` lets a ``wrap_around`` graph lower onto the mesh
        through ``core.device.feedback_scan`` (K synchronous turns of the
        feedback channel); ``feedback_cond=pred`` makes the loop
        data-dependent instead — host runners evaluate ``pred(item)`` per
        feedback turn and deliver the item once it goes false, device
        lowering goes through ``core.device.feedback_while``
        (``lax.while_loop``) with ``feedback_steps`` as an optional cap.
        ``a2a_capacity_factor`` bounds the device
        all_to_all expert lanes (default: lossless, host-parity).
        ``shm_slot_bytes`` sizes the fixed shared-memory ring slots of
        process-placed farms (raise it for large batches).  ``transport=``
        (a :class:`~repro.core.shm.TransportConfig` or dict of its fields)
        tunes the whole shared-memory transport instead: ``ring_slots``
        (farm-lane depth cap, default 64), ``grid_slots`` (a2a grid-segment
        depth cap, default 32), ``slot_bytes`` (default 64 KiB),
        ``arena_bytes`` (oversize-ndarray slab, default 4 MiB), ``bounded``
        (False = unbounded uSPSC worker lanes), and ``batch``/``flush_s``
        (vectored flush policy); it supersedes ``shm_slot_bytes`` when both
        are given.  ``mode`` forces placement: "host", "process", "remote",
        "device", or cost-driven "auto".

        ``remote_workers=["host:port", ...]`` names a pool of
        ``python -m repro.launch.worker`` worker pools (or
        :func:`~repro.core.net.spawn_loopback_pool` addresses) and unlocks
        the ``host_remote`` target; ``net_credit`` bounds each network
        lane's in-flight window (back-pressure depth).

        ``adaptive=True`` makes eligible farm stages *re-placeable at
        runtime*: they lower to :class:`~repro.core.runtime.AdaptiveFarmNode`
        boundary nodes (sequence-ordered on both host tiers) whose width
        and thread/process tier a :class:`~repro.core.runtime.Supervisor`
        adjusts live from the runner's own ``stats()`` — see
        ``core/runtime.py``.  Without a supervisor the adaptive runner
        behaves like the static one."""
        from .compiler import CompileConfig, compile_graph
        if config is not None:
            if plan is not None:
                raise GraphError("compile(config=...) already carries the "
                                 "plan — drop the positional plan argument")
            if kwargs:
                raise GraphError("compile(config=...) does not mix with the "
                                 f"legacy kwargs {sorted(kwargs)} — set them "
                                 "on the CompileConfig instead")
            return compile_graph(self, config=config)
        if kwargs:
            known = {f.name for f in dataclasses.fields(CompileConfig)}
            unknown = sorted(k for k in kwargs if k not in known)
            if unknown:
                raise TypeError("compile() got unexpected keyword "
                                f"argument(s) {unknown}; see CompileConfig "
                                "for the supported knobs")
            warnings.warn(
                "FFGraph.compile(**kwargs) is deprecated — pass a "
                "CompileConfig: compile(config=CompileConfig("
                + ", ".join(f"{k}=..." for k in sorted(kwargs)) + "))",
                DeprecationWarning, stacklevel=2)
        return compile_graph(self, config=CompileConfig(plan=plan, **kwargs))

    def lower(self, plan: Any = None, *, capacity: int = 512,
              results_capacity: int = 4096, axis: str = "data") -> "Runner":
        """Compat wrapper over :meth:`compile`: ``plan=None`` forces every
        stage onto host threads (:class:`HostRunner`); a ShardingPlan forces
        the whole graph onto the mesh (:class:`DeviceRunner`)."""
        from .compiler import compile_graph
        return compile_graph(self, plan,
                             mode="host" if plan is None else "device",
                             normalize=False, capacity=capacity,
                             results_capacity=results_capacity, axis=axis)


# ---------------------------------------------------------------------------
# optimize(): rewrite passes
# ---------------------------------------------------------------------------
def _compose(f: Callable, g: Callable) -> Callable:
    def fg(x):
        return g(f(x))
    fg.__name__ = "fused"
    return fg


def _is_pure_seq(n: Any) -> bool:
    return isinstance(n, SeqG) and n.pure


def _pure_of(n: Any) -> Optional[Callable]:
    """The per-item pure function a node computes, or None if stateful."""
    if _is_pure_seq(n):
        return n.node
    if isinstance(n, PipeG):
        fns = [_pure_of(s) for s in n.stages]
        if any(f is None for f in fns):
            return None
        out = fns[0]
        for f in fns[1:]:
            out = _compose(out, f)
        return out
    return None


def _fusable_farm(n: Any) -> bool:
    return (isinstance(n, FarmG) and n.emitter is None and n.collector is None
            and n.lb is None and n.ondemand is None
            and all(_pure_of(w) is not None for w in n.workers))


def _normalize(n: Any) -> Any:
    if isinstance(n, PipeG):
        # 1. flatten nested pipelines
        stages: List[Any] = []
        for s in n.stages:
            s = _normalize(s)
            if isinstance(s, PipeG):
                stages.extend(s.stages)
            else:
                stages.append(s)
        # 2. farm/pipeline fusion: pipe(farm(f), farm(g)) -> farm(pipe(f,g))
        fused: List[Any] = []
        for s in stages:
            prev = fused[-1] if fused else None
            if (_fusable_farm(s) and _fusable_farm(prev)
                    and len(prev.workers) == len(s.workers)):
                fn = (_compose(prev.fn, s.fn)
                      if prev.fn is not None and s.fn is not None else None)
                if (fn is None and (prev.n_auto or s.n_auto
                                    or prev.autoscale or s.autoscale)):
                    # an auto/autoscale width needs a replicable fn: fusing
                    # without one would silently pin the farm to width 1
                    fused.append(s)
                    continue
                workers = [PipeG([a, b])
                           for a, b in zip(prev.workers, s.workers)]
                fused[-1] = FarmG(workers, fn=fn,
                                  n_auto=prev.n_auto or s.n_auto,
                                  autoscale=prev.autoscale or s.autoscale)
                continue
            fused.append(s)
        # 3. collector-emitter collapse: absorb pure seq stages into the
        #    adjacent farm's emitter/collector (one thread + one queue less)
        out: List[Any] = []
        for s in fused:
            prev = out[-1] if out else None
            if (_is_pure_seq(s) and isinstance(prev, FarmG)
                    and (prev.collector is None or _is_pure_seq(prev.collector))):
                col = (s if prev.collector is None
                       else SeqG(_compose(prev.collector.node, s.node), pure=True))
                out[-1] = dataclasses.replace(prev, collector=col)
                continue
            if (isinstance(s, FarmG) and _is_pure_seq(prev) and len(out) > 1
                    and (s.emitter is None or _is_pure_seq(s.emitter))):
                # only absorb a *non-source* stage: the first pipeline stage
                # may be a generator driven with task=None
                em = (prev if s.emitter is None
                      else SeqG(_compose(prev.node, s.emitter.node), pure=True))
                out[-1] = dataclasses.replace(s, emitter=em)
                continue
            out.append(s)
        return out[0] if len(out) == 1 else PipeG(out)
    if isinstance(n, FarmG):
        return dataclasses.replace(n, workers=[_normalize(w) for w in n.workers])
    if isinstance(n, MapG):
        return dataclasses.replace(n, workers=[_normalize(w) for w in n.workers])
    if isinstance(n, A2AG):
        return dataclasses.replace(n, left=[_normalize(l) for l in n.left],
                                   right=[_normalize(r) for r in n.right])
    return n


# ---------------------------------------------------------------------------
# Host lowering
# ---------------------------------------------------------------------------
def _mark_single_use(node: Any) -> Any:
    """Stateful node instances carry consumed counters and dead threads after
    a run; building them into a second runner silently replays stale state,
    so re-lowering is an error — build a fresh instance/graph instead."""
    if getattr(node, "_ff_lowered", False):
        raise GraphError(f"{type(node).__name__} instance is already part of "
                         "a lowered runner; stateful nodes are single-use — "
                         "construct a fresh graph to run again")
    node._ff_lowered = True
    return node


def _build_host(n: Any, capacity: int) -> Any:
    if isinstance(n, SeqG):
        return FnNode(n.node) if n.pure else _mark_single_use(n.node)
    if isinstance(n, PipeG):
        return Pipeline(*[_build_host(s, capacity) for s in n.stages],
                        capacity=capacity)
    if isinstance(n, FarmG):
        workers, lb = n.workers, n.lb
        if n.autoscale:
            # materialize the max worker set; the balancer moves the active
            # boundary at runtime from observed lane depth
            max_w = (max(1, os.cpu_count() or 1) if n.n_auto
                     else max(1, len(n.workers)))
            workers = [SeqG(n.fn, pure=True) for _ in range(max_w)]
            lb = AutoscaleLB(max_workers=max_w)
        elif n.n_auto and len(n.workers) == 1:
            # width left to the compiler; emit() materializes the cost-chosen
            # width — this fallback covers direct lower() of an auto farm
            width = getattr(n.placement, "width", None) or (os.cpu_count() or 1)
            workers = [SeqG(n.fn, pure=True) for _ in range(max(1, width))]
        # a LoadBalancer binds to one farm's lanes at _start: sharing it
        # across lowerings would let one runner steal another's routing
        f = Farm([_build_host(w, capacity) for w in workers],
                 lb=lb if n.autoscale else
                 (None if lb is None else _mark_single_use(lb)),
                 capacity=capacity)
        if n.emitter is not None:
            f.add_emitter(_build_host(n.emitter, capacity))
        if n.collector is not None:
            f.add_collector(_build_host(n.collector, capacity))
        if n.ondemand is not None:
            f.set_scheduling_ondemand(n.ondemand)
        return f
    if isinstance(n, MapG):
        return FFMap(_build_host(n.splitter, capacity),
                     [_build_host(w, capacity) for w in n.workers],
                     _build_host(n.composer, capacity), capacity=capacity)
    if isinstance(n, A2AG):
        return A2ASkeleton([_build_host(l, capacity) for l in n.left],
                           [_build_host(r, capacity) for r in n.right],
                           router=n.router, capacity=capacity)
    raise GraphError(f"cannot host-lower {n!r}")


class StageHandle:
    """The uniform per-stage sample + reconfigure surface the adaptive
    runtime (``core/runtime.py``) consumes across every runner.

    The base handle is *read-only*: ``stats()`` snapshots the stage's
    runtime counters and the reconfigure operations refuse.  Adaptive farm
    stages (``compile(adaptive=True)``) return a reconfigurable subclass
    whose ``resize`` moves the active-worker routing boundary and whose
    ``migrate`` drains the stage to a quiescent boundary and hot-swaps its
    engine between the thread and process tiers."""

    reconfigurable = False

    def __init__(self, desc: str, target: Any = None,
                 stats_fn: Optional[Callable[[], dict]] = None,
                 tier: str = "host"):
        self.desc = desc
        self._target = target
        self._stats_fn = stats_fn
        self._tier = tier

    @property
    def tier(self) -> str:
        return self._tier

    def stats(self) -> dict:
        if self._stats_fn is not None:
            return self._stats_fn()
        from .skeletons import _stat_of
        return _stat_of(self._target)

    def can_migrate(self, target: str) -> bool:
        return False

    def resize(self, width: int) -> bool:
        raise GraphError(f"stage {self.desc!r} is not reconfigurable "
                         "(compile with adaptive=True for live resize)")

    def migrate(self, target: str) -> bool:
        raise GraphError(f"stage {self.desc!r} is not reconfigurable "
                         "(compile with adaptive=True for live migration)")


class Runner:
    """Common result surface of ``FFGraph.lower``/``FFGraph.compile``."""

    placements: List = []       # [(stage description, Placement)] from emit

    def run(self, stream: Optional[Sequence] = None) -> List[Any]:
        raise NotImplementedError

    def ffTime(self) -> float:
        return (self._t1 - self._t0) * 1e3

    def describe_placements(self) -> str:
        return "\n".join(f"  [{p.target:12s}] {desc}"
                         + (f" width={p.width}" if p.width else "")
                         + (f"  # {p.reason}" if p.reason else "")
                         for desc, p in self.placements)

    def stats(self) -> dict:
        """Runtime stats: per-node service-time EMA, items processed, max
        observed lane depth — populated while/after the graph runs."""
        return {}

    def stage_handles(self) -> List[StageHandle]:
        """One :class:`StageHandle` per top-level stage — the surface the
        adaptive supervisor samples (and, for adaptive stages, acts on)."""
        return []

    def replacement_events(self) -> List[Any]:
        """Re-placement events (tier migrations) recorded by adaptive stages
        — printed by the launchers' placement reports."""
        return []


class HostRunner(Runner):
    """Graph lowered onto host threads + SPSC queues, exposing both batch
    ``run`` and the paper's accelerator mode (the compat adapter behind
    ``InferenceEngine`` / ``JaxAccelerator``-style usage)."""

    def __init__(self, graph: FFGraph, capacity: int = 512,
                 results_capacity: int = 4096,
                 feedback_cond: Optional[Callable] = None):
        built = _build_host(graph.root, capacity)
        if not isinstance(built, Skeleton):
            built = Pipeline(built, capacity=capacity)
        self._skel = built
        self._wrap = graph._wrap
        # data-dependent feedback: an item coming off the feedback edge
        # re-enters the loop only while cond(item) holds, and is delivered
        # as a result once it goes false (mirrors device feedback_while)
        self._feedback_cond = feedback_cond if graph._wrap else None
        self._cap = capacity
        self._results = SPSCQueue(results_capacity)
        self._in_q: Optional[SPSCQueue] = None
        # the input queue can see several producers (offload, the feedback
        # edge, wait()'s error unwind): serialise pushes so the SPSC
        # invariant holds
        self._push_lock = threading.Lock()
        self._fed = 0
        self._feed_done = False
        self._t0 = self._t1 = 0.0

    # -- wiring ---------------------------------------------------------------
    def _push_in(self, item: Any) -> None:
        # per-attempt locking (never a blocking push while holding the lock,
        # or wait()'s unwind could deadlock on it), and bail out once the
        # whole network has died — its results stream is already closed, so
        # blocking a producer on a queue nobody drains helps no one.  A
        # degraded-but-alive network keeps consuming (dead nodes drain their
        # inputs), so items are only dropped when no thread is left.
        while True:
            with self._push_lock:
                if self._in_q.try_push(item):
                    return
            if not self._skel._alive():   # terminated (cleanly or by error)
                return
            time.sleep(1e-5)

    def _route(self, item: Any) -> None:
        if item is EOS:
            self._results.push(EOS)
        elif isinstance(item, Deliver):
            self._results.push(item.value)
        elif self._wrap:
            if (self._feedback_cond is not None
                    and not bool(self._feedback_cond(item))):
                self._results.push(item)
            else:
                self._push_in(item)
        else:
            self._results.push(item)

    # -- accelerator mode (paper Sec. 9, verbatim names) ----------------------
    def run_then_freeze(self) -> int:
        self._t0 = time.perf_counter()
        self._in_q = self._skel._make_input(self._cap)
        self._skel._bind(self._route)
        self._skel._start(self._in_q)
        return 0

    def offload(self, task: Any) -> None:
        if self._in_q is None:
            raise RuntimeError("offload before run_then_freeze")
        self._push_in(task)

    def load_result(self, timeout: Optional[float] = None) -> tuple[bool, Any]:
        item = self._results.pop(timeout)
        return (False, None) if item is EOS else (True, item)

    def load_result_nb(self) -> tuple[bool, Any]:
        ok, item = self._results.try_pop()
        if not ok or item is EOS:
            return False, None
        return True, item

    def pending_inputs(self) -> int:
        """Items offloaded but not yet consumed by the first stage — lets
        callers implement admission back-pressure over the full backlog."""
        return 0 if self._in_q is None else len(self._in_q)

    def wait(self, timeout: Optional[float] = None) -> int:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self.error() is not None and self._in_q is not None:
                # a stage died mid-network: stages upstream of the fault are
                # still blocked on their input queues — unwind them with EOS
                # so join() terminates and the error is reported instead of
                # hanging.  Non-blocking (retried each slice) so a full queue
                # whose consumer died cannot wedge the unwind itself.
                with self._push_lock:
                    self._in_q.try_push(EOS)
            slice_t = 0.1
            if deadline is not None:
                slice_t = min(slice_t, max(0.0, deadline - time.monotonic()))
            self._skel._join(slice_t)
            if not self._skel._alive():
                # terminated: feed one EOS to the input so any detached
                # drainer left by a self-terminated first stage can finish
                # instead of polling a dead queue for the process lifetime.
                # Retried briefly — a live drainer frees a slot of a full
                # queue within its 1ms backoff; with no consumer we give up.
                if self._in_q is not None:
                    for _ in range(100):
                        with self._push_lock:
                            if self._in_q.try_push(EOS):
                                break
                        time.sleep(1e-3)
                break
            if deadline is not None and time.monotonic() >= deadline:
                break
        self._t1 = time.perf_counter()
        return -1 if self.error() is not None else 0

    def error(self) -> Optional[BaseException]:
        return self._skel._error()

    # -- source / streaming mode ----------------------------------------------
    def start_stream(self) -> "HostRunner":
        """Start a source graph (first stage generates); results stream into
        the bounded results queue — back-pressure for prefetch pipelines."""
        self._t0 = time.perf_counter()
        if self._wrap:
            self._in_q = self._skel._make_input(self._cap)
        self._skel._bind(self._route)
        self._skel._start(self._in_q)
        return self

    def get(self, timeout: Optional[float] = None) -> Any:
        """Next streamed result; None at end-of-stream."""
        item = self._results.pop(timeout)
        return None if item is EOS else item

    # -- batch convenience -----------------------------------------------------
    def run_and_wait_end(self) -> int:
        """Run a source graph to completion.  There is no result consumer, so
        outputs are discarded (sinks act via side effects, as in the paper's
        run_and_wait_end) — the bounded results queue must not back-pressure
        a network nobody is draining."""
        self._t0 = time.perf_counter()
        if self._wrap:
            self._in_q = self._skel._make_input(self._cap)

            def route(item: Any) -> None:
                if item is not EOS and not isinstance(item, Deliver):
                    self._push_in(item)
            self._skel._bind(route)
        else:
            self._skel._bind(lambda item: None)
        self._skel._start(self._in_q)
        self._skel._join()
        self._t1 = time.perf_counter()
        return -1 if self.error() is not None else 0

    def run(self, stream: Optional[Sequence] = None,
            timeout: Optional[float] = None) -> List[Any]:
        """Feed ``stream`` (or let sources run) and collect all outputs.
        ``timeout`` bounds each blocking wait, not the whole run; on
        TimeoutError the feeder stops but node threads cannot be killed —
        discard the runner (graphs are single-use anyway)."""
        self._abandoned = False
        self._fed, self._feed_done = 0, False
        # a cond-terminated feedback graph delivers exactly one result per
        # fed item (each loops until its cond goes false) but no node ever
        # returns EOS — the collector below counts it out, then run() feeds
        # the terminating EOS itself
        counted = (stream is not None and self._wrap
                   and self._feedback_cond is not None)
        if stream is None:
            self.start_stream()
        else:
            self.run_then_freeze()

            def feed() -> None:
                # a separate feeder so collection below drains results while
                # offloading — a long stream must not fill every queue and
                # deadlock against an unread results queue
                for x in stream:
                    if self._abandoned:
                        return
                    self.offload(x)
                    self._fed += 1
                self._feed_done = True
                if not self._wrap:      # feedback graphs terminate themselves
                    self.offload(EOS)
            threading.Thread(target=feed, daemon=True,
                             name="ff-run-feeder").start()
        out = []
        try:
            last = time.monotonic()
            while True:
                if counted and self._feed_done and len(out) >= self._fed:
                    break
                if counted:
                    # bounded slices so the count-out condition above is
                    # rechecked after the feeder finishes (an unbounded pop
                    # could block forever once the last result is in)
                    try:
                        item = self._results.pop(0.05)
                    except TimeoutError:
                        if timeout is not None \
                                and time.monotonic() - last > timeout:
                            raise
                        continue
                    last = time.monotonic()
                else:
                    item = self._results.pop(timeout)
                if item is EOS:
                    break
                out.append(item)
        except BaseException:
            self._abandoned = True
            raise
        if counted:
            self.offload(EOS)
        if self.wait(timeout) != 0:
            raise self.error()
        return out

    def shutdown(self, timeout: float = 10.0) -> None:
        """Best-effort unwind for a runner being discarded before its
        stream ended (error, timeout, lost interest): feeds EOS so node
        threads terminate and process-farm stages release their worker
        processes and shared-memory segments.  Without this, a discarded
        mid-stream runner's daemon threads (and any shm segments) linger
        until interpreter exit."""
        self._abandoned = True
        if self._in_q is not None:
            with self._push_lock:
                self._in_q.try_push(EOS)
        self.wait(timeout)

    def stats(self) -> dict:
        return {"backend": type(self).__name__,
                "graph": self._skel.stats(),
                "results_max_depth": self._results.max_depth}

    def _top_members(self) -> List[Any]:
        skel = self._skel
        return list(skel._stages) if isinstance(skel, Pipeline) else [skel]

    def stage_handles(self) -> List[StageHandle]:
        handles = []
        for st in self._top_members():
            # a stage that builds its own handle (AdaptiveFarmNode,
            # net.RemoteFarmNode) knows its tier and reconfig surface
            if hasattr(st, "make_handle"):
                handles.append(st.make_handle())
            else:
                desc = getattr(st, "_label", None) or type(st).__name__
                handles.append(StageHandle(desc, st))
        return handles

    def replacement_events(self) -> List[Any]:
        out: List[Any] = []
        for st in self._top_members():
            out.extend(getattr(st, "migrations", ()) or ())
        return out


# ---------------------------------------------------------------------------
# Device lowering
# ---------------------------------------------------------------------------
def _device_fn(n: Any) -> tuple[Callable, bool]:
    """(per-item function, uses-farm?) for a device-lowerable subgraph."""
    if isinstance(n, SeqG):
        if not n.pure:
            raise GraphError("device lowering needs pure stages "
                             f"(got {type(n.node).__name__})")
        return n.node, False
    if isinstance(n, PipeG):
        fns = [_device_fn(s) for s in n.stages]
        fn = fns[0][0]
        for f, _ in fns[1:]:
            fn = _compose(fn, f)
        return fn, any(farm for _, farm in fns)
    if isinstance(n, FarmG):
        if n.lb is not None or n.ondemand is not None:
            # a custom balancer (e.g. BroadcastLB) changes which/how many
            # outputs exist; SPMD batch sharding is round-robin only
            raise GraphError("device farm lowering supports only the default "
                             "round-robin schedule (no lb/ondemand)")
        if n.fn is None and len(n.workers) > 1:
            # an explicit worker list may be heterogeneous; SPMD lowering
            # replicates ONE function, so silently picking workers[0] would
            # diverge from the host round-robin
            raise GraphError("device farm lowering is SPMD: build the farm "
                             "from one replicated worker (farm(fn, n=...))")
        fn = n.fn if n.fn is not None else _pure_of(n.workers[0])
        if fn is None:
            raise GraphError("device farm lowering needs pure workers")
        for part in (n.emitter, n.collector):
            if part is not None:
                if not _is_pure_seq(part):
                    raise GraphError("device farm lowering needs pure "
                                     "emitter/collector")
        if n.emitter is not None:
            fn = _compose(n.emitter.node, fn)
        if n.collector is not None:
            fn = _compose(fn, n.collector.node)
        return fn, True
    if isinstance(n, MapG):
        # ffmap folds in as a vmapped body: per item, the (pure) splitter
        # yields the worker parts — a tuple/list of len(workers), or an
        # array whose leading axis unstacks to one part per worker — each
        # worker maps its part, and the (pure) composer rebuilds from the
        # results tuple.  The data-parallel map over *items* then rides the
        # same farm_map/vmap path as a device farm.
        parts_fns = []
        for w in n.workers:
            f = _pure_of(w)
            if f is None:
                raise GraphError("device map lowering needs pure workers")
            parts_fns.append(f)
        split_fn = _pure_of(n.splitter)
        comp_fn = _pure_of(n.composer)
        if split_fn is None or comp_fn is None:
            raise GraphError(
                "device map lowering needs a pure splitter/composer "
                "(per item: splitter -> len(workers) parts, composer <- "
                "results tuple); stateful multi-emit splitters are "
                "host-only")

        def _map_fn(x, _split=split_fn, _comp=comp_fn,
                    _parts=tuple(parts_fns)):
            parts = _split(x)
            if not isinstance(parts, (tuple, list)):
                parts = tuple(parts[i] for i in range(len(_parts)))
            if len(parts) != len(_parts):
                raise GraphError(
                    f"device map splitter yielded {len(parts)} parts for "
                    f"{len(_parts)} workers")
            return _comp(tuple(f(p) for f, p in zip(_parts, parts)))

        return _map_fn, True
    raise GraphError(f"no device lowering for {type(n).__name__} here "
                     "(all_to_all/feedback lower only at the top level of the "
                     "graph via compile(); otherwise use the host path or "
                     "feedback_scan/tensor_map directly)")


class DeviceRunner(Runner):
    """Graph lowered through core/device.py onto a JAX mesh: the stream is
    stacked into a batch, farm stages become ``shard_map`` over the data axis
    (round-robin == even batch sharding), pure seq stages are jitted and
    vmapped, ``all_to_all`` stages become MoE-style dispatch/combine
    (``core.device.a2a_dispatch``), and ``wrap_around`` graphs run
    ``feedback_steps`` synchronous turns through ``core.device.feedback_scan``.
    Semantics match :class:`HostRunner` on pure graphs up to output ordering
    (the host farm collector is arrival-ordered).

    The whole graph compiles as ONE part — a single jitted program per
    device run (the ``core/fuse.py`` device-segment fusion): N adjacent
    stages cost one dispatch and one host sync per batch, with all
    cross-stage XLA fusion intact, and ``stats()`` reports one fused entry
    whose label lists the composed stages.  ``fuse=False`` restores the
    one-program-per-stage split (one entry per top-level stage, one jit +
    one host sync each) — per-stage observability for A/B benchmarks and
    the adaptive runtime's attribution experiments; a ``wrap_around`` graph
    always runs its feedback loop as one fused part.

    ``microbatch=`` switches ``run`` from one whole-stream batch to a
    *software pipeline* of microbatches through the overlapped boundary:
    each chunk is dispatched asynchronously (no per-chunk
    ``block_until_ready``) and retired FIFO once ``inflight`` newer chunks
    ride behind it, so host stacking of chunk *i+1* and the copy-out of
    *i-1* overlap the device compute of *i*.  Absolute per-chunk stream
    offsets keep ``all_to_all`` routing identical to the whole-batch path;
    ``overlap=False`` (or ``inflight=1``) runs the same chunking strictly
    synchronously.  ``stats()['boundary']`` splits the run into h2d stack
    time, async submit, and drain (compute remainder + d2h) so placement
    reports show where the boundary is stall-bound."""

    def __init__(self, graph: FFGraph, plan: Any, axis: str = "data",
                 feedback_steps: Optional[int] = None,
                 feedback_cond: Optional[Callable] = None,
                 a2a_capacity_factor: Optional[float] = None,
                 fuse: bool = True, overlap: bool = True,
                 microbatch: Optional[int] = None,
                 inflight: Optional[int] = None):
        from . import perf_model as pm
        from .compiler import _top_stages, make_device_batched
        from .fuse import jit_segment, segment_key
        self._t0 = self._t1 = 0.0
        self._items = 0
        self._batches = 0
        self._stats_lock = threading.Lock()
        # _parts: [desc, jitted batched(xs, offset), svc_time_ema_s, items]
        self._parts: List[List[Any]] = []
        self._axis_size = 1
        # a feedback loop runs its turns over the whole batch at once:
        # chunking would re-trace the scan per chunk shape for no benefit
        self._microbatch = None if graph._wrap else microbatch
        if inflight is None:
            rec = pm.lookup_autotuned("device_overlap:window")
            inflight = int(rec.get("inflight", 2)) if rec else 2
        self._inflight = max(1, int(inflight)) if overlap else 1
        # boundary accounting (cumulative seconds; under _stats_lock)
        self._b_h2d = 0.0      # host stack + device transfer submit
        self._b_submit = 0.0   # async dispatch of the jitted parts
        self._b_drain = 0.0    # copy-out wait (compute remainder + d2h)
        self._b_stall = 0.0    # drain share paid while the window was full
        self._chunks = 0

        def _add_part(sub: FFGraph, desc: str,
                      steps: Optional[int] = None,
                      cond: Optional[Callable] = None) -> None:
            batched, mult = make_device_batched(
                sub, plan, axis=axis, feedback_steps=steps,
                feedback_cond=cond,
                a2a_capacity_factor=a2a_capacity_factor)
            key = segment_key(sub, 0, mult, plan, axis,
                              a2a_capacity_factor, steps, cond)
            self._parts.append([desc, jit_segment(batched, key), 0.0, 0])
            self._axis_size = max(self._axis_size, mult)

        if graph._wrap:
            _add_part(graph, graph.describe(), steps=feedback_steps,
                      cond=feedback_cond)
        elif fuse:
            stages = _top_stages(graph)
            _add_part(graph, " + ".join(s.describe() for s in stages))
        else:
            for s in _top_stages(graph):
                _add_part(FFGraph(s), s.describe())

    def run(self, stream: Sequence) -> List[Any]:
        import jax
        import jax.numpy as jnp
        import numpy as np
        self._t0 = time.perf_counter()
        items = [np.asarray(x) for x in stream]
        if not items:
            return []
        if self._microbatch is not None:
            return self._run_pipelined(items)
        n = len(items)
        pad = (-n) % self._axis_size
        # stack on the host, then ONE device put for the whole batch
        # (jnp.asarray canonicalizes dtypes exactly like per-item asarray did)
        xs = jnp.asarray(np.stack(items + items[:1] * pad))
        offset = jnp.int32(0)
        for part in self._parts:
            t0 = time.perf_counter()
            xs = jax.block_until_ready(part[1](xs, offset))
            per_item = (time.perf_counter() - t0) / n
            with self._stats_lock:
                part[2] = per_item if part[3] == 0 \
                    else 0.5 * part[2] + 0.5 * per_item
                part[3] += n
        ys = xs
        self._t1 = time.perf_counter()
        with self._stats_lock:
            self._items += n
            self._batches += 1
        # ONE device->host copy per output leaf, then numpy slicing — per-item
        # jax indexing would pay a dispatch per item and dominate small runs.
        # A per-item function may return a pytree; padding rows dropped.
        host = jax.tree.map(np.asarray, ys)
        return [jax.tree.map(lambda t: t[i], host) for i in range(n)]

    def _run_pipelined(self, items: List[Any]) -> List[Any]:
        """The overlapped boundary: chunk the stream into microbatches and
        keep a depth-K window of them in flight.  Dispatch never syncs —
        the oldest chunk is only awaited (FIFO, so order is exact) once the
        window is full; bytes match the whole-batch path because each chunk
        runs the same jitted parts at its absolute stream offset."""
        import collections
        import jax
        import jax.numpy as jnp
        import numpy as np
        B = max(int(self._microbatch), self._axis_size)
        out: List[Any] = []
        window = collections.deque()   # FIFO of (k, ys) in flight

        def retire(k: int, ys: Any, stalled: bool) -> None:
            t0 = time.perf_counter()
            host = jax.tree.map(np.asarray, ys)
            dt = time.perf_counter() - t0
            with self._stats_lock:
                self._b_drain += dt
                if stalled:
                    self._b_stall += dt
            out.extend(jax.tree.map(lambda t, i=i: t[i], host)
                       for i in range(k))

        n = len(items)
        for start in range(0, n, B):
            chunk = items[start:start + B]
            k = len(chunk)
            pad = (-k) % self._axis_size
            t0 = time.perf_counter()
            xs = jnp.asarray(np.stack(chunk + chunk[:1] * pad))
            t1 = time.perf_counter()
            # async dispatch of every part at this chunk's absolute stream
            # offset (all_to_all routing parity with the host feeder)
            offset = jnp.int32(start)
            ys = xs
            for part in self._parts:
                ys = part[1](ys, offset)
            t2 = time.perf_counter()
            with self._stats_lock:
                self._b_h2d += t1 - t0
                self._b_submit += t2 - t1
                self._chunks += 1
                per_item = (t2 - t0) / k / max(1, len(self._parts))
                for part in self._parts:
                    # submit-side attribution only: the drain below is a
                    # boundary property, not any one part's service time
                    part[2] = per_item if part[3] == 0 \
                        else 0.5 * part[2] + 0.5 * per_item
                    part[3] += k
            if self._inflight <= 1:
                retire(k, ys, stalled=False)   # the synchronous boundary
                continue
            for leaf in jax.tree.leaves(ys):
                copy = getattr(leaf, "copy_to_host_async", None)
                if copy is not None:
                    try:
                        copy()
                    except Exception:   # noqa: BLE001 - optional fast path
                        pass
            window.append((k, ys))
            while len(window) > self._inflight:
                retire(*window.popleft(), stalled=True)
        while window:
            retire(*window.popleft(), stalled=False)
        self._t1 = time.perf_counter()
        with self._stats_lock:
            self._items += n
            self._batches += 1
        return out

    def stats(self) -> dict:
        with self._stats_lock:
            stages = [{"node": f"device[{desc}]", "backend": "device",
                       "items": it, "svc_time_ema_s": ema}
                      for desc, _fn, ema, it in self._parts]
            drain = self._b_drain
            return {"backend": "DeviceRunner", "items": self._items,
                    "batches": self._batches,
                    "svc_time_ema_s": sum(s["svc_time_ema_s"]
                                          for s in stages),
                    "boundary": {
                        "mode": ("overlapped" if self._microbatch is not None
                                 and self._inflight > 1 else "sync"),
                        "microbatch": self._microbatch or 0,
                        "inflight": self._inflight, "chunks": self._chunks,
                        "h2d_s": round(self._b_h2d, 6),
                        "submit_s": round(self._b_submit, 6),
                        "drain_s": round(drain, 6),
                        "stall_s": round(self._b_stall, 6),
                        "stall_frac": round(self._b_stall / drain, 4)
                        if drain > 0 else 0.0,
                    },
                    "stages": stages}

    def stage_handles(self) -> List[StageHandle]:
        def snap(part):
            with self._stats_lock:
                return {"node": f"device[{part[0]}]", "backend": "device",
                        "items": part[3], "svc_time_ema_s": part[2]}
        return [StageHandle(p[0], stats_fn=(lambda p=p: snap(p)),
                            tier="device") for p in self._parts]
