"""L1/L2 — true shared-memory channels for the process-backed host tier.

``core/queues.py`` carries the thread-backed host tier; its rings are Python
lists, so they cannot cross a process boundary and its CPU-bound producers
serialize on the GIL.  This module is the same FastFlow layer-1 structure on
``multiprocessing.shared_memory``: a fixed-slot single-producer /
single-consumer ring whose indices live *in* the shared segment, with the
same wait-free single-writer discipline — the producer only writes ``tail``,
the consumer only writes ``head``, each as one aligned 8-byte store (a single
memcpy in CPython, atomic on every platform we target), so neither side ever
takes a lock on the fast path.

Payload encoding per slot:

- **ndarray fast path** (tag ``ARR``): dtype/shape header plus the raw data
  bytes copied straight into the slot — no pickling of the buffer;
- **pickle fallback** (tag ``PKL``): arbitrary pytrees / Python objects as
  pickled bytes;
- **control tags**: ``EOS`` (end-of-stream; decoded back to the module-wide
  :data:`~repro.core.node.EOS` sentinel so identity checks keep working
  across the boundary) and ``ERR`` (a pickled error record from a worker).

Each slot header also carries a **u64 sequence number** alongside the
length/tag word.  Per-lane FIFO order is enough for a farm (one hop, parent
assigns seqs and matches results by arrival order), but the ``all_to_all``
grid routes items data-dependently across two hops, so the seq must ride the
wire with the payload — in the fixed header, not the payload, so bare
ndarrays keep the raw-slab fast path.

Layer 2 composes the same SPMC / MPSC lane bundles as ``core/queues.py`` out
of these rings — the emitter/collector wiring of a process farm — plus
:class:`ShmMPMCGrid`, the process-tier instance of
``queues.MPMCQueue``: an nL x nR grid of SPSC lanes where producer ``i``
owns row ``i`` and consumer ``j`` owns column ``j``, so every lane keeps the
single-writer index discipline.  It is the interconnect of the process-backed
``all_to_all`` (``core/process.ProcessA2ANode``).
"""

from __future__ import annotations

import pickle
import struct
import time
from multiprocessing import shared_memory
from typing import Any, List, Optional, Tuple

import numpy as np

from .node import EOS
from .queues import QueueClosed

# ring header: producer / consumer indices on separate cache lines, plus the
# closed flag (written by the producer, read by both sides)
_OFF_TAIL = 0
_OFF_HEAD = 64
_OFF_CLOSED = 128
_HEADER = 192

_SLOT_HDR = 16           # u32 payload length | u8 tag | 3B pad | u64 seq
_SLOT_FMT = "<IB3xQ"

TAG_PKL = 0
TAG_ARR = 1
TAG_EOS = 2
TAG_ERR = 3


class ShmError:
    """A worker-side failure shipped through the ring (tag ``ERR``)."""

    __slots__ = ("worker", "exc", "tb")

    def __init__(self, worker: int, exc: str, tb: str):
        self.worker = worker
        self.exc = exc
        self.tb = tb

    def __repr__(self) -> str:
        return f"ShmError(worker={self.worker}, exc={self.exc!r})"


class WorkerStats:
    """A worker-side CPU-time record shipped over a result lane (seq-less
    control payload, not a stream item): ``items`` processed so far and an
    EMA of per-item *CPU* seconds (``time.thread_time``).  Farms fold these
    into ``node_stats()["svc_cpu_ema_s"]`` so the runtime Supervisor's
    process→thread policy compares true service times instead of inferring
    them from hop domination."""

    __slots__ = ("worker", "items", "cpu_ema_s")

    def __init__(self, worker: int, items: int, cpu_ema_s: float):
        self.worker = worker
        self.items = items
        self.cpu_ema_s = cpu_ema_s

    def __repr__(self) -> str:
        return (f"WorkerStats(worker={self.worker}, items={self.items}, "
                f"cpu_ema_s={self.cpu_ema_s:.3g})")


def _unregister_tracker(name: str) -> None:
    # attaching registers the segment with this process's resource_tracker,
    # which would unlink it when the attacher exits; only the creator owns
    # the segment's lifetime
    try:
        from multiprocessing import resource_tracker
        resource_tracker.unregister("/" + name, "shared_memory")
    except Exception:   # noqa: BLE001 - best effort, platform-dependent
        pass


class ShmSPSCQueue:
    """Bounded SPSC ring over one shared-memory segment.

    Same surface as :class:`~repro.core.queues.SPSCQueue` (``try_push`` /
    ``try_pop`` / blocking wrappers / ``close``), crossing a process
    boundary.  The object is picklable: unpickling (or ``attach``) maps the
    same segment by name, so a ``fork``- or ``spawn``-started worker sees the
    identical ring.  Only the creating process may ``unlink``.
    """

    def __init__(self, capacity: int = 64, slot_bytes: int = 1 << 16,
                 name: Optional[str] = None, _create: bool = True):
        if capacity < 2:
            raise ValueError("capacity must be >= 2")
        self._cap = capacity
        self._slot = slot_bytes
        self._stride = _SLOT_HDR + slot_bytes
        self._creator = _create
        self.max_depth = 0          # producer-side observation, process-local
        size = _HEADER + capacity * self._stride
        if _create:
            self._shm = shared_memory.SharedMemory(create=True, size=size)
        else:
            self._shm = shared_memory.SharedMemory(name=name)
            _unregister_tracker(self._shm.name)
        self._buf = self._shm.buf

    # -- pickling: reattach by name -----------------------------------------
    def __getstate__(self):
        return {"capacity": self._cap, "slot_bytes": self._slot,
                "name": self._shm.name}

    def __setstate__(self, state):
        self.__init__(state["capacity"], state["slot_bytes"],
                      name=state["name"], _create=False)

    @property
    def name(self) -> str:
        return self._shm.name

    @property
    def capacity(self) -> int:
        return self._cap - 1

    # -- shared-index helpers ------------------------------------------------
    def _load(self, off: int) -> int:
        return int.from_bytes(self._buf[off:off + 8], "little")

    def _store(self, off: int, v: int) -> None:
        self._buf[off:off + 8] = v.to_bytes(8, "little")

    def __len__(self) -> int:
        if self._buf is None:           # detached/destroyed: nothing queued
            return 0
        return (self._load(_OFF_TAIL) - self._load(_OFF_HEAD)) % self._cap

    def empty(self) -> bool:
        return self._load(_OFF_TAIL) == self._load(_OFF_HEAD)

    @property
    def closed(self) -> bool:
        return self._buf[_OFF_CLOSED] != 0

    def close(self) -> None:
        self._buf[_OFF_CLOSED] = 1

    def drained(self) -> bool:
        """Closed with nothing left to pop."""
        return self.closed and self.empty()

    # -- encode / decode -----------------------------------------------------
    def _encode(self, base: int, tag: int, obj: Any, seq: int = 0) -> None:
        if tag == TAG_ARR:
            dt = obj.dtype.str.encode("ascii")
            meta = struct.pack("<BB", obj.ndim, len(dt)) + dt \
                + struct.pack(f"<{obj.ndim}q", *obj.shape)
            payload_len = len(meta) + obj.nbytes
            if payload_len > self._slot:
                raise ValueError(
                    f"array of {obj.nbytes}B exceeds the {self._slot}B shm "
                    "slot; raise slot_bytes= on the ring")
            off = base + _SLOT_HDR
            self._buf[off:off + len(meta)] = meta
            off += len(meta)
            self._buf[off:off + obj.nbytes] = memoryview(obj).cast("B")
        elif tag in (TAG_PKL, TAG_ERR):
            payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
            payload_len = len(payload)
            if payload_len > self._slot:
                raise ValueError(
                    f"pickled item of {payload_len}B exceeds the "
                    f"{self._slot}B shm slot; raise slot_bytes= on the ring")
            off = base + _SLOT_HDR
            self._buf[off:off + payload_len] = payload
        else:                       # TAG_EOS
            payload_len = 0
        struct.pack_into(_SLOT_FMT, self._buf, base, payload_len, tag, seq)

    def _decode(self, base: int) -> Tuple[Any, int]:
        payload_len, tag, seq = struct.unpack_from(_SLOT_FMT, self._buf, base)
        off = base + _SLOT_HDR
        if tag == TAG_EOS:
            return EOS, seq
        if tag == TAG_ARR:
            ndim, dlen = struct.unpack_from("<BB", self._buf, off)
            off += 2
            dtype = np.dtype(bytes(self._buf[off:off + dlen]).decode("ascii"))
            off += dlen
            shape = struct.unpack_from(f"<{ndim}q", self._buf, off)
            off += 8 * ndim
            nbytes = int(dtype.itemsize * int(np.prod(shape, dtype=np.int64))) \
                if ndim else dtype.itemsize
            # bytes() copies out of the slot before the producer reuses it
            return np.frombuffer(bytes(self._buf[off:off + nbytes]),
                                 dtype=dtype).reshape(shape), seq
        obj = pickle.loads(bytes(self._buf[off:off + payload_len]))
        return obj, seq

    # -- non-blocking primitives (the lock-free layer) -----------------------
    def _try_push_tag(self, tag: int, obj: Any, seq: int = 0) -> bool:
        tail = self._load(_OFF_TAIL)
        head = self._load(_OFF_HEAD)
        nxt = (tail + 1) % self._cap
        if nxt == head:             # full
            return False
        self._encode(_HEADER + tail * self._stride, tag, obj, seq)
        self._store(_OFF_TAIL, nxt)     # single atomic publish
        depth = (nxt - head) % self._cap
        if depth > self.max_depth:
            self.max_depth = depth
        return True

    def try_push(self, item: Any, seq: int = 0) -> bool:
        # the raw-slab path only fits plain dtypes: structured dtypes
        # collapse to void under dtype.str (field names lost) and object
        # dtypes have no flat buffer — both must ride the pickle path
        if isinstance(item, np.ndarray) and item.dtype.names is None \
                and item.dtype.kind != "O":
            a = np.ascontiguousarray(item)
            try:
                return self._try_push_tag(TAG_ARR, a, seq)
            except ValueError:
                return self._try_push_tag(TAG_PKL, item, seq)
        return self._try_push_tag(TAG_PKL, item, seq)

    def try_pop_seq(self) -> Tuple[bool, Any, int]:
        head = self._load(_OFF_HEAD)
        if head == self._load(_OFF_TAIL):   # empty
            return False, None, 0
        item, seq = self._decode(_HEADER + head * self._stride)
        self._store(_OFF_HEAD, (head + 1) % self._cap)
        return True, item, seq

    def try_pop(self) -> Tuple[bool, Any]:
        ok, item, _seq = self.try_pop_seq()
        return ok, item

    # -- blocking wrappers ---------------------------------------------------
    def push(self, item: Any, timeout: Optional[float] = None,
             seq: int = 0) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            # same discipline as the thread tier: a closed queue refuses new
            # items even when slots remain
            if self.closed:
                raise QueueClosed("push to closed shm queue")
            if self.try_push(item, seq):
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm SPSC push timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def pop_seq(self, timeout: Optional[float] = None) -> Tuple[Any, int]:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            ok, item, seq = self.try_pop_seq()
            if ok:
                return item, seq
            if self.closed:
                raise QueueClosed("pop from closed empty shm queue")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm SPSC pop timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def pop(self, timeout: Optional[float] = None) -> Any:
        return self.pop_seq(timeout)[0]

    def push_eos(self, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            # a closed lane's consumer is gone (or the network is unwinding)
            # and will never see the mark; raising lets a worker's EOS
            # fan-out unwind instead of wedging on a dead peer's full lane
            if self.closed:
                raise QueueClosed("push_eos to closed shm queue")
            if self._try_push_tag(TAG_EOS, None):
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm SPSC push_eos timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def push_err(self, err: ShmError, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            if self.closed:
                raise QueueClosed("push_err to closed shm queue")
            if self._try_push_tag(TAG_ERR, err):
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm SPSC push_err timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    # -- segment lifetime ----------------------------------------------------
    def detach(self) -> None:
        try:
            self._buf = None
            self._shm.close()
        except Exception:   # noqa: BLE001 - already detached
            pass

    def destroy(self) -> None:
        """Release the segment (creator only; attachers just detach)."""
        self.detach()
        if self._creator:
            try:
                self._shm.unlink()
            except FileNotFoundError:
                pass


class ShmSPMCQueue:
    """Single producer, multiple consumer *processes*: one shm SPSC lane per
    consumer, round-robin by default (mirrors
    :class:`~repro.core.queues.SPMCQueue`)."""

    def __init__(self, n_consumers: int, capacity: int = 64,
                 slot_bytes: int = 1 << 16):
        self.lanes = [ShmSPSCQueue(capacity, slot_bytes)
                      for _ in range(n_consumers)]
        self._rr = 0

    def push_to(self, idx: int, item: Any,
                timeout: Optional[float] = None) -> None:
        self.lanes[idx].push(item, timeout)

    def push_rr(self, item: Any, timeout: Optional[float] = None) -> int:
        idx = self._rr
        self.lanes[idx].push(item, timeout)
        self._rr = (self._rr + 1) % len(self.lanes)
        return idx

    def broadcast_eos(self) -> None:
        for lane in self.lanes:
            lane.push_eos()

    def close_all(self) -> None:
        for lane in self.lanes:
            lane.close()

    def destroy(self) -> None:
        for lane in self.lanes:
            lane.destroy()


class ShmMPSCQueue:
    """Multiple producer processes, single consumer: one shm SPSC lane per
    producer, drained fairly (mirrors
    :class:`~repro.core.queues.MPSCQueue`)."""

    def __init__(self, n_producers: int, capacity: int = 64,
                 slot_bytes: int = 1 << 16):
        self.lanes = [ShmSPSCQueue(capacity, slot_bytes)
                      for _ in range(n_producers)]
        self._next = 0

    def lane(self, idx: int) -> ShmSPSCQueue:
        return self.lanes[idx]

    def try_pop_any_seq(self) -> Tuple[bool, Any, int, int]:
        n = len(self.lanes)
        for off in range(n):
            i = (self._next + off) % n
            ok, item, seq = self.lanes[i].try_pop_seq()
            if ok:
                self._next = (i + 1) % n
                return True, item, i, seq
        return False, None, -1, 0

    def try_pop_any(self) -> Tuple[bool, Any, int]:
        ok, item, i, _seq = self.try_pop_any_seq()
        return ok, item, i

    def pop_any(self, timeout: Optional[float] = None) -> Tuple[Any, int]:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            ok, item, i = self.try_pop_any()
            if ok:
                return item, i
            if all(lane.drained() for lane in self.lanes):
                raise QueueClosed("pop from closed and drained shm MPSC")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm MPSC pop timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def close_all(self) -> None:
        for lane in self.lanes:
            lane.close()

    def destroy(self) -> None:
        for lane in self.lanes:
            lane.destroy()


class ShmMPMCGrid:
    """Multiple producer / multiple consumer *processes*: an nL x nR grid of
    shm SPSC lanes (producer ``i`` -> consumer ``j``), the process-tier
    instance of :class:`~repro.core.queues.MPMCQueue`.

    Producer ``i`` writes only row ``i`` and consumer ``j`` reads only column
    ``j``, so every lane keeps the wait-free single-writer index discipline —
    the MPMC behaviour is composition, not locking.  This is the stage
    interconnect of the process-backed ``all_to_all``: left worker processes
    attach their row (``row(i)``), right worker processes their column
    (``col(j)``); both are plain lists of picklable lanes, so a child maps
    only the segments it touches."""

    def __init__(self, n_producers: int, n_consumers: int, capacity: int = 64,
                 slot_bytes: int = 1 << 16):
        self.grid = [[ShmSPSCQueue(capacity, slot_bytes)
                      for _ in range(n_consumers)]
                     for _ in range(n_producers)]
        self._next = [0] * n_consumers

    @property
    def n_producers(self) -> int:
        return len(self.grid)

    @property
    def n_consumers(self) -> int:
        return len(self.grid[0]) if self.grid else 0

    def row(self, i: int) -> List[ShmSPSCQueue]:
        """Producer ``i``'s output lanes, one per consumer."""
        return self.grid[i]

    def col(self, j: int) -> List[ShmSPSCQueue]:
        """Consumer ``j``'s input lanes, one per producer."""
        return [r[j] for r in self.grid]

    def push(self, producer: int, consumer: int, item: Any,
             timeout: Optional[float] = None, seq: int = 0) -> None:
        self.grid[producer][consumer].push(item, timeout, seq=seq)

    def try_pop(self, consumer: int) -> Tuple[bool, Any, int, int]:
        """Fair non-blocking pop from ``consumer``'s column:
        ``(ok, item, producer, seq)``."""
        n = len(self.grid)
        for off in range(n):
            i = (self._next[consumer] + off) % n
            ok, item, seq = self.grid[i][consumer].try_pop_seq()
            if ok:
                self._next[consumer] = (i + 1) % n
                return True, item, i, seq
        return False, None, -1, 0

    def pop(self, consumer: int,
            timeout: Optional[float] = None) -> Tuple[Any, int, int]:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            ok, item, i, seq = self.try_pop(consumer)
            if ok:
                return item, i, seq
            if all(row[consumer].drained() for row in self.grid):
                raise QueueClosed("pop from closed and drained shm MPMC column")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm MPMC pop timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def max_depth(self) -> int:
        """Process-local high-water mark over every lane this side pushed."""
        return max((l.max_depth for row in self.grid for l in row), default=0)

    def close_all(self) -> None:
        for row in self.grid:
            for lane in row:
                lane.close()

    def destroy(self) -> None:
        for row in self.grid:
            for lane in row:
                lane.destroy()
