"""L1/L2 — true shared-memory channels for the process-backed host tier.

``core/queues.py`` carries the thread-backed host tier; its rings are Python
lists, so they cannot cross a process boundary and its CPU-bound producers
serialize on the GIL.  This module is the same FastFlow layer-1 structure on
``multiprocessing.shared_memory``: a fixed-slot single-producer /
single-consumer ring whose indices live *in* the shared segment, with the
same wait-free single-writer discipline — the producer only writes ``tail``,
the consumer only writes ``head``, each as one aligned 8-byte store (a single
memcpy in CPython, atomic on every platform we target), so neither side ever
takes a lock on the fast path.

The transport has **three lane tiers**, selected per lane at build time
(:class:`TransportConfig` / ``compile(transport=...)``):

1. **bounded SPSC** (:class:`ShmSPSCQueue`) — the classic fixed-slot ring;
   a full ring is back-pressure, pushed batches amortize the index traffic
   (one tail publish per batch, not per item);
2. **uSPSC unbounded** (:class:`ShmUSPSCQueue`) — the 2009 FastFlow TR's
   unbounded queue: a linked chain of fixed-slot ring segments, grown on
   overflow (a ``SEG`` control slot names the next segment) and retired on
   drain, so back-pressure policy becomes a compile-time choice
   (``bounded=`` on lanes) instead of a wedge risk;
3. **slab arena** (:class:`ShmArena`) — a FIFO byte ring riding next to a
   lane, so ndarrays larger than a slot ship as arena offsets in the slot
   header instead of falling back to pickle.

Payload encoding per slot:

- **ndarray fast path** (tag ``ARR``): dtype/shape header plus the raw data
  bytes copied straight into the slot — no pickling of the buffer;
- **arena ndarray** (tag ``ARN``): the same dtype/shape header plus a
  ``(offset, nbytes)`` pair naming a block in the lane's :class:`ShmArena`
  — the slot stays fixed-size while the payload does not;
- **pickle fallback** (tag ``PKL``): arbitrary pytrees / Python objects as
  pickled bytes;
- **vectored batch** (tag ``BATCH``): one pickled list of ``(seq, item)``
  pairs — the coalesced form ``push_many`` emits for runs of small
  non-array items, one ``pickle.dumps`` and one slot for the whole run;
- **control tags**: ``EOS`` (end-of-stream; decoded back to the module-wide
  :data:`~repro.core.node.EOS` sentinel so identity checks keep working
  across the boundary), ``ERR`` (a pickled error record from a worker) and
  ``SEG`` (a uSPSC growth marker carrying the next segment's name).

Each slot header also carries a **u64 sequence number** alongside the
length/tag word.  Per-lane FIFO order is enough for a farm (one hop, parent
assigns seqs and matches results by arrival order), but the ``all_to_all``
grid routes items data-dependently across two hops, so the seq must ride the
wire with the payload — in the fixed header, not the payload, so bare
ndarrays keep the raw-slab fast path.

Layer 2 composes the same SPMC / MPSC lane bundles as ``core/queues.py`` out
of these rings — the emitter/collector wiring of a process farm — plus
:class:`ShmMPMCGrid`, the process-tier instance of
``queues.MPMCQueue``: an nL x nR grid of SPSC lanes where producer ``i``
owns row ``i`` and consumer ``j`` owns column ``j``, so every lane keeps the
single-writer index discipline.  It is the interconnect of the process-backed
``all_to_all`` (``core/process.ProcessA2ANode``).
"""

from __future__ import annotations

import pickle
import struct
import time
from collections import deque
from dataclasses import dataclass
from multiprocessing import shared_memory
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from .node import EOS
from .queues import QueueClosed

# ring header: producer / consumer indices on separate cache lines, plus the
# closed flag (written by the producer, read by both sides)
_OFF_TAIL = 0
_OFF_HEAD = 64
_OFF_CLOSED = 128
_HEADER = 192

_SLOT_HDR = 16           # u32 payload length | u8 tag | 3B pad | u64 seq
_SLOT_FMT = "<IB3xQ"

TAG_PKL = 0
TAG_ARR = 1
TAG_EOS = 2
TAG_ERR = 3
TAG_BATCH = 4       # pickled list of (seq, item) pairs — one slot per run
TAG_SEG = 5         # uSPSC growth marker: pickled next-segment descriptor
TAG_ARN = 6         # ndarray meta + (offset, nbytes) into the lane's arena

# most items a single BATCH slot may coalesce; bounds both the pickle size
# probe (halving search below) and the consumer-side staging burst
_BATCH_MAX = 64


@dataclass(frozen=True)
class TransportConfig:
    """Per-compile tuning knobs for the shm transport.

    Defaults are the values that were hard-coded before this existed:

    - ``ring_slots`` (64): slots per farm lane (emitter->worker and
      worker->collector rings); the compiler clamps its ``capacity`` hint
      into ``[2, ring_slots]``;
    - ``grid_slots`` (32): slots per :class:`ShmMPMCGrid` lane — the
      ``all_to_all`` interconnect allocates nL x nR of them, so its clamp
      is tighter;
    - ``slot_bytes`` (64 KiB): fixed payload bytes per slot;
    - ``arena_bytes`` (4 MiB): per-lane slab arena for ndarrays larger than
      a slot; ``0`` disables the arena (oversize arrays then fall back to
      pickle as before);
    - ``bounded`` (True): ``False`` swaps farm input lanes to the uSPSC
      unbounded tier — the emitter never blocks, segments grow on overflow;
    - ``batch`` (16): producer-side max items buffered per vectored flush;
    - ``flush_s`` (2 ms): adaptive-flush timeout — a partial batch older
      than this is pushed anyway so latency-sensitive streams don't stall.
    """

    ring_slots: int = 64
    grid_slots: int = 32
    slot_bytes: int = 1 << 16
    arena_bytes: int = 1 << 22
    bounded: bool = True
    batch: int = 16
    flush_s: float = 2e-3

    def __post_init__(self):
        if self.ring_slots < 2 or self.grid_slots < 2:
            raise ValueError("transport ring/grid slots must be >= 2")
        if self.slot_bytes < _SLOT_HDR:
            raise ValueError("transport slot_bytes too small")
        if self.batch < 1:
            raise ValueError("transport batch must be >= 1")
        if self.arena_bytes != 0 and self.arena_bytes < 4096:
            raise ValueError("transport arena_bytes must be 0 (disabled) "
                             "or >= 4096")


def as_transport(obj: Any) -> "TransportConfig":
    """Coerce ``compile(transport=...)`` input: None (defaults), a
    :class:`TransportConfig`, or a dict of field overrides."""
    if obj is None:
        return TransportConfig()
    if isinstance(obj, TransportConfig):
        return obj
    if isinstance(obj, dict):
        return TransportConfig(**obj)
    raise TypeError(f"transport must be TransportConfig/dict/None, "
                    f"not {type(obj).__name__}")


class _SegMark:
    """Decoded ``SEG`` slot: descriptor of the next uSPSC segment."""

    __slots__ = ("state",)

    def __init__(self, state: dict):
        self.state = state


class ShmError:
    """A worker-side failure shipped through the ring (tag ``ERR``)."""

    __slots__ = ("worker", "exc", "tb")

    def __init__(self, worker: int, exc: str, tb: str):
        self.worker = worker
        self.exc = exc
        self.tb = tb

    def __repr__(self) -> str:
        return f"ShmError(worker={self.worker}, exc={self.exc!r})"


class WorkerStats:
    """A worker-side CPU-time record shipped over a result lane (seq-less
    control payload, not a stream item): ``items`` processed so far and an
    EMA of per-item *CPU* seconds (``time.thread_time``).  Farms fold these
    into ``node_stats()["svc_cpu_ema_s"]`` so the runtime Supervisor's
    process→thread policy compares true service times instead of inferring
    them from hop domination."""

    __slots__ = ("worker", "items", "cpu_ema_s")

    def __init__(self, worker: int, items: int, cpu_ema_s: float):
        self.worker = worker
        self.items = items
        self.cpu_ema_s = cpu_ema_s

    def __repr__(self) -> str:
        return (f"WorkerStats(worker={self.worker}, items={self.items}, "
                f"cpu_ema_s={self.cpu_ema_s:.3g})")


def _unregister_tracker(name: str) -> None:
    # attaching registers the segment with this process's resource_tracker,
    # which would unlink it when the attacher exits; only the creator owns
    # the segment's lifetime
    try:
        from multiprocessing import resource_tracker
        resource_tracker.unregister("/" + name, "shared_memory")
    except Exception:   # noqa: BLE001 - best effort, platform-dependent
        pass


# arena header: producer / consumer byte cursors on separate cache lines;
# both are *absolute* (monotonically increasing, never wrapped) so the
# free-space check is plain subtraction and wrap-skips stay consistent
_ARN_OFF_TAIL = 0
_ARN_OFF_HEAD = 64
_ARN_HEADER = 128


class ShmArena:
    """Variable-size slab arena: a FIFO byte ring in one shm segment.

    Rides next to an SPSC lane and inherits its discipline: the lane's
    producer owns the alloc cursor (``tail``), the lane's consumer owns the
    free cursor (``head``), each a single aligned 8-byte store.  Because the
    lane is consumed FIFO and blocks are allocated FIFO, blocks are freed in
    allocation order — so the arena never needs a free list, just two
    cursors.  A block that would straddle the end of the ring is placed at
    the start instead; the skipped gap is accounted for by carrying the
    *absolute* start offset in the slot header, so the consumer's free
    cursor jumps the same gap.

    Producer protocol: ``alloc`` -> ``write`` -> ``commit``; consumer:
    ``take`` (copy out + free in one step).  ``alloc`` returning ``None``
    is back-pressure (the lane's ``try_push`` returns False and the
    blocking wrapper retries after the consumer frees).
    """

    def __init__(self, size: int = 1 << 22, name: Optional[str] = None,
                 _create: bool = True):
        if size < 4096:
            raise ValueError("arena size must be >= 4096 bytes")
        self._size = size
        self._creator = _create
        if _create:
            self._shm = shared_memory.SharedMemory(create=True,
                                                   size=_ARN_HEADER + size)
        else:
            self._shm = shared_memory.SharedMemory(name=name)
            _unregister_tracker(self._shm.name)
        self._buf = self._shm.buf

    def __getstate__(self):
        return {"size": self._size, "name": self._shm.name}

    def __setstate__(self, state):
        self.__init__(state["size"], name=state["name"], _create=False)

    @property
    def name(self) -> str:
        return self._shm.name

    @property
    def data_size(self) -> int:
        return self._size

    def _load(self, off: int) -> int:
        return int.from_bytes(self._buf[off:off + 8], "little")

    def _store(self, off: int, v: int) -> None:
        self._buf[off:off + 8] = v.to_bytes(8, "little")

    def used(self) -> int:
        return self._load(_ARN_OFF_TAIL) - self._load(_ARN_OFF_HEAD)

    # -- producer side -------------------------------------------------------
    def alloc(self, nbytes: int) -> Optional[int]:
        """Reserve ``nbytes`` contiguous; returns the absolute start offset
        or ``None`` when the ring is too full (back-pressure, not an
        error)."""
        if nbytes > self._size:
            raise ValueError(
                f"array of {nbytes}B exceeds the {self._size}B shm arena; "
                "raise arena_bytes= on the transport")
        tail = self._load(_ARN_OFF_TAIL)
        head = self._load(_ARN_OFF_HEAD)
        pos = tail % self._size
        start = tail if pos + nbytes <= self._size \
            else tail + (self._size - pos)      # skip the end-of-ring gap
        if start + nbytes - head > self._size:
            return None
        return start

    def write(self, start: int, data: memoryview) -> None:
        off = _ARN_HEADER + (start % self._size)
        self._buf[off:off + len(data)] = data

    def commit(self, start: int, nbytes: int) -> None:
        self._store(_ARN_OFF_TAIL, start + nbytes)

    # -- consumer side -------------------------------------------------------
    def take(self, start: int, nbytes: int) -> bytes:
        """Copy a block out and free it (advance the head cursor past it,
        including any wrap gap the producer skipped)."""
        off = _ARN_HEADER + (start % self._size)
        data = bytes(self._buf[off:off + nbytes])
        self._store(_ARN_OFF_HEAD, start + nbytes)
        return data

    # -- segment lifetime ----------------------------------------------------
    def detach(self) -> None:
        try:
            self._buf = None
            self._shm.close()
        except Exception:   # noqa: BLE001 - already detached
            pass

    def destroy(self) -> None:
        self.detach()
        if self._creator:
            try:
                self._shm.unlink()
            except FileNotFoundError:
                pass


class ShmSPSCQueue:
    """Bounded SPSC ring over one shared-memory segment.

    Same surface as :class:`~repro.core.queues.SPSCQueue` (``try_push`` /
    ``try_pop`` / blocking wrappers / ``close``), crossing a process
    boundary.  The object is picklable: unpickling (or ``attach``) maps the
    same segment by name, so a ``fork``- or ``spawn``-started worker sees the
    identical ring.  Only the creating process may ``unlink``.
    """

    def __init__(self, capacity: int = 64, slot_bytes: int = 1 << 16,
                 name: Optional[str] = None, _create: bool = True,
                 arena_bytes: int = 0, arena_name: Optional[str] = None):
        if capacity < 2:
            raise ValueError("capacity must be >= 2")
        self._cap = capacity
        self._slot = slot_bytes
        self._stride = _SLOT_HDR + slot_bytes
        self._creator = _create
        self.max_depth = 0          # producer-side observation, process-local
        self.arena_pushes = 0       # oversize ndarrays shipped via the arena
        self.pickle_fallbacks = 0   # ndarrays that had to ride TAG_PKL
        # consumer-side overflow of expanded BATCH slots (process-local)
        self._staged: deque = deque()
        size = _HEADER + capacity * self._stride
        if _create:
            self._shm = shared_memory.SharedMemory(create=True, size=size)
        else:
            self._shm = shared_memory.SharedMemory(name=name)
            _unregister_tracker(self._shm.name)
        self._buf = self._shm.buf
        try:
            if arena_name is not None:
                self._arena: Optional[ShmArena] = ShmArena(
                    arena_bytes, name=arena_name, _create=False)
            elif _create and arena_bytes > 0:
                self._arena = ShmArena(arena_bytes)
            else:
                self._arena = None
        except Exception:
            # a rejected arena must not leak the ring segment just created
            self._buf = None
            self._shm.close()
            if _create:
                self._shm.unlink()
            raise

    # -- pickling: reattach by name -----------------------------------------
    def __getstate__(self):
        state = {"capacity": self._cap, "slot_bytes": self._slot,
                 "name": self._shm.name}
        if self._arena is not None:
            state["arena_bytes"] = self._arena.data_size
            state["arena_name"] = self._arena.name
        return state

    def __setstate__(self, state):
        self.__init__(state["capacity"], state["slot_bytes"],
                      name=state["name"], _create=False,
                      arena_bytes=state.get("arena_bytes", 0),
                      arena_name=state.get("arena_name"))

    @property
    def name(self) -> str:
        return self._shm.name

    @property
    def capacity(self) -> int:
        return self._cap - 1

    # -- shared-index helpers ------------------------------------------------
    def _load(self, off: int) -> int:
        return int.from_bytes(self._buf[off:off + 8], "little")

    def _store(self, off: int, v: int) -> None:
        self._buf[off:off + 8] = v.to_bytes(8, "little")

    def __len__(self) -> int:
        if self._buf is None:           # detached/destroyed: nothing queued
            return 0
        return len(self._staged) \
            + (self._load(_OFF_TAIL) - self._load(_OFF_HEAD)) % self._cap

    def empty(self) -> bool:
        if self._buf is None:
            return True
        return not self._staged \
            and self._load(_OFF_TAIL) == self._load(_OFF_HEAD)

    @property
    def closed(self) -> bool:
        if self._buf is None:           # a detached lane refuses new items
            return True
        return self._buf[_OFF_CLOSED] != 0

    def close(self) -> None:
        self._buf[_OFF_CLOSED] = 1

    def drained(self) -> bool:
        """Closed with nothing left to pop."""
        return self.closed and self.empty()

    # -- encode / decode -----------------------------------------------------
    def _encode(self, base: int, tag: int, obj: Any, seq: int = 0) -> None:
        if tag == TAG_ARR:
            dt = obj.dtype.str.encode("ascii")
            meta = struct.pack("<BB", obj.ndim, len(dt)) + dt \
                + struct.pack(f"<{obj.ndim}q", *obj.shape)
            payload_len = len(meta) + obj.nbytes
            if payload_len > self._slot:
                raise ValueError(
                    f"array of {obj.nbytes}B exceeds the {self._slot}B shm "
                    "slot; raise slot_bytes= on the ring")
            off = base + _SLOT_HDR
            self._buf[off:off + len(meta)] = meta
            off += len(meta)
            self._buf[off:off + obj.nbytes] = memoryview(obj).cast("B")
        elif tag in (TAG_PKL, TAG_ERR, TAG_SEG):
            payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
            payload_len = len(payload)
            if payload_len > self._slot:
                raise ValueError(
                    f"pickled item of {payload_len}B exceeds the "
                    f"{self._slot}B shm slot; raise slot_bytes= on the ring")
            off = base + _SLOT_HDR
            self._buf[off:off + payload_len] = payload
        else:                       # TAG_EOS
            payload_len = 0
        struct.pack_into(_SLOT_FMT, self._buf, base, payload_len, tag, seq)

    def _encode_raw(self, base: int, tag: int, payload: bytes,
                    seq: int = 0) -> None:
        """Write an already-serialized payload (BATCH / SEG slots)."""
        if len(payload) > self._slot:
            raise ValueError(
                f"payload of {len(payload)}B exceeds the {self._slot}B shm "
                "slot; raise slot_bytes= on the ring")
        self._buf[base + _SLOT_HDR:base + _SLOT_HDR + len(payload)] = payload
        struct.pack_into(_SLOT_FMT, self._buf, base, len(payload), tag, seq)

    @staticmethod
    def _arr_meta(a: np.ndarray) -> bytes:
        dt = a.dtype.str.encode("ascii")
        return struct.pack("<BB", a.ndim, len(dt)) + dt \
            + struct.pack(f"<{a.ndim}q", *a.shape)

    def _encode_arena(self, base: int, a: np.ndarray, seq: int) -> bool:
        """Ship ``a`` through the slab arena: the slot carries only meta +
        ``(offset, nbytes)``.  False when the arena is too full (the ring
        slot stays unclaimed — caller must not advance the tail)."""
        start = self._arena.alloc(a.nbytes)
        if start is None:
            return False
        self._arena.write(start, memoryview(a).cast("B"))
        self._arena.commit(start, a.nbytes)
        payload = self._arr_meta(a) + struct.pack("<QQ", start, a.nbytes)
        self._encode_raw(base, TAG_ARN, payload, seq)
        self.arena_pushes += 1
        return True

    def _decode(self, base: int) -> Tuple[int, Any, int]:
        """Decode one slot -> ``(tag, obj, seq)``.  BATCH decodes to the
        list of ``(seq, item)`` pairs; SEG to a :class:`_SegMark`; ARN
        copies the block out of the arena and frees it."""
        payload_len, tag, seq = struct.unpack_from(_SLOT_FMT, self._buf, base)
        off = base + _SLOT_HDR
        if tag == TAG_EOS:
            return tag, EOS, seq
        if tag in (TAG_ARR, TAG_ARN):
            ndim, dlen = struct.unpack_from("<BB", self._buf, off)
            off += 2
            dtype = np.dtype(bytes(self._buf[off:off + dlen]).decode("ascii"))
            off += dlen
            shape = struct.unpack_from(f"<{ndim}q", self._buf, off)
            off += 8 * ndim
            if tag == TAG_ARN:
                start, nbytes = struct.unpack_from("<QQ", self._buf, off)
                data = self._arena.take(start, nbytes)
                return tag, np.frombuffer(data, dtype=dtype).reshape(shape), \
                    seq
            nbytes = int(dtype.itemsize * int(np.prod(shape, dtype=np.int64))) \
                if ndim else dtype.itemsize
            # bytes() copies out of the slot before the producer reuses it
            return tag, np.frombuffer(bytes(self._buf[off:off + nbytes]),
                                      dtype=dtype).reshape(shape), seq
        obj = pickle.loads(bytes(self._buf[off:off + payload_len]))
        if tag == TAG_SEG:
            return tag, _SegMark(obj), seq
        return tag, obj, seq

    # -- non-blocking primitives (the lock-free layer) -----------------------
    def _try_push_tag(self, tag: int, obj: Any, seq: int = 0) -> bool:
        tail = self._load(_OFF_TAIL)
        head = self._load(_OFF_HEAD)
        nxt = (tail + 1) % self._cap
        if nxt == head:             # full
            return False
        self._encode(_HEADER + tail * self._stride, tag, obj, seq)
        self._store(_OFF_TAIL, nxt)     # single atomic publish
        depth = (nxt - head) % self._cap
        if depth > self.max_depth:
            self.max_depth = depth
        return True

    @staticmethod
    def _is_plain_array(item: Any) -> bool:
        # the raw-slab path only fits plain dtypes: structured dtypes
        # collapse to void under dtype.str (field names lost) and object
        # dtypes have no flat buffer — both must ride the pickle path
        return isinstance(item, np.ndarray) and item.dtype.names is None \
            and item.dtype.kind != "O"

    def _try_push_arena(self, a: np.ndarray, seq: int) -> bool:
        tail = self._load(_OFF_TAIL)
        head = self._load(_OFF_HEAD)
        nxt = (tail + 1) % self._cap
        if nxt == head:             # full
            return False
        if not self._encode_arena(_HEADER + tail * self._stride, a, seq):
            return False            # arena full — back-pressure, retry later
        self._store(_OFF_TAIL, nxt)
        depth = (nxt - head) % self._cap
        if depth > self.max_depth:
            self.max_depth = depth
        return True

    def try_push(self, item: Any, seq: int = 0) -> bool:
        if self._is_plain_array(item):
            a = np.ascontiguousarray(item)
            if len(self._arr_meta(a)) + a.nbytes <= self._slot:
                return self._try_push_tag(TAG_ARR, a, seq)
            if self._arena is not None:
                return self._try_push_arena(a, seq)
            self.pickle_fallbacks += 1
            return self._try_push_tag(TAG_PKL, item, seq)
        return self._try_push_tag(TAG_PKL, item, seq)

    def try_pop_seq(self) -> Tuple[bool, Any, int]:
        if self._staged:
            item, seq = self._staged.popleft()
            return True, item, seq
        head = self._load(_OFF_HEAD)
        if head == self._load(_OFF_TAIL):   # empty
            return False, None, 0
        tag, item, seq = self._decode(_HEADER + head * self._stride)
        self._store(_OFF_HEAD, (head + 1) % self._cap)
        if tag == TAG_BATCH:
            # expand the run: hand out the first pair now, stage the rest
            (seq, item), rest = item[0], item[1:]
            self._staged.extend((it, s) for s, it in rest)
        return True, item, seq

    def try_pop(self) -> Tuple[bool, Any]:
        ok, item, _seq = self.try_pop_seq()
        return ok, item

    # -- vectored (batched) primitives ---------------------------------------
    def try_push_many(self, items: Sequence[Any],
                      seqs: Optional[Sequence[int]] = None,
                      reserve: int = 0) -> int:
        """Vectored push: encode as many leading ``items`` as fit, then
        publish the tail ONCE — one atomic-index write and (on the blocking
        wrapper) one spin per batch instead of per item.  Runs of small
        non-array items coalesce into single ``BATCH`` slots (one
        ``pickle.dumps`` per run); plain ndarrays keep their raw-slab /
        arena slots inside the same publish.  ``reserve`` keeps that many
        ring slots unclaimed (the uSPSC tier reserves one for its growth
        marker).  Returns the number of leading items pushed."""
        n = len(items)
        if n == 0:
            return 0
        if seqs is None:
            seqs = (0,) * n
        tail = self._load(_OFF_TAIL)
        head = self._load(_OFF_HEAD)
        free = (head - tail - 1) % self._cap - reserve
        if free <= 0:
            return 0
        pos = tail
        pushed = 0
        pending: List[Tuple[int, Any]] = []   # (seq, item) run to coalesce

        def emit(tag, obj, seq):
            nonlocal pos, free
            self._encode(_HEADER + pos * self._stride, tag, obj, seq)
            pos = (pos + 1) % self._cap
            free -= 1

        def flush_pending() -> bool:
            """Emit the buffered run as BATCH slots (halving a chunk whose
            pickle overflows the slot); False when the ring filled first."""
            nonlocal pos, free, pushed
            while pending:
                if free <= 0:
                    return False
                chunk = pending[:_BATCH_MAX]
                payload = pickle.dumps(chunk,
                                       protocol=pickle.HIGHEST_PROTOCOL)
                while len(payload) > self._slot and len(chunk) > 1:
                    chunk = chunk[:max(1, len(chunk) // 2)]
                    payload = pickle.dumps(chunk,
                                           protocol=pickle.HIGHEST_PROTOCOL)
                if len(chunk) == 1:
                    # a lone item gains nothing from the batch frame; this
                    # also surfaces the oversize-pickle ValueError unchanged
                    emit(TAG_PKL, chunk[0][1], chunk[0][0])
                else:
                    self._encode_raw(_HEADER + pos * self._stride, TAG_BATCH,
                                     payload, chunk[0][0])
                    pos = (pos + 1) % self._cap
                    free -= 1
                del pending[:len(chunk)]
                pushed += len(chunk)
            return True

        try:
            for seq, obj in zip(seqs, items):
                if self._is_plain_array(obj):
                    if not flush_pending() or free <= 0:
                        break
                    a = np.ascontiguousarray(obj)
                    if len(self._arr_meta(a)) + a.nbytes <= self._slot:
                        emit(TAG_ARR, a, seq)
                    elif self._arena is not None:
                        if not self._encode_arena(
                                _HEADER + pos * self._stride, a, seq):
                            break       # arena full — stop, caller retries
                        pos = (pos + 1) % self._cap
                        free -= 1
                    else:
                        self.pickle_fallbacks += 1
                        emit(TAG_PKL, obj, seq)
                    pushed += 1
                else:
                    pending.append((seq, obj))
                    if len(pending) >= _BATCH_MAX and not flush_pending():
                        break
            else:
                flush_pending()
        finally:
            if pos != tail:             # single atomic publish for the batch
                self._store(_OFF_TAIL, pos)
                depth = (pos - head) % self._cap
                if depth > self.max_depth:
                    self.max_depth = depth
        return pushed

    def try_pop_many(self, max_items: int = 256) -> List[Tuple[Any, int]]:
        """Vectored pop: drain staged items plus every currently-published
        slot (up to ``max_items``), then publish the head ONCE.  Returns
        ``(item, seq)`` pairs in FIFO order; a BATCH slot expands in place
        (its items count toward, and may overshoot, ``max_items`` — a slot
        is atomic).  Control items (EOS / ShmError) appear in-stream."""
        out: List[Tuple[Any, int]] = []
        while self._staged and len(out) < max_items:
            out.append(self._staged.popleft())
        head = self._load(_OFF_HEAD)
        tail = self._load(_OFF_TAIL)
        pos = head
        while pos != tail and len(out) < max_items:
            tag, item, seq = self._decode(_HEADER + pos * self._stride)
            pos = (pos + 1) % self._cap
            if tag == TAG_BATCH:
                out.extend((it, s) for s, it in item)
            else:
                out.append((item, seq))
        if pos != head:                 # single atomic publish for the batch
            self._store(_OFF_HEAD, pos)
        return out

    def push_many(self, items: Sequence[Any],
                  seqs: Optional[Sequence[int]] = None,
                  timeout: Optional[float] = None) -> None:
        """Blocking vectored push — one spin loop per *batch*.  Preserves
        input order exactly across partial flushes (a full ring or full
        arena pushes a prefix and retries the rest)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        done = 0
        n = len(items)
        while done < n:
            if self.closed:
                raise QueueClosed("push_many to closed shm queue")
            k = self.try_push_many(
                items[done:] if done else items,
                (seqs[done:] if done else seqs) if seqs is not None else None)
            done += k
            if done >= n:
                return
            if k:
                delay = 1e-6            # progress: reset the backoff
                continue
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm SPSC push_many timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def pop_many(self, max_items: int = 256,
                 timeout: Optional[float] = None) -> List[Tuple[Any, int]]:
        """Blocking vectored pop: at least one ``(item, seq)`` pair, up to
        whatever is already published (one head write for the lot)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            got = self.try_pop_many(max_items)
            if got:
                return got
            if self.closed:
                raise QueueClosed("pop from closed empty shm queue")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm SPSC pop_many timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    # -- blocking wrappers ---------------------------------------------------
    def push(self, item: Any, timeout: Optional[float] = None,
             seq: int = 0) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            # same discipline as the thread tier: a closed queue refuses new
            # items even when slots remain
            if self.closed:
                raise QueueClosed("push to closed shm queue")
            if self.try_push(item, seq):
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm SPSC push timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def pop_seq(self, timeout: Optional[float] = None) -> Tuple[Any, int]:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            ok, item, seq = self.try_pop_seq()
            if ok:
                return item, seq
            if self.closed:
                raise QueueClosed("pop from closed empty shm queue")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm SPSC pop timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def pop(self, timeout: Optional[float] = None) -> Any:
        return self.pop_seq(timeout)[0]

    def push_eos(self, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            # a closed lane's consumer is gone (or the network is unwinding)
            # and will never see the mark; raising lets a worker's EOS
            # fan-out unwind instead of wedging on a dead peer's full lane
            if self.closed:
                raise QueueClosed("push_eos to closed shm queue")
            if self._try_push_tag(TAG_EOS, None):
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm SPSC push_eos timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def push_err(self, err: ShmError, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            if self.closed:
                raise QueueClosed("push_err to closed shm queue")
            if self._try_push_tag(TAG_ERR, err):
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm SPSC push_err timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    # -- segment lifetime ----------------------------------------------------
    def detach(self) -> None:
        try:
            self._buf = None
            self._shm.close()
        except Exception:   # noqa: BLE001 - already detached
            pass
        if self._arena is not None:
            self._arena.detach()

    def destroy(self) -> None:
        """Release the segment (creator only; attachers just detach)."""
        self.detach()
        if self._creator:
            try:
                self._shm.unlink()
            except FileNotFoundError:
                pass
        if self._arena is not None and self._creator:
            self._arena.destroy()

    def _unlink_any(self) -> None:
        """Best-effort unlink regardless of creator — the uSPSC tier hands
        segment ownership to whichever side retires the segment.  A creator
        handle goes through ``SharedMemory.unlink`` (which also clears its
        resource-tracker entry); an attached handle unlinks raw, because its
        tracker entry was already balanced at attach time and a second
        unregister would just splat a KeyError in the tracker process."""
        name = getattr(self._shm, "_name", "/" + self._shm.name)
        self.detach()
        try:
            from multiprocessing.shared_memory import _posixshmem
            _posixshmem.shm_unlink(name)
        except Exception:   # noqa: BLE001 - gone already / non-posix
            pass


class BatchedLaneWriter:
    """Producer-side adaptive batcher over one lane.

    Buffers ``put()`` items and flushes them with one vectored
    ``push_many`` when the batch fills, when ``maybe_flush`` finds the
    oldest buffered item past ``flush_s`` (the adaptive-flush timeout), or
    when EOS/ERR must go out — a control mark never overtakes buffered
    items, so stream order survives partial flushes."""

    __slots__ = ("_lane", "_batch", "_flush_s", "_items", "_seqs", "_t0")

    def __init__(self, lane: Any, batch: int = 16, flush_s: float = 2e-3):
        self._lane = lane
        self._batch = max(1, batch)
        self._flush_s = flush_s
        self._items: List[Any] = []
        self._seqs: List[int] = []
        self._t0 = 0.0

    def __len__(self) -> int:
        return len(self._items)

    def put(self, item: Any, seq: int = 0,
            timeout: Optional[float] = None) -> None:
        if not self._items:
            self._t0 = time.monotonic()
        self._items.append(item)
        self._seqs.append(seq)
        if len(self._items) >= self._batch:
            self.flush(timeout)

    def due(self) -> bool:
        return bool(self._items) \
            and time.monotonic() - self._t0 >= self._flush_s

    def maybe_flush(self, timeout: Optional[float] = None) -> None:
        if self.due():
            self.flush(timeout)

    def flush(self, timeout: Optional[float] = None) -> None:
        if not self._items:
            return
        items, seqs = self._items, self._seqs
        self._items, self._seqs = [], []
        self._lane.push_many(items, seqs, timeout=timeout)

    def push_eos(self, timeout: Optional[float] = None) -> None:
        self.flush(timeout)
        self._lane.push_eos(timeout)

    def push_err(self, err: "ShmError",
                 timeout: Optional[float] = None) -> None:
        self.flush(timeout)
        self._lane.push_err(err, timeout)


class ShmUSPSCQueue:
    """Unbounded SPSC: a linked chain of fixed-slot ring segments (the 2009
    FastFlow TR's uSPSC design, lifted onto shm segments).

    The producer writes into its current tail segment; when the ring fills
    it creates a fresh segment, drops a ``SEG`` marker (the new segment's
    name) into the permanently-reserved last slot, and carries on in the
    new ring — the push side never blocks on a slow consumer.  The consumer
    drains its current head segment; the marker is by construction the
    final slot of a segment, so on decoding one it retires the drained
    segment (close + unlink) and re-attaches the next by name.  Every
    segment individually keeps the wait-free single-writer discipline, and
    one shared :class:`ShmArena` spans the whole chain (allocation order ==
    consumption order across segments, so FIFO freeing still holds).

    Same push/pop surface as :class:`ShmSPSCQueue`; ``bounded=False`` lanes
    in a farm are exactly this class.  ``close()`` marks the *producer's*
    current segment, so the drain-then-raise contract is *per chain*: the
    consumer raises ``QueueClosed`` only after following every marker to
    the closed final segment and emptying it.
    """

    def __init__(self, capacity: int = 64, slot_bytes: int = 1 << 16,
                 arena_bytes: int = 0, _seg: Optional[ShmSPSCQueue] = None,
                 _arena: Optional[ShmArena] = None):
        if capacity < 4:
            raise ValueError("uSPSC segment capacity must be >= 4")
        self._cap = capacity
        self._slot = slot_bytes
        if _seg is not None:            # attaching side (unpickle)
            self._arena = _arena
            seg = _seg
        else:
            self._arena = ShmArena(arena_bytes) if arena_bytes > 0 else None
            seg = ShmSPSCQueue(capacity, slot_bytes)
            seg._arena = self._arena
            # uSPSC segments live outside the resource tracker: retirement
            # crosses process boundaries (the consumer unlinks what the
            # producer created), which the tracker's per-name set cannot
            # express without double-unregister noise
            _unregister_tracker(seg.name)
        self._w = seg                   # producer's current tail segment
        self._r = seg                   # consumer's current head segment
        self._retired: deque = deque()  # grown-past segments awaiting drain
        self.segments_grown = 0         # producer-side, process-local

    # -- pickling: both sides start at the producer's current segment -------
    def __getstate__(self):
        return {"capacity": self._cap, "slot_bytes": self._slot,
                "seg": self._w.__getstate__(),
                "arena": None if self._arena is None
                else self._arena.__getstate__()}

    def __setstate__(self, state):
        arena = None
        if state["arena"] is not None:
            arena = ShmArena.__new__(ShmArena)
            arena.__setstate__(state["arena"])
        seg = ShmSPSCQueue.__new__(ShmSPSCQueue)
        seg.__setstate__(state["seg"])
        seg._arena = arena
        self.__init__(state["capacity"], state["slot_bytes"],
                      _seg=seg, _arena=arena)

    @property
    def capacity(self) -> int:
        """Per-segment capacity — the chain itself is unbounded."""
        return self._cap - 1

    @property
    def unbounded(self) -> bool:
        return True

    @property
    def max_depth(self) -> int:
        return self._w.max_depth

    @property
    def arena_pushes(self) -> int:
        return self._w.arena_pushes

    @property
    def pickle_fallbacks(self) -> int:
        return self._w.pickle_fallbacks

    def __len__(self) -> int:
        # local view only: the segments this handle currently maps
        n = len(self._r)
        if self._w is not self._r:
            n += len(self._w)
        return n

    def empty(self) -> bool:
        return self._r.empty() and self._w.empty()

    @property
    def closed(self) -> bool:
        # producer view; consumers detect shutdown via drained() (the flag
        # lives on the chain's final segment, reached by draining)
        return self._w.closed

    def close(self) -> None:
        self._w.close()
        if self._r is not self._w:
            self._r.close()

    def drained(self) -> bool:
        return self._r.closed and self._r.empty()

    # -- producer side -------------------------------------------------------
    def _free_w(self) -> int:
        w = self._w
        return (w._load(_OFF_HEAD) - w._load(_OFF_TAIL) - 1) % w._cap

    def _grow(self) -> None:
        """Chain a fresh segment: marker into the reserved last slot of the
        full ring, then switch writes over."""
        new = ShmSPSCQueue(self._cap, self._slot)
        new._arena = self._arena
        _unregister_tracker(new.name)   # tracker-free, like every segment
        ok = self._w._try_push_tag(TAG_SEG, new.__getstate__())
        assert ok, "uSPSC reserved growth slot was taken"
        old = self._w
        self._w = new
        self.segments_grown += 1
        # this handle may also BE the consumer (in-process use), so the old
        # mapping cannot be dropped eagerly — park it and close mappings of
        # segments the consumer has provably drained
        if old is not self._r:
            self._retired.append(old)
        while self._retired:
            seg = self._retired[0]
            if seg._buf is not None and not seg.empty():
                break                   # consumer still inside it
            if seg._buf is not None:
                seg._arena = None       # the chain arena outlives segments
                seg.detach()
            self._retired.popleft()

    def try_push(self, item: Any, seq: int = 0) -> bool:
        if self._free_w() <= 1:        # only the reserved marker slot left
            self._grow()
        return self._w.try_push(item, seq)

    def try_push_many(self, items: Sequence[Any],
                      seqs: Optional[Sequence[int]] = None) -> int:
        total = 0
        n = len(items)
        while total < n:
            k = self._w.try_push_many(
                items[total:] if total else items,
                (seqs[total:] if total else seqs) if seqs is not None
                else None,
                reserve=1)
            total += k
            if total >= n:
                break
            if self._free_w() <= 1:
                self._grow()            # ring-bound stall: chain and go on
                continue
            break                       # arena-bound stall: let caller retry
        return total

    def push(self, item: Any, timeout: Optional[float] = None,
             seq: int = 0) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            if self.closed:
                raise QueueClosed("push to closed shm queue")
            if self.try_push(item, seq):
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm uSPSC push timed out")
            time.sleep(delay)           # arena back-pressure only
            delay = min(delay * 2, 1e-3)

    def push_many(self, items: Sequence[Any],
                  seqs: Optional[Sequence[int]] = None,
                  timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        done = 0
        n = len(items)
        while done < n:
            if self.closed:
                raise QueueClosed("push_many to closed shm queue")
            k = self.try_push_many(
                items[done:] if done else items,
                (seqs[done:] if done else seqs) if seqs is not None else None)
            done += k
            if done >= n:
                return
            if k:
                delay = 1e-6
                continue
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm uSPSC push_many timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def push_eos(self, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            if self.closed:
                raise QueueClosed("push_eos to closed shm queue")
            if self._free_w() <= 1:
                self._grow()
            if self._w._try_push_tag(TAG_EOS, None):
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm uSPSC push_eos timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def push_err(self, err: ShmError,
                 timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            if self.closed:
                raise QueueClosed("push_err to closed shm queue")
            if self._free_w() <= 1:
                self._grow()
            if self._w._try_push_tag(TAG_ERR, err):
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm uSPSC push_err timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    # -- consumer side -------------------------------------------------------
    def _switch(self, mark: _SegMark) -> None:
        """Follow a growth marker: retire the drained segment, attach the
        next.  Retiring unlinks — this side inherited ownership when the
        producer grew past it."""
        state = dict(mark.state)
        new = ShmSPSCQueue(state["capacity"], state["slot_bytes"],
                           name=state["name"], _create=False)
        new._arena = self._arena
        old = self._r
        self._r = new
        if self._w is old:              # attached handle: track the head
            self._w = new
        old._arena = None               # the chain arena outlives segments
        old._unlink_any()

    def try_pop_seq(self) -> Tuple[bool, Any, int]:
        while True:
            ok, item, seq = self._r.try_pop_seq()
            if ok and isinstance(item, _SegMark):
                self._switch(item)
                continue
            return ok, item, seq

    def try_pop(self) -> Tuple[bool, Any]:
        ok, item, _seq = self.try_pop_seq()
        return ok, item

    def try_pop_many(self, max_items: int = 256) -> List[Tuple[Any, int]]:
        out: List[Tuple[Any, int]] = []
        while len(out) < max_items:
            got = self._r.try_pop_many(max_items - len(out))
            if not got:
                break
            # a marker is always the last slot of its segment
            if isinstance(got[-1][0], _SegMark):
                out.extend(got[:-1])
                self._switch(got[-1][0])
                continue
            out.extend(got)
        return out

    def pop_seq(self, timeout: Optional[float] = None) -> Tuple[Any, int]:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            ok, item, seq = self.try_pop_seq()
            if ok:
                return item, seq
            if self.drained():
                raise QueueClosed("pop from closed empty shm queue")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm uSPSC pop timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def pop(self, timeout: Optional[float] = None) -> Any:
        return self.pop_seq(timeout)[0]

    def pop_many(self, max_items: int = 256,
                 timeout: Optional[float] = None) -> List[Tuple[Any, int]]:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            got = self.try_pop_many(max_items)
            if got:
                return got
            if self.drained():
                raise QueueClosed("pop from closed empty shm queue")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm uSPSC pop_many timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    # -- segment lifetime ----------------------------------------------------
    def detach(self) -> None:
        for seg in (self._r, self._w):
            seg._arena = None           # the chain arena outlives segments
        self._r.detach()
        if self._w is not self._r:
            self._w.detach()
        if self._arena is not None:
            self._arena.detach()

    def destroy(self) -> None:
        """Unlink whatever segments this handle still maps (intermediate
        segments were already retired by the consumer as it drained)."""
        for seg in (self._r, self._w):
            seg._arena = None
        self._r._unlink_any()
        if self._w is not self._r:
            self._w._unlink_any()
        for seg in self._retired:       # mapped but not yet swept
            seg._arena = None
            seg._unlink_any()
        self._retired.clear()
        if self._arena is not None:
            if self._arena._creator:
                self._arena.destroy()
            else:
                self._arena.detach()


class ShmSPMCQueue:
    """Single producer, multiple consumer *processes*: one shm SPSC lane per
    consumer, round-robin by default (mirrors
    :class:`~repro.core.queues.SPMCQueue`)."""

    def __init__(self, n_consumers: int, capacity: int = 64,
                 slot_bytes: int = 1 << 16, arena_bytes: int = 0,
                 bounded: bool = True):
        if bounded:
            self.lanes = [ShmSPSCQueue(capacity, slot_bytes,
                                       arena_bytes=arena_bytes)
                          for _ in range(n_consumers)]
        else:
            self.lanes = [ShmUSPSCQueue(max(capacity, 4), slot_bytes,
                                        arena_bytes=arena_bytes)
                          for _ in range(n_consumers)]
        self._rr = 0

    @classmethod
    def from_lanes(cls, lanes: List[Any]) -> "ShmSPMCQueue":
        """Wrap pre-built lanes (the farm builds them one worker at a time
        so each lane's pages can first-touch on its worker's NUMA node)."""
        self = cls.__new__(cls)
        self.lanes = list(lanes)
        self._rr = 0
        return self

    def push_to(self, idx: int, item: Any,
                timeout: Optional[float] = None) -> None:
        self.lanes[idx].push(item, timeout)

    def push_rr(self, item: Any, timeout: Optional[float] = None) -> int:
        idx = self._rr
        self.lanes[idx].push(item, timeout)
        self._rr = (self._rr + 1) % len(self.lanes)
        return idx

    def broadcast_eos(self) -> None:
        for lane in self.lanes:
            lane.push_eos()

    def close_all(self) -> None:
        for lane in self.lanes:
            lane.close()

    def destroy(self) -> None:
        for lane in self.lanes:
            lane.destroy()


class ShmMPSCQueue:
    """Multiple producer processes, single consumer: one shm SPSC lane per
    producer, drained fairly (mirrors
    :class:`~repro.core.queues.MPSCQueue`)."""

    def __init__(self, n_producers: int, capacity: int = 64,
                 slot_bytes: int = 1 << 16, arena_bytes: int = 0):
        self.lanes = [ShmSPSCQueue(capacity, slot_bytes,
                                   arena_bytes=arena_bytes)
                      for _ in range(n_producers)]
        self._next = 0

    @classmethod
    def from_lanes(cls, lanes: List[Any]) -> "ShmMPSCQueue":
        """Wrap pre-built lanes (see :meth:`ShmSPMCQueue.from_lanes`)."""
        self = cls.__new__(cls)
        self.lanes = list(lanes)
        self._next = 0
        return self

    def lane(self, idx: int) -> ShmSPSCQueue:
        return self.lanes[idx]

    def try_pop_any_seq(self) -> Tuple[bool, Any, int, int]:
        n = len(self.lanes)
        for off in range(n):
            i = (self._next + off) % n
            ok, item, seq = self.lanes[i].try_pop_seq()
            if ok:
                self._next = (i + 1) % n
                return True, item, i, seq
        return False, None, -1, 0

    def try_pop_any_many(self,
                         max_items: int = 256) -> List[Tuple[Any, int, int]]:
        """Vectored fair drain: ``(item, lane, seq)`` triples, one head
        publish per non-empty lane visited.  Per-lane FIFO order holds (a
        lane's run stays contiguous); fairness rotates the start lane."""
        n = len(self.lanes)
        out: List[Tuple[Any, int, int]] = []
        for off in range(n):
            i = (self._next + off) % n
            got = self.lanes[i].try_pop_many(max_items - len(out))
            if got:
                out.extend((item, i, seq) for item, seq in got)
                if len(out) >= max_items:
                    self._next = (i + 1) % n
                    break
        if out and len(out) < max_items:
            self._next = (self._next + 1) % n
        return out

    def try_pop_any(self) -> Tuple[bool, Any, int]:
        ok, item, i, _seq = self.try_pop_any_seq()
        return ok, item, i

    def pop_any(self, timeout: Optional[float] = None) -> Tuple[Any, int]:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            ok, item, i = self.try_pop_any()
            if ok:
                return item, i
            if all(lane.drained() for lane in self.lanes):
                raise QueueClosed("pop from closed and drained shm MPSC")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm MPSC pop timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def close_all(self) -> None:
        for lane in self.lanes:
            lane.close()

    def destroy(self) -> None:
        for lane in self.lanes:
            lane.destroy()


class ShmMPMCGrid:
    """Multiple producer / multiple consumer *processes*: an nL x nR grid of
    shm SPSC lanes (producer ``i`` -> consumer ``j``), the process-tier
    instance of :class:`~repro.core.queues.MPMCQueue`.

    Producer ``i`` writes only row ``i`` and consumer ``j`` reads only column
    ``j``, so every lane keeps the wait-free single-writer index discipline —
    the MPMC behaviour is composition, not locking.  This is the stage
    interconnect of the process-backed ``all_to_all``: left worker processes
    attach their row (``row(i)``), right worker processes their column
    (``col(j)``); both are plain lists of picklable lanes, so a child maps
    only the segments it touches."""

    def __init__(self, n_producers: int, n_consumers: int, capacity: int = 64,
                 slot_bytes: int = 1 << 16, arena_bytes: int = 0):
        self.grid = [[ShmSPSCQueue(capacity, slot_bytes,
                                   arena_bytes=arena_bytes)
                      for _ in range(n_consumers)]
                     for _ in range(n_producers)]
        self._next = [0] * n_consumers

    @property
    def n_producers(self) -> int:
        return len(self.grid)

    @property
    def n_consumers(self) -> int:
        return len(self.grid[0]) if self.grid else 0

    def row(self, i: int) -> List[ShmSPSCQueue]:
        """Producer ``i``'s output lanes, one per consumer."""
        return self.grid[i]

    def col(self, j: int) -> List[ShmSPSCQueue]:
        """Consumer ``j``'s input lanes, one per producer."""
        return [r[j] for r in self.grid]

    def push(self, producer: int, consumer: int, item: Any,
             timeout: Optional[float] = None, seq: int = 0) -> None:
        self.grid[producer][consumer].push(item, timeout, seq=seq)

    def try_pop(self, consumer: int) -> Tuple[bool, Any, int, int]:
        """Fair non-blocking pop from ``consumer``'s column:
        ``(ok, item, producer, seq)``."""
        n = len(self.grid)
        for off in range(n):
            i = (self._next[consumer] + off) % n
            ok, item, seq = self.grid[i][consumer].try_pop_seq()
            if ok:
                self._next[consumer] = (i + 1) % n
                return True, item, i, seq
        return False, None, -1, 0

    def pop(self, consumer: int,
            timeout: Optional[float] = None) -> Tuple[Any, int, int]:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            ok, item, i, seq = self.try_pop(consumer)
            if ok:
                return item, i, seq
            if all(row[consumer].drained() for row in self.grid):
                raise QueueClosed("pop from closed and drained shm MPMC column")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("shm MPMC pop timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def max_depth(self) -> int:
        """Process-local high-water mark over every lane this side pushed."""
        return max((l.max_depth for row in self.grid for l in row), default=0)

    def close_all(self) -> None:
        for row in self.grid:
            for lane in row:
                lane.close()

    def destroy(self) -> None:
        for row in self.grid:
            for lane in row:
                lane.destroy()
