"""Performance model — paper Sec. 13, extended with the TPU roofline.

The paper's algebra:
  * farm:     T(m tasks, nw workers) ~= T_seq / nw, bounded by emitter /
              collector service times and Amdahl's law;
  * pipeline: service time T_S = max_i T_Si; speedup = sum T_Si / max T_Si.

We reuse exactly that algebra to pick pipeline microbatch counts and farm
widths, and extend it with a three-term roofline (compute / HBM / ICI) used by
benchmarks/roofline.py and the §Perf hillclimb.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import time
import warnings
from typing import Dict, Optional, Sequence


# --------------------------------------------------------------------------
# Paper Sec. 13 algebra
# --------------------------------------------------------------------------
def farm_time(m_tasks: int, t_task: float, nw: int,
              t_emit: float = 0.0, t_collect: float = 0.0) -> float:
    """Completion time of m tasks on an nw-worker farm: workers process in
    parallel, but the emitter/collector are serial stages — the farm's
    service time is max(t_emit, t_task/nw, t_collect)."""
    service = max(t_emit, t_task / nw, t_collect)
    return m_tasks * service + t_task  # + one task latency (paper: latency
    # of a single task does not decrease)


def farm_speedup(m_tasks: int, t_task: float, nw: int,
                 t_emit: float = 0.0, t_collect: float = 0.0) -> float:
    return (m_tasks * t_task) / farm_time(m_tasks, t_task, nw, t_emit, t_collect)


def pipeline_service_time(stage_times: Sequence[float]) -> float:
    return max(stage_times)


def pipeline_time(m_tasks: int, stage_times: Sequence[float]) -> float:
    """m x T_S plus the fill latency sum(T_Si)."""
    return m_tasks * pipeline_service_time(stage_times) + sum(stage_times)


def pipeline_speedup(stage_times: Sequence[float], m_tasks: int = 10**9) -> float:
    """-> sum T_Si / max T_Si for long streams (paper's formula)."""
    seq = sum(stage_times)
    return (m_tasks * seq) / pipeline_time(m_tasks, stage_times) * (1.0)


def amdahl(serial_fraction: float, n: int) -> float:
    return 1.0 / (serial_fraction + (1.0 - serial_fraction) / n)


def choose_farm_width(t_task: float, n_max: int, t_emit: float = 0.0,
                      t_collect: float = 0.0,
                      overhead: float = 2e-5) -> int:
    """Smallest worker count whose per-item service time hits the farm's
    serial floor: service = max(t_emit, t_task/nw, t_collect), so adding
    workers beyond t_task/floor buys nothing (paper Sec. 13).  ``overhead``
    is the channel's own service time (queue push/pop) — the floor even for
    a free emitter.  Used by the graph compiler's ``place`` stage."""
    floor = max(t_emit, t_collect, overhead, 1e-9)
    w = math.ceil(t_task / floor)
    return max(1, min(w, max(1, n_max)))


def a2a_service_time(t_left: float, t_right: float, n_left: int,
                     n_right: int, hop: float = 0.0) -> float:
    """Steady-state per-item service time of an ``all_to_all`` stage: the
    left and right ranks pipeline across the lane grid, so the stage's
    service time is the slower side over its width — floored by twice the
    per-item channel hop, because the hosting node pays the emitter-side
    push and the collector-side pop serially for every item.  Used by the
    compiler's ``place`` to cost the process-tier a2a against the
    GIL-serialized thread estimate."""
    return max(t_left / max(1, n_left), t_right / max(1, n_right),
               2.0 * hop)


def pipeline_bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble: (S-1)/(M+S-1) — the fill/drain idle fraction of the
    device pipeline skeleton."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def choose_microbatches(n_stages: int, max_bubble: float = 0.1,
                        max_micro: int = 256) -> int:
    """Smallest M with bubble fraction <= max_bubble."""
    m = math.ceil((n_stages - 1) * (1.0 - max_bubble) / max_bubble)
    return max(1, min(m, max_micro))


# --------------------------------------------------------------------------
# TPU v5e roofline (target hardware; this container only dry-runs)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float   # per chip, FLOP/s
    hbm_bw: float            # per chip, B/s
    ici_bw: float            # per link, B/s
    dci_bw: float            # per pod-to-pod link share, B/s
    hbm_bytes: float


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    dci_bw=6.25e9,   # conservative DCI share per chip
    hbm_bytes=16 * 2**30,
)


@dataclasses.dataclass
class RooflineTerms:
    """The three terms, in seconds, per step, per chip (the prompt's
    definitions: totals divided by (chips x peak))."""
    compute_s: float
    memory_s: float
    collective_s: float
    # breakdown
    flops_total: float = 0.0
    bytes_total: float = 0.0
    coll_bytes_ici: float = 0.0
    coll_bytes_dci: float = 0.0
    model_flops: float = 0.0
    model_flops_s: float = 0.0   # time to run MODEL_FLOPS at peak

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (perfect-overlap) step time = max of terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute fraction: MODEL_FLOPS-at-peak time / step-time."""
        if self.step_time_s == 0 or not self.model_flops:
            return 0.0
        return self.model_flops_s / self.step_time_s


def roofline(flops_total: float, bytes_total: float,
             coll_bytes_ici_per_chip: float, n_chips: int,
             hw: HardwareSpec = TPU_V5E,
             coll_bytes_dci_per_chip: float = 0.0,
             model_flops: float = 0.0) -> RooflineTerms:
    """flops_total/bytes_total are fleet totals (sum over chips); collective
    bytes are per-chip link traffic (ring-model)."""
    compute_s = flops_total / (n_chips * hw.peak_flops_bf16)
    memory_s = bytes_total / (n_chips * hw.hbm_bw)
    collective_s = (coll_bytes_ici_per_chip / hw.ici_bw
                    + coll_bytes_dci_per_chip / hw.dci_bw)
    return RooflineTerms(
        compute_s, memory_s, collective_s,
        flops_total=flops_total, bytes_total=bytes_total,
        coll_bytes_ici=coll_bytes_ici_per_chip,
        coll_bytes_dci=coll_bytes_dci_per_chip,
        model_flops=model_flops,
        model_flops_s=model_flops / (n_chips * hw.peak_flops_bf16))


# --------------------------------------------------------------------------
# Startup calibration — measured host constants for the compiler's place pass
# --------------------------------------------------------------------------
@dataclasses.dataclass
class HostCalibration:
    """The host-tier cost constants ``place`` consumes.  ``source`` records
    where they came from: baked-in ``default``s, a fresh ``measured`` run, or
    the on-disk ``cached`` result of an earlier run on this machine."""

    peak_flops: float           # useful numpy FLOP/s of one host core
    queue_hop_s: float          # per-item thread-tier SPSC push+pop cost
    proc_hop_s: float           # per-item process-lane (shm ring) hop cost
    device_dispatch_s: float    # per-microbatch host<->device boundary cost
    net_hop_s: float = 5e-4     # per-item network-lane (TCP frame) hop cost
    # marginal per-stage cost of one extra stage INSIDE a fused (single-jit)
    # device segment: what an adjacent device stage pays once core/fuse.py
    # has merged it into the run, vs. the full device_dispatch_s it would
    # pay as its own program.  Measured as (t_chain(K) - t_chain(1))/(K-1)
    # on jitted stage chains; typically ~0 (XLA fuses the bodies), which is
    # exactly why place() should amortize the one real dispatch across the
    # whole fused run.
    fused_segment_s: float = 2e-6
    # per-item cost of the *vectored* process lane (push_many/pop_many
    # amortize the index traffic and the pickling over a batch) — what the
    # batched farm transport actually pays per item
    shm_batched_hop_s: float = 5e-5
    # streaming bandwidth of the slab arena (oversize-ndarray path), GB/s
    arena_bw_gbs: float = 2.0
    # host<->device boundary transfer bandwidths (GB/s): what one microbatch
    # pays to cross the boundary each way.  With the overlapped boundary
    # (double-buffered async device_put / copy-out) these are what place()
    # charges AGAINST compute, not in addition to it.
    h2d_bw_gbs: float = 8.0
    d2h_bw_gbs: float = 8.0
    # overlap efficiency of the async boundary: 1.0 = transfers hide
    # perfectly behind compute (cost = max(transfer, compute)), 0.0 = no
    # overlap at all (cost = transfer + compute).  Measured by timing a
    # depth-K in-flight dispatch window against K synchronous round trips.
    overlap_eff: float = 0.5
    source: str = "default"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def proc_hop_effective_s(self) -> float:
        """The per-item process-lane cost placement should charge.  The
        farm transport is batched, so the amortized hop is the honest
        per-item price; capped by ``proc_hop_s`` so a noisy batched probe
        can never make the process tier look *worse* than per-item."""
        return min(self.proc_hop_s, self.shm_batched_hop_s)

    def boundary_time(self, transfer_s: float, compute_s: float) -> float:
        """Cost of one fused device run behind the *overlapped* boundary:
        the async window hides ``overlap_eff`` of the smaller term behind
        the larger one, so the run costs ``max(transfer, compute)`` plus
        the unhidden remainder — never their plain sum (the synchronous
        boundary's price), never better than the larger term alone."""
        lo, hi = min(transfer_s, compute_s), max(transfer_s, compute_s)
        eff = min(1.0, max(0.0, self.overlap_eff))
        return hi + (1.0 - eff) * lo


# conservative fallbacks, used only until/unless calibrate() has run
DEFAULT_CALIBRATION = HostCalibration(
    peak_flops=5e10, queue_hop_s=2e-5, proc_hop_s=2e-4,
    device_dispatch_s=2e-5, net_hop_s=5e-4, fused_segment_s=2e-6,
    shm_batched_hop_s=5e-5, arena_bw_gbs=2.0,
    h2d_bw_gbs=8.0, d2h_bw_gbs=8.0, overlap_eff=0.5, source="default")

# version 5: h2d_bw_gbs/d2h_bw_gbs + overlap_eff (the overlapped device
# boundary); version 4: fused_segment_s (device-segment fusion) + the
# autotune table; version 3: shm_batched_hop_s + arena_bw_gbs joined (the
# batched uSPSC transport); version 2 added net_hop_s — older caches must
# miss cleanly
_CALIB_VERSION = 5
_calibration: Optional[HostCalibration] = None


def _calib_cache_path() -> str:
    """Resolution order: ``REPRO_FF_CALIB_CACHE`` (exact file path) >
    ``REPRO_FF_CACHE`` (cache *directory* for everything this framework
    persists — what CI sets per job so runs are hermetic and the
    calibration can be pre-warmed once instead of re-measured in every
    pytest worker) > ``XDG_CACHE_HOME`` > ``~/.cache``."""
    override = os.environ.get("REPRO_FF_CALIB_CACHE")
    if override:
        return override
    base = os.environ.get("REPRO_FF_CACHE")
    if base:
        return os.path.join(base, "calibration.json")
    xdg = os.environ.get("XDG_CACHE_HOME",
                         os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(xdg, "repro_ff", "calibration.json")


def _measure_peak_flops() -> float:
    import numpy as np
    n = 192
    a = np.random.default_rng(0).standard_normal((n, n)).astype(np.float32)
    flops = 2.0 * n ** 3
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        a @ a
        best = min(best, time.perf_counter() - t0)
    return flops / max(best, 1e-9)


def _measure_queue_hop() -> float:
    from .queues import SPSCQueue
    q = SPSCQueue(256)
    n = 20_000
    t0 = time.perf_counter()
    for i in range(n):
        q.try_push(i)
        q.try_pop()
    return max((time.perf_counter() - t0) / n, 1e-9)


def _echo_main(in_lane, out_lane) -> None:
    """Calibration child: bounce items straight back (proc-lane hop probe)."""
    from .node import EOS
    while True:
        item = in_lane.pop()
        if item is EOS:
            break
        out_lane.push(item)
    out_lane.push_eos()


def _measure_proc_hop(n: int = 200) -> float:
    import numpy as np
    from .process import _mp_context, _quiet_fork
    from .shm import ShmSPSCQueue
    ping = ShmSPSCQueue(capacity=16)
    pong = ShmSPSCQueue(capacity=16)
    proc = _mp_context().Process(target=_echo_main, args=(ping, pong),
                                 daemon=True, name="ff-calibrate-echo")
    with _quiet_fork():
        proc.start()
    payload = np.arange(64, dtype=np.float32)
    try:
        ping.push(payload, timeout=5.0)         # warm both directions
        pong.pop(timeout=5.0)
        # streaming, not ping-pong: the farm emitter pushes a stream while
        # the collector drains, so the relevant hop cost is the pipelined
        # per-item cost, not the one-item round-trip latency.  Items ride
        # bare, like the farm protocol, so this measures the raw-slab path.
        sent = recv = 0
        deadline = time.monotonic() + 10.0
        t0 = time.perf_counter()
        while recv < n:
            progressed = False
            if sent < n and ping.try_push(payload):
                sent += 1
                progressed = True
            ok, _ = pong.try_pop()
            if ok:
                recv += 1
                progressed = True
            if not progressed:
                if time.monotonic() > deadline:
                    raise TimeoutError("proc-hop calibration stalled")
                time.sleep(1e-6)
        rtt = 2.0 * (time.perf_counter() - t0) / n  # keep rtt/2 == per hop
    finally:
        try:
            ping.push_eos(timeout=1.0)
        except TimeoutError:
            pass
        proc.join(timeout=5.0)
        if proc.is_alive():
            proc.terminate()
        ping.destroy()
        pong.destroy()
    return max(rtt / 2.0, 1e-9)


def _echo_many_main(in_lane, out_lane, batch: int) -> None:
    """Calibration child: bounce items back in vectored batches (batched
    proc-lane hop probe — same pop_many/push_many path the farm workers use)."""
    from .node import EOS
    done = False
    while not done:
        out = []
        for item, _seq in in_lane.pop_many(batch):
            if item is EOS:
                done = True
                break
            out.append(item)
        if out:
            out_lane.push_many(out)
    out_lane.push_eos()


def _measure_shm_batched_hop(n: int = 2000, batch: int = 32) -> float:
    """Per-item cost of the *vectored* process lane: same streaming echo
    shape as :func:`_measure_proc_hop`, but both sides move items with
    ``try_push_many``/``try_pop_many`` so the index traffic and the pickling
    amortize over the batch.  This is what a batched farm hop actually costs
    per item, and what ``place`` should charge for the process tier."""
    from .process import _mp_context, _quiet_fork
    from .shm import ShmSPSCQueue
    ping = ShmSPSCQueue(capacity=64)
    pong = ShmSPSCQueue(capacity=64)
    proc = _mp_context().Process(target=_echo_many_main,
                                 args=(ping, pong, batch),
                                 daemon=True, name="ff-calibrate-echo-many")
    with _quiet_fork():
        proc.start()
    items = list(range(batch))                  # small items: the batch win
    try:
        ping.push_many(items, timeout=5.0)      # warm both directions
        got = 0
        deadline = time.monotonic() + 5.0
        while got < batch:
            got += len(pong.try_pop_many(batch))
            if time.monotonic() > deadline:
                raise TimeoutError("batched-hop calibration warmup stalled")
        sent = recv = 0
        deadline = time.monotonic() + 10.0
        t0 = time.perf_counter()
        while recv < n:
            progressed = False
            if sent < n:
                k = ping.try_push_many(items[:min(batch, n - sent)])
                sent += k
                progressed = progressed or k > 0
            k = len(pong.try_pop_many(batch))
            recv += k
            progressed = progressed or k > 0
            if not progressed:
                if time.monotonic() > deadline:
                    raise TimeoutError("batched-hop calibration stalled")
                time.sleep(1e-6)
        rtt = 2.0 * (time.perf_counter() - t0) / n  # keep rtt/2 == per hop
    finally:
        try:
            ping.push_eos(timeout=1.0)
        except TimeoutError:
            pass
        proc.join(timeout=5.0)
        if proc.is_alive():
            proc.terminate()
        ping.destroy()
        pong.destroy()
    return max(rtt / 2.0, 1e-9)


def _measure_arena_bw(nbytes: int = 4 << 20, reps: int = 5) -> float:
    """Streaming bandwidth (GB/s) of the slab-arena path: one oversize
    ndarray through an arena-backed lane per rep (producer copy in + consumer
    copy out), in-process so it measures memory bandwidth, not scheduling."""
    import numpy as np
    from .shm import ShmSPSCQueue
    q = ShmSPSCQueue(capacity=4, slot_bytes=1024, arena_bytes=2 * nbytes)
    try:
        a = np.zeros(nbytes // 4, dtype=np.float32)
        q.try_push(a)                           # warm the mappings
        q.try_pop()
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            if not q.try_push(a):
                break
            ok, _ = q.try_pop()
            if not ok:
                break
            best = min(best, time.perf_counter() - t0)
        if not (best < float("inf")) or q.arena_pushes == 0:
            return DEFAULT_CALIBRATION.arena_bw_gbs
        return max(nbytes / best / 1e9, 1e-3)
    finally:
        q.destroy()


def _measure_net_hop(n: int = 200) -> float:
    """Per-item network-lane hop cost, measured over loopback TCP with the
    actual frame codec of ``core/net.py`` (raw-ndarray fast path).  Streamed
    pipelined like :func:`_measure_proc_hop` — the remote farm's emitter and
    collector overlap, so the relevant figure is the per-item cost of a full
    round trip divided by two, not one-frame latency."""
    import socket
    import struct
    import threading

    import numpy as np
    try:
        from .net import (TAG_EOS, decode_payload, encode_frame, encode_item,
                          read_frame)
        from .shm import _SLOT_FMT
        ls = socket.create_server(("127.0.0.1", 0))
        port = ls.getsockname()[1]

        def _echo() -> None:
            conn, _peer = ls.accept()
            try:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                while True:
                    fr = read_frame(conn)
                    if fr is None or fr[0] == TAG_EOS:
                        return
                    tag, payload, seq = fr
                    conn.sendall(struct.pack(_SLOT_FMT, len(payload),
                                             tag, seq) + payload)
            finally:
                conn.close()

        echo = threading.Thread(target=_echo, daemon=True,
                                name="ff-calibrate-net-echo")
        echo.start()
        sock = socket.create_connection(("127.0.0.1", port), timeout=5.0)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        frame = encode_item(np.arange(64, dtype=np.float32))
        try:
            sock.sendall(frame)                 # warm both directions
            read_frame(sock)
            t0 = time.perf_counter()

            def _send() -> None:
                for _ in range(n):
                    sock.sendall(frame)

            sender = threading.Thread(target=_send, daemon=True)
            sender.start()
            for _ in range(n):
                tag, payload, _seq = read_frame(sock)
                decode_payload(tag, payload)
            rtt = (time.perf_counter() - t0) / n
            sender.join(timeout=5.0)
            sock.sendall(encode_frame(TAG_EOS))
        finally:
            sock.close()
            ls.close()
            echo.join(timeout=5.0)
        return max(rtt / 2.0, 1e-9)
    except Exception:   # noqa: BLE001 - no loopback here: keep the default
        return DEFAULT_CALIBRATION.net_hop_s


def _measure_device_dispatch() -> float:
    try:
        import jax
        import jax.numpy as jnp
        f = jax.jit(lambda x: x + 1.0)
        x = jnp.zeros((8,), jnp.float32)
        jax.block_until_ready(f(x))             # compile outside the clock
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(f(x))
            best = min(best, time.perf_counter() - t0)
        return max(best, 1e-9)
    except Exception:   # noqa: BLE001 - no usable backend: keep the default
        return DEFAULT_CALIBRATION.device_dispatch_s


def _measure_fused_segment(k: int = 4) -> float:
    """Marginal per-stage cost inside one jitted device segment: time a
    ``k``-stage composed chain vs a 1-stage program and divide the extra
    by ``k - 1``.  Near-zero on every real backend (XLA fuses the bodies) —
    which is the measured fact that lets ``place`` charge a fused run one
    dispatch instead of one per stage."""
    try:
        import jax
        import jax.numpy as jnp

        def _chain(n):
            def f(x):
                for i in range(n):
                    x = x * 1.0001 + float(i)
                return x
            return jax.jit(f)

        x = jnp.zeros((8,), jnp.float32)

        def _best(f):
            jax.block_until_ready(f(x))         # compile outside the clock
            best = float("inf")
            for _ in range(5):
                t0 = time.perf_counter()
                jax.block_until_ready(f(x))
                best = min(best, time.perf_counter() - t0)
            return best

        t1, tk = _best(_chain(1)), _best(_chain(k))
        return max((tk - t1) / (k - 1), 1e-9)
    except Exception:   # noqa: BLE001 - no usable backend: keep the default
        return DEFAULT_CALIBRATION.fused_segment_s


def _measure_h2d_bw(nbytes: int = 4 << 20, reps: int = 5) -> float:
    """Host->device boundary bandwidth (GB/s): one device_put of an
    ``nbytes`` float32 array, synced, best of ``reps`` — the per-microbatch
    input cost of the device boundary node."""
    try:
        import jax
        import numpy as np
        a = np.zeros(nbytes // 4, dtype=np.float32)
        jax.block_until_ready(jax.device_put(a))    # warm the path
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(jax.device_put(a))
            best = min(best, time.perf_counter() - t0)
        return max(nbytes / max(best, 1e-9) / 1e9, 1e-3)
    except Exception:   # noqa: BLE001 - no usable backend: keep the default
        return DEFAULT_CALIBRATION.h2d_bw_gbs


def _measure_d2h_bw(nbytes: int = 4 << 20, reps: int = 5) -> float:
    """Device->host boundary bandwidth (GB/s): one full host copy-out of an
    ``nbytes`` device array, best of ``reps`` — the per-microbatch output
    cost of the device boundary node."""
    try:
        import jax
        import numpy as np
        x = jax.block_until_ready(
            jax.device_put(np.zeros(nbytes // 4, dtype=np.float32)))
        np.asarray(x)                               # warm the path
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            np.asarray(x)
            best = min(best, time.perf_counter() - t0)
        return max(nbytes / max(best, 1e-9) / 1e9, 1e-3)
    except Exception:   # noqa: BLE001 - no usable backend: keep the default
        return DEFAULT_CALIBRATION.d2h_bw_gbs


def _measure_overlap_eff(k: int = 8, reps: int = 3) -> float:
    """Overlap efficiency of JAX's async dispatch on this backend: time
    ``k`` jitted steps submitted as one in-flight window (sync only at the
    end) against the same ``k`` steps each synced before the next is
    submitted.  1 - window/serial is the fraction of per-step host round
    trips the window hides; clamped to [0, 1].  A backend with synchronous
    dispatch measures ~0 and place() falls back to costing the boundary as
    transfer + compute."""
    try:
        import jax
        import jax.numpy as jnp
        f = jax.jit(lambda x: x * 1.0001 + 1.0)
        x = jnp.zeros((256, 256), jnp.float32)
        jax.block_until_ready(f(x))                 # compile off the clock
        serial = window = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            for _i in range(k):
                jax.block_until_ready(f(x))
            serial = min(serial, time.perf_counter() - t0)
            t0 = time.perf_counter()
            ys = [f(x) for _i in range(k)]
            jax.block_until_ready(ys)
            window = min(window, time.perf_counter() - t0)
        if serial <= 0.0 or not (serial < float("inf")):
            return DEFAULT_CALIBRATION.overlap_eff
        return min(1.0, max(0.0, 1.0 - window / serial))
    except Exception:   # noqa: BLE001 - no usable backend: keep the default
        return DEFAULT_CALIBRATION.overlap_eff


def calibrate(cache: bool = True) -> HostCalibration:
    """Measure the host-tier cost constants on this machine and (optionally)
    persist them, replacing the baked-in defaults ``place`` would otherwise
    consume: one core's useful numpy FLOP/s, the per-item thread-queue hop,
    the per-item shared-memory process-lane hop, the per-item loopback
    network-lane hop, the host<->device dispatch cost, the boundary
    transfer bandwidths each way (h2d/d2h), and the async-dispatch overlap
    efficiency the overlapped boundary can bank on.

    A read-only or unwritable cache location (containerized remote workers,
    sealed CI sandboxes) degrades to in-memory constants with a one-line
    warning — never an exception."""
    global _calibration
    c = HostCalibration(
        peak_flops=_measure_peak_flops(),
        queue_hop_s=_measure_queue_hop(),
        proc_hop_s=_measure_proc_hop(),
        device_dispatch_s=_measure_device_dispatch(),
        net_hop_s=_measure_net_hop(),
        fused_segment_s=_measure_fused_segment(),
        shm_batched_hop_s=_measure_shm_batched_hop(),
        arena_bw_gbs=_measure_arena_bw(),
        h2d_bw_gbs=_measure_h2d_bw(),
        d2h_bw_gbs=_measure_d2h_bw(),
        overlap_eff=_measure_overlap_eff(),
        source="measured")
    _calibration = c
    if cache:
        path = _calib_cache_path()
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(path, "w") as f:
                json.dump({"version": _CALIB_VERSION,
                           "cpu_count": os.cpu_count(), **c.as_dict()}, f)
        except OSError as e:
            warnings.warn(
                f"perf_model: calibration cache {path!r} is not writable "
                f"({e}); keeping measured constants in memory only",
                RuntimeWarning, stacklevel=2)
    return c


def _load_cached_calibration() -> Optional[HostCalibration]:
    try:
        with open(_calib_cache_path()) as f:
            d = json.load(f)
        if not isinstance(d, dict) \
                or d.get("version") != _CALIB_VERSION \
                or d.get("cpu_count") != os.cpu_count():
            return None
        return HostCalibration(
            peak_flops=float(d["peak_flops"]),
            queue_hop_s=float(d["queue_hop_s"]),
            proc_hop_s=float(d["proc_hop_s"]),
            device_dispatch_s=float(d["device_dispatch_s"]),
            net_hop_s=float(d["net_hop_s"]),
            fused_segment_s=float(d["fused_segment_s"]),
            shm_batched_hop_s=float(d["shm_batched_hop_s"]),
            arena_bw_gbs=float(d["arena_bw_gbs"]),
            h2d_bw_gbs=float(d["h2d_bw_gbs"]),
            d2h_bw_gbs=float(d["d2h_bw_gbs"]),
            overlap_eff=float(d["overlap_eff"]),
            source="cached")
    except (OSError, ValueError, KeyError, TypeError):
        # any unreadable/corrupt cache is a miss, never a crash
        return None


def get_calibration(measure: bool = True) -> HostCalibration:
    """The process-wide calibration: memoized, then the on-disk cache, then a
    fresh :func:`calibrate` run (skipped when ``measure=False``, which
    returns the baked-in defaults instead)."""
    global _calibration
    if _calibration is not None:
        return _calibration
    cached = _load_cached_calibration()
    if cached is not None:
        _calibration = cached
        return cached
    if not measure:
        return DEFAULT_CALIBRATION
    return calibrate()


def reset_calibration() -> None:
    """Drop the in-memory calibration (tests)."""
    global _calibration
    _calibration = None


# --------------------------------------------------------------------------
# Online refinement — runner stats fed back into the calibration cache
# --------------------------------------------------------------------------
# ``calibrate()`` made the place() constants measured-at-startup instead of
# baked-in; ``observe()`` closes the remaining gap: runtime stats (sampled by
# core/runtime.Supervisor, or passed in by hand) refine BOTH the channel
# constants (shared-memory hop EMA) and a per-callable table of measured
# service times + GIL signals, so the *next* compile()'s annotate/place pass
# starts from what actually happened rather than a fresh sample probe.
# The table is keyed by ``fn_key`` (module.qualname — stable across runs of
# the same code, best-effort across edits) and persists inside the same
# on-disk calibration cache.

_OBSERVE_MIN_ITEMS = 8      # ignore records with fewer processed items
_observed: Optional[Dict[str, dict]] = None


def fn_key(fn) -> Optional[str]:
    """Stable-ish identity for a worker callable in the observed-cost table
    (``module.qualname``).  None for objects without one (partials, odd
    callables) — those simply never match an observation."""
    mod = getattr(fn, "__module__", None)
    qn = getattr(fn, "__qualname__", None)
    if not mod or not qn:
        return None
    return f"{mod}.{qn}"


def _load_observed() -> Dict[str, dict]:
    global _observed
    if _observed is None:
        _observed = {}
        try:
            with open(_calib_cache_path()) as f:
                d = json.load(f)
            obs = d.get("observed")
            if (isinstance(obs, dict) and d.get("version") == _CALIB_VERSION
                    and d.get("cpu_count") == os.cpu_count()):
                _observed = {str(k): dict(v) for k, v in obs.items()
                             if isinstance(v, dict)}
        except (OSError, ValueError, TypeError):
            pass
    return _observed


def lookup_observed(key: Optional[str],
                    min_items: int = _OBSERVE_MIN_ITEMS) -> Optional[dict]:
    """The observed cost record for a callable key, or None when there is no
    (sufficiently substantiated) history.  Consumed by the compiler's
    ``annotate`` stage: a callable with runtime history no longer needs a
    ``sample=`` probe to be cost-placed."""
    if not key:
        return None
    rec = _load_observed().get(key)
    if rec and rec.get("items", 0) >= min_items \
            and float(rec.get("t_task", 0.0)) > 0.0:
        return dict(rec)
    return None


def reset_observed() -> None:
    """Drop the in-memory observed-cost table (tests)."""
    global _observed
    _observed = None


def _save_cache_tables(what: str = "observed costs") -> None:
    """Persist calibration + observed + autotune tables into the one cache
    file; a read-only location degrades to in-memory with a warning."""
    path = _calib_cache_path()
    c = get_calibration(measure=False)
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump({"version": _CALIB_VERSION,
                       "cpu_count": os.cpu_count(), **c.as_dict(),
                       "observed": _load_observed(),
                       "autotune": _load_autotune()}, f)
    except OSError as e:
        warnings.warn(
            f"perf_model: calibration cache {path!r} is not writable ({e}); "
            f"keeping {what} in memory only",
            RuntimeWarning, stacklevel=2)


def _save_observed() -> None:
    _save_cache_tables("observed costs")


# --------------------------------------------------------------------------
# Tile autotuning — ``benchmarks/roofline.py --autotune`` winners
# --------------------------------------------------------------------------
# The sweep times kernel tile candidates (``block_t`` of the fused a2a hop
# and the router, ``chunk`` of the SSD scan) per shape on THIS backend and
# records the winners here, keyed ``"<kernel>:T<T>:E<E>:D<D>"``.  Kernels
# consult :func:`lookup_autotuned` when called without an explicit tile, so
# a pre-warmed cache (CI warms it alongside the calibration) changes real
# dispatch shapes without any pytest worker ever paying for the sweep; an
# absent record is simply a heuristic default, never a trigger to sweep.
# The table lives inside the same calibration.json (same REPRO_FF_CACHE
# resolution, same read-only degradation).

_autotune: Optional[Dict[str, dict]] = None


def _load_autotune() -> Dict[str, dict]:
    global _autotune
    if _autotune is None:
        _autotune = {}
        try:
            with open(_calib_cache_path()) as f:
                d = json.load(f)
            at = d.get("autotune")
            # unlike the observed table, tile winners do not gate on
            # cpu_count: they depend on the accelerator backend and shape
            if isinstance(at, dict) and d.get("version") == _CALIB_VERSION:
                _autotune = {str(k): dict(v) for k, v in at.items()
                             if isinstance(v, dict)}
        except (OSError, ValueError, TypeError):
            pass
    return _autotune


def lookup_autotuned(key: Optional[str]) -> Optional[dict]:
    """The autotuned record for a kernel/shape key (e.g.
    ``"a2a_fused:T256:E4:D64"``), or None — callers fall back to their
    heuristic tile and never sweep."""
    if not key:
        return None
    rec = _load_autotune().get(key)
    return dict(rec) if rec else None


def record_autotuned(entries: Dict[str, dict], write: bool = True) -> int:
    """Merge sweep winners into the autotune table; ``write=True`` persists
    them (with the calibration + observed tables) into the on-disk cache.
    Returns the number of records absorbed."""
    table = _load_autotune()
    n = 0
    for k, v in entries.items():
        if isinstance(v, dict):
            table[str(k)] = dict(v)
            n += 1
    if write and n:
        _save_cache_tables("autotune results")
    return n


def reset_autotuned() -> None:
    """Drop the in-memory autotune table (tests)."""
    global _autotune
    _autotune = None


def _stat_records(x, out: list) -> None:
    """Collect node-stat dicts from an arbitrarily nested stats() tree."""
    if isinstance(x, dict):
        if "svc_cpu_ema_s" in x or "hop_ema_s" in x or "fn_key" in x:
            out.append(x)
        for v in x.values():
            _stat_records(v, out)
    elif isinstance(x, (list, tuple)):
        for v in x:
            _stat_records(v, out)


def observe(stats: dict, alpha: float = 0.25, write: bool = False) -> int:
    """Fold one ``runner.stats()`` snapshot (or any nested stats tree) into
    the calibration state; returns the number of facts absorbed.

    - farm records carrying a ``fn_key`` and a per-item CPU-time EMA update
      the observed per-callable service time — thread-tier records from the
      parent's own measurement, process/remote-tier records from the
      worker-side :class:`~repro.core.shm.WorkerStats` CPU clocks shipped
      back over the result lanes (true service times, so the Supervisor's
      process->thread policy no longer needs the hop-domination heuristic);
      a thread record's ``gil_ratio`` (CPU/wall) measured under >=2
      concurrently active workers also settles the callable's GIL signal —
      below 0.7 the workers were serializing on the GIL
      (``releases_gil=False``), above 0.9 they truly ran in parallel
      (``True``);
    - process-tier records with a parent-side ``hop_ema_s`` refine the
      calibrated shared-memory lane hop with an EMA; remote-tier records
      refine the network-lane hop (``net_hop_s``) the same way.

    ``write=True`` persists the refreshed calibration + observed table into
    the on-disk cache (the supervisor writes once at ``stop()``; periodic
    in-memory merges stay cheap)."""
    global _calibration
    recs: list = []
    _stat_records(stats, recs)
    table = _load_observed()
    absorbed = 0
    for r in recs:
        items = int(r.get("items", 0) or 0)
        if items < _OBSERVE_MIN_ITEMS:
            continue
        key = r.get("fn_key")
        cpu = float(r.get("svc_cpu_ema_s", 0.0) or 0.0)
        backend = r.get("backend")
        if key and cpu > 0.0 and backend in ("thread", "process", "remote"):
            prev = table.get(key)
            rg = prev.get("releases_gil") if prev else None
            ratio = r.get("gil_ratio")     # thread records only
            if ratio is not None and int(r.get("active", 1) or 1) >= 2:
                if ratio < 0.7:
                    rg = False
                elif ratio > 0.9:
                    rg = True
            t = cpu if prev is None else \
                (1.0 - alpha) * float(prev["t_task"]) + alpha * cpu
            table[key] = {"t_task": t, "releases_gil": rg,
                          "items": max(items, prev["items"] if prev else 0)}
            absorbed += 1
        hop = float(r.get("hop_ema_s", 0.0) or 0.0)
        if hop > 0.0 and backend in ("process", "remote"):
            c = get_calibration(measure=False)
            if backend == "process":
                c = dataclasses.replace(
                    c, proc_hop_s=(1.0 - alpha) * c.proc_hop_s + alpha * hop,
                    source="observed")
            else:
                c = dataclasses.replace(
                    c, net_hop_s=(1.0 - alpha) * c.net_hop_s + alpha * hop,
                    source="observed")
            _calibration = c
            absorbed += 1
    if write and absorbed:
        _save_observed()
    return absorbed


# ring-model per-chip traffic for each collective kind -----------------------
def collective_link_bytes(kind: str, operand_bytes: float, group_size: int) -> float:
    """Per-chip bytes that traverse links for one collective, ring algorithm.
    ``operand_bytes`` is the per-device operand (post-SPMD HLO shapes are
    already per-device)."""
    n = max(group_size, 1)
    if n == 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * operand_bytes * (n - 1) / n
    if kind in ("all-gather",):
        # operand is the local shard; each chip receives (n-1) shards
        return operand_bytes * (n - 1)
    if kind in ("reduce-scatter",):
        return operand_bytes * (n - 1) / n
    if kind in ("all-to-all",):
        return operand_bytes * (n - 1) / n
    if kind in ("collective-permute", "collective-permute-start"):
        return operand_bytes
    return operand_bytes
