"""Performance model — paper Sec. 13, extended with the TPU roofline.

The paper's algebra:
  * farm:     T(m tasks, nw workers) ~= T_seq / nw, bounded by emitter /
              collector service times and Amdahl's law;
  * pipeline: service time T_S = max_i T_Si; speedup = sum T_Si / max T_Si.

We reuse exactly that algebra to pick pipeline microbatch counts and farm
widths, and extend it with a three-term roofline (compute / HBM / ICI) used by
benchmarks/roofline.py and the §Perf hillclimb.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence


# --------------------------------------------------------------------------
# Paper Sec. 13 algebra
# --------------------------------------------------------------------------
def farm_time(m_tasks: int, t_task: float, nw: int,
              t_emit: float = 0.0, t_collect: float = 0.0) -> float:
    """Completion time of m tasks on an nw-worker farm: workers process in
    parallel, but the emitter/collector are serial stages — the farm's
    service time is max(t_emit, t_task/nw, t_collect)."""
    service = max(t_emit, t_task / nw, t_collect)
    return m_tasks * service + t_task  # + one task latency (paper: latency
    # of a single task does not decrease)


def farm_speedup(m_tasks: int, t_task: float, nw: int,
                 t_emit: float = 0.0, t_collect: float = 0.0) -> float:
    return (m_tasks * t_task) / farm_time(m_tasks, t_task, nw, t_emit, t_collect)


def pipeline_service_time(stage_times: Sequence[float]) -> float:
    return max(stage_times)


def pipeline_time(m_tasks: int, stage_times: Sequence[float]) -> float:
    """m x T_S plus the fill latency sum(T_Si)."""
    return m_tasks * pipeline_service_time(stage_times) + sum(stage_times)


def pipeline_speedup(stage_times: Sequence[float], m_tasks: int = 10**9) -> float:
    """-> sum T_Si / max T_Si for long streams (paper's formula)."""
    seq = sum(stage_times)
    return (m_tasks * seq) / pipeline_time(m_tasks, stage_times) * (1.0)


def amdahl(serial_fraction: float, n: int) -> float:
    return 1.0 / (serial_fraction + (1.0 - serial_fraction) / n)


def choose_farm_width(t_task: float, n_max: int, t_emit: float = 0.0,
                      t_collect: float = 0.0,
                      overhead: float = 2e-5) -> int:
    """Smallest worker count whose per-item service time hits the farm's
    serial floor: service = max(t_emit, t_task/nw, t_collect), so adding
    workers beyond t_task/floor buys nothing (paper Sec. 13).  ``overhead``
    is the channel's own service time (queue push/pop) — the floor even for
    a free emitter.  Used by the graph compiler's ``place`` stage."""
    floor = max(t_emit, t_collect, overhead, 1e-9)
    w = math.ceil(t_task / floor)
    return max(1, min(w, max(1, n_max)))


def pipeline_bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble: (S-1)/(M+S-1) — the fill/drain idle fraction of the
    device pipeline skeleton."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def choose_microbatches(n_stages: int, max_bubble: float = 0.1,
                        max_micro: int = 256) -> int:
    """Smallest M with bubble fraction <= max_bubble."""
    m = math.ceil((n_stages - 1) * (1.0 - max_bubble) / max_bubble)
    return max(1, min(m, max_micro))


# --------------------------------------------------------------------------
# TPU v5e roofline (target hardware; this container only dry-runs)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float   # per chip, FLOP/s
    hbm_bw: float            # per chip, B/s
    ici_bw: float            # per link, B/s
    dci_bw: float            # per pod-to-pod link share, B/s
    hbm_bytes: float


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    dci_bw=6.25e9,   # conservative DCI share per chip
    hbm_bytes=16 * 2**30,
)


@dataclasses.dataclass
class RooflineTerms:
    """The three terms, in seconds, per step, per chip (the prompt's
    definitions: totals divided by (chips x peak))."""
    compute_s: float
    memory_s: float
    collective_s: float
    # breakdown
    flops_total: float = 0.0
    bytes_total: float = 0.0
    coll_bytes_ici: float = 0.0
    coll_bytes_dci: float = 0.0
    model_flops: float = 0.0
    model_flops_s: float = 0.0   # time to run MODEL_FLOPS at peak

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (perfect-overlap) step time = max of terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute fraction: MODEL_FLOPS-at-peak time / step-time."""
        if self.step_time_s == 0 or not self.model_flops:
            return 0.0
        return self.model_flops_s / self.step_time_s


def roofline(flops_total: float, bytes_total: float,
             coll_bytes_ici_per_chip: float, n_chips: int,
             hw: HardwareSpec = TPU_V5E,
             coll_bytes_dci_per_chip: float = 0.0,
             model_flops: float = 0.0) -> RooflineTerms:
    """flops_total/bytes_total are fleet totals (sum over chips); collective
    bytes are per-chip link traffic (ring-model)."""
    compute_s = flops_total / (n_chips * hw.peak_flops_bf16)
    memory_s = bytes_total / (n_chips * hw.hbm_bw)
    collective_s = (coll_bytes_ici_per_chip / hw.ici_bw
                    + coll_bytes_dci_per_chip / hw.dci_bw)
    return RooflineTerms(
        compute_s, memory_s, collective_s,
        flops_total=flops_total, bytes_total=bytes_total,
        coll_bytes_ici=coll_bytes_ici_per_chip,
        coll_bytes_dci=coll_bytes_dci_per_chip,
        model_flops=model_flops,
        model_flops_s=model_flops / (n_chips * hw.peak_flops_bf16))


# ring-model per-chip traffic for each collective kind -----------------------
def collective_link_bytes(kind: str, operand_bytes: float, group_size: int) -> float:
    """Per-chip bytes that traverse links for one collective, ring algorithm.
    ``operand_bytes`` is the per-device operand (post-SPMD HLO shapes are
    already per-device)."""
    n = max(group_size, 1)
    if n == 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * operand_bytes * (n - 1) / n
    if kind in ("all-gather",):
        # operand is the local shard; each chip receives (n-1) shards
        return operand_bytes * (n - 1)
    if kind in ("reduce-scatter",):
        return operand_bytes * (n - 1) / n
    if kind in ("all-to-all",):
        return operand_bytes * (n - 1) / n
    if kind in ("collective-permute", "collective-permute-start"):
        return operand_bytes
    return operand_bytes
