"""L1/L2 — streaming-network channels (FastFlow Sec. 2, layers 1-2).

FastFlow's first layer is a lock-free SPSC ring buffer on shared memory; its
second layer composes SPMC/MPSC/MPMC networks out of SPSC queues.  On the host
side of this framework the same structure carries data-pipeline batches and
serving requests.  This module is the *thread-tier* instance: CPython's GIL
makes single-word index updates atomic, so the ring below is wait-free in the
same sense as FastFlow's — the producer only writes ``_tail``, the consumer
only writes ``_head``, and neither takes a lock on the fast path.

The host tier has three backends, all carrying the same channel structure:

- **threads** (this module): cheapest hop; real parallelism only for stages
  that release the GIL (I/O, large BLAS calls, jitted device dispatch);
- **processes** (``core/shm.py``): the same fixed-slot SPSC ring laid out in
  ``multiprocessing.shared_memory`` — FastFlow's actual multicore story —
  so CPU-bound Python/numpy stages scale with cores; the staged compiler's
  ``place`` pass picks it from a measured GIL-sensitivity signal and
  startup-calibrated hop costs (``perf_model.calibrate``);
- **device** (``core/device.py``, ``kernels/``): collective_permute ring
  edges and Pallas double-buffered VMEM tiles, the mesh-side analogue.
"""

from __future__ import annotations

import threading
import time
from typing import Any, List, Optional, Sequence


class QueueClosed(Exception):
    """Raised when pushing to / popping from a closed-and-drained queue."""


class SPSCQueue:
    """Bounded single-producer single-consumer ring buffer.

    Wait-free push/pop (no locks on the fast path); ``push``/``pop`` offer
    blocking convenience wrappers with exponential backoff, mirroring
    FastFlow's ``ff_send_out(task, retry, ticks)`` semantics.
    """

    def __init__(self, capacity: int = 512):
        if capacity < 2:
            raise ValueError("capacity must be >= 2")
        self._cap = capacity
        self._buf: List[Any] = [None] * capacity
        self._head = 0  # consumer-owned
        self._tail = 0  # producer-owned
        self._closed = False
        self.max_depth = 0              # producer-side high-water mark

    # -- non-blocking primitives (the lock-free layer) ----------------------
    def try_push(self, item: Any) -> bool:
        nxt = (self._tail + 1) % self._cap
        if nxt == self._head:           # full
            return False
        self._buf[self._tail] = item
        self._tail = nxt                # single atomic publish
        depth = (nxt - self._head) % self._cap
        if depth > self.max_depth:
            self.max_depth = depth
        return True

    def try_pop(self) -> tuple[bool, Any]:
        if self._head == self._tail:    # empty
            return False, None
        item = self._buf[self._head]
        self._buf[self._head] = None
        self._head = (self._head + 1) % self._cap
        return True, item

    def __len__(self) -> int:
        return (self._tail - self._head) % self._cap

    @property
    def capacity(self) -> int:
        return self._cap - 1

    def empty(self) -> bool:
        return self._head == self._tail

    # -- blocking wrappers ---------------------------------------------------
    def push(self, item: Any, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            # closed first: a closed queue refuses new items even when slots
            # remain (the stream is ended; accepting would strand the item)
            if self._closed:
                raise QueueClosed("push to closed queue")
            if self.try_push(item):
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("SPSC push timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def pop(self, timeout: Optional[float] = None) -> Any:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            ok, item = self.try_pop()
            if ok:
                return item
            if self._closed:
                raise QueueClosed("pop from closed empty queue")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("SPSC pop timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def close(self) -> None:
        self._closed = True

    @property
    def closed(self) -> bool:
        return self._closed

    def drained(self) -> bool:
        """Closed with nothing left to pop."""
        return self._closed and self._head == self._tail


class SPMCQueue:
    """Single producer, multiple consumers: one SPSC lane per consumer.

    The producer selects the destination lane; the default policy is
    round-robin (FastFlow's default farm scheduling).  ``select`` may be
    overridden by a load balancer (see core/skeletons.py).
    """

    def __init__(self, n_consumers: int, capacity: int = 512):
        self.lanes = [SPSCQueue(capacity) for _ in range(n_consumers)]
        self._rr = 0

    def push_to(self, idx: int, item: Any, timeout: Optional[float] = None) -> None:
        self.lanes[idx].push(item, timeout)

    def push_rr(self, item: Any, timeout: Optional[float] = None) -> int:
        idx = self._rr
        self.lanes[idx].push(item, timeout)
        self._rr = (self._rr + 1) % len(self.lanes)
        return idx

    def push_ondemand(self, item: Any, threshold: int = 1,
                      timeout: Optional[float] = None) -> int:
        """FastFlow Sec. 8.3.2: deliver to the first lane with <= threshold
        queued items; BLOCK until a lane qualifies (the emitter waits for a
        worker to 'ask' — auto-scheduling)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            for i, lane in enumerate(self.lanes):
                if len(lane) <= threshold and lane.try_push(item):
                    return i
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("SPMC on-demand push timed out")
            time.sleep(1e-5)

    def broadcast(self, item: Any, timeout: Optional[float] = None) -> None:
        for lane in self.lanes:
            lane.push(item, timeout)

    def close_all(self) -> None:
        """Close every lane: consumers drain what is queued, then their
        ``pop`` raises :class:`QueueClosed`; further pushes are refused."""
        for lane in self.lanes:
            lane.close()


class MPSCQueue:
    """Multiple producers, single consumer: one SPSC lane per producer; the
    consumer drains lanes fairly (FastFlow collector gathering policy)."""

    def __init__(self, n_producers: int, capacity: int = 512):
        self.lanes = [SPSCQueue(capacity) for _ in range(n_producers)]
        self._next = 0

    def lane(self, idx: int) -> SPSCQueue:
        return self.lanes[idx]

    def try_pop_any(self) -> tuple[bool, Any, int]:
        n = len(self.lanes)
        for off in range(n):
            i = (self._next + off) % n
            ok, item = self.lanes[i].try_pop()
            if ok:
                self._next = (i + 1) % n
                return True, item, i
        return False, None, -1

    def pop_any(self, timeout: Optional[float] = None) -> tuple[Any, int]:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            ok, item, i = self.try_pop_any()
            if ok:
                return item, i
            if all(lane.drained() for lane in self.lanes):
                raise QueueClosed("pop from closed and drained MPSC network")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("MPSC pop timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def close_all(self) -> None:
        """Close every producer lane; once drained, ``pop_any`` raises
        :class:`QueueClosed` instead of spinning to ``TimeoutError``."""
        for lane in self.lanes:
            lane.close()


class MPMCQueue:
    """Multiple producers, multiple consumers, composed of SPSC lanes
    (producer i -> consumer j), as in FastFlow layer 2.  Device-side this is
    the all-to-all used by the MoE farm."""

    def __init__(self, n_producers: int, n_consumers: int, capacity: int = 128):
        self.grid = [[SPSCQueue(capacity) for _ in range(n_consumers)]
                     for _ in range(n_producers)]
        self._next = [0] * n_consumers

    def push(self, producer: int, consumer: int, item: Any,
             timeout: Optional[float] = None) -> None:
        self.grid[producer][consumer].push(item, timeout)

    def pop(self, consumer: int, timeout: Optional[float] = None) -> tuple[Any, int]:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        n_prod = len(self.grid)
        while True:
            for off in range(n_prod):
                i = (self._next[consumer] + off) % n_prod
                ok, item = self.grid[i][consumer].try_pop()
                if ok:
                    self._next[consumer] = (i + 1) % n_prod
                    return item, i
            if all(row[consumer].drained() for row in self.grid):
                raise QueueClosed(
                    "pop from closed and drained MPMC column")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("MPMC pop timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def close_all(self) -> None:
        """Close every lane in the grid; a consumer whose column is closed
        and drained gets :class:`QueueClosed` from ``pop`` instead of
        spinning to ``TimeoutError``."""
        for row in self.grid:
            for lane in row:
                lane.close()
