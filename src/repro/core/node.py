"""L3 building block — the ``ff_node`` sequential-concurrent-activity
abstraction (FastFlow Secs. 4-6).

A node wraps business-logic into ``svc`` (called once per input stream item),
with ``svc_init``/``svc_end`` lifecycle hooks.  Returning:

- an object  -> delivered onto the node's output stream;
- ``GO_ON``  -> no output, keep the node alive;
- ``EOS``    -> terminate this node; end-of-stream propagates downstream
                (FastFlow returns NULL; we use an explicit sentinel).

``ff_send_out`` delivers extra items mid-``svc`` (Sec. 5).  Each node runs on
its own thread; streams are the SPSC queues of core/queues.py.
"""

from __future__ import annotations

import threading
import time
import traceback
from typing import Any, Callable, Optional

from .queues import SPSCQueue


class _Sentinel:
    def __init__(self, name: str):
        self._name = name

    def __repr__(self):
        return self._name


GO_ON = _Sentinel("GO_ON")
EOS = _Sentinel("EOS")            # FastFlow: returning NULL / FF_EOS mark
_NO_INPUT = _Sentinel("NO_INPUT")  # activation token for source nodes

# service-time EMA warm-up: the EMA seeds from the *median* of this many
# initial samples instead of the first one alone — a slow first call (jit
# trace, cold cache, page faults) would otherwise poison the estimate for
# ~20 items, and the adaptive supervisor acts on these estimates
_SVC_WARMUP_N = 5
_SVC_EMA_ALPHA = 0.2


def spawn_drainer(pop: Callable[[], Any], n_eos: int = 1) -> None:
    """A node that exits before consuming its input's end-of-stream — by
    error or by voluntarily returning EOS/None — must never wedge upstream
    producers on its full queue.  Hand the stream to a detached daemon
    drainer (discarding items until ``n_eos`` EOS marks arrive) so the
    node's own thread stays joinable even when the terminating EOS never
    arrives.  ``pop`` abstracts the channel: an SPSC pop, an MPSC pop_any,
    or an MPMC column pop."""
    def drain() -> None:
        try:
            n = n_eos
            while n > 0:
                if pop() is EOS:
                    n -= 1
        except BaseException:   # noqa: BLE001 - queue closed etc.
            pass
    threading.Thread(target=drain, daemon=True, name="ff-drain").start()


def _drain_until_eos(in_q: "SPSCQueue") -> None:
    spawn_drainer(in_q.pop)


class FFNode:
    """Subclass and override ``svc`` (mandatory), ``svc_init``/``svc_end``
    (optional), exactly as in the paper."""

    def __init__(self):
        self._out: Optional[Callable[[Any], None]] = None
        self._id: int = -1
        self.thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None
        self.svc_calls: int = 0   # for stats (ffStats analogue)
        self.svc_time_ema: float = 0.0   # EMA of svc() service time, seconds
        # counters above are mutated by the node's worker thread and read by
        # stats()/the adaptive supervisor mid-stream: updates and snapshots
        # both go through this lock so readers see a consistent pair
        self._stats_lock = threading.Lock()
        self._svc_warmup: list = []
        # When this node has an input stream but must generate initial tasks
        # itself (divide&conquer emitters on a feedback loop), set
        # ``prime = True``: svc(None) is called once before consuming input.
        self.prime: bool = False

    # -- user API ------------------------------------------------------------
    def svc(self, task: Any) -> Any:  # pragma: no cover - abstract
        raise NotImplementedError

    def svc_init(self) -> int:
        return 0

    def svc_end(self) -> None:
        pass

    def get_my_id(self) -> int:
        """Paper Sec. 14 run-time routine."""
        return self._id

    def ff_send_out(self, task: Any) -> None:
        if self._out is None:
            raise RuntimeError("ff_send_out outside a running streaming network")
        self._out(task)

    # -- runtime -------------------------------------------------------------
    def _bind(self, out_fn: Callable[[Any], None], node_id: int) -> None:
        self._out = out_fn
        self._id = node_id

    def _run_loop(self, in_q: Optional[SPSCQueue]) -> None:
        """Thread body: pull from input stream (if any), call svc, route
        output.  End-of-stream handling follows the paper: EOS on the input
        stream terminates the node (svc not called) and propagates."""
        input_eos = in_q is None      # source nodes have no stream to drain
        try:
            if self.svc_init() < 0:
                raise RuntimeError(f"svc_init failed in {type(self).__name__}")
            primed = (in_q is None) or not self.prime
            while True:
                if in_q is None:
                    task = _NO_INPUT
                elif not primed:
                    task, primed = _NO_INPUT, True
                else:
                    task = in_q.pop()
                    if task is EOS:
                        input_eos = True
                        break
                with self._stats_lock:
                    self.svc_calls += 1
                t0 = time.perf_counter()
                result = self.svc(None if task is _NO_INPUT else task)
                self._record_svc_time(time.perf_counter() - t0)
                if result is None:   # paper: returning NULL terminates the node
                    result = EOS
                if result is EOS:
                    break
                if result is not GO_ON:
                    self._out(result)
        except BaseException as e:  # noqa: BLE001 - surfaced to the runner
            self.error = e
            traceback.print_exc()
        finally:
            try:
                self.svc_end()
            finally:
                if self._out is not None:
                    self._out(EOS)
                if not input_eos:
                    _drain_until_eos(in_q)

    def _start(self, in_q: Optional[SPSCQueue]) -> None:
        self.thread = threading.Thread(
            target=self._run_loop, args=(in_q,), daemon=True,
            name=f"ffnode-{type(self).__name__}-{self._id}")
        self.thread.start()

    def _join(self, timeout: Optional[float] = None) -> None:
        if self.thread is not None:
            self.thread.join(timeout)

    def _alive(self) -> bool:
        return self.thread is not None and self.thread.is_alive()

    def _record_svc_time(self, dt: float) -> None:
        """Fold one measured ``svc`` duration into ``svc_time_ema``.  The
        first ``_SVC_WARMUP_N`` samples seed the EMA with their running
        median, so one slow warm-up call cannot poison the estimate."""
        with self._stats_lock:
            if len(self._svc_warmup) < _SVC_WARMUP_N:
                self._svc_warmup.append(dt)
                self.svc_time_ema = \
                    sorted(self._svc_warmup)[len(self._svc_warmup) // 2]
            else:
                self.svc_time_ema = ((1.0 - _SVC_EMA_ALPHA) * self.svc_time_ema
                                     + _SVC_EMA_ALPHA * dt)

    def node_stats(self) -> dict:
        """Per-node runtime stats for ``runner.stats()``: items processed and
        the service-time EMA (seconds).  Snapshot under the stats lock so a
        mid-stream reader never sees a torn calls/EMA pair."""
        with self._stats_lock:
            return {"node": type(self).__name__, "items": self.svc_calls,
                    "svc_time_ema_s": self.svc_time_ema}


class FnNode(FFNode):
    """Convenience: lift a plain callable into an ff_node."""

    def __init__(self, fn: Callable[[Any], Any]):
        super().__init__()
        self._fn = fn

    def svc(self, task: Any) -> Any:
        return self._fn(task)

    def node_stats(self) -> dict:
        s = super().node_stats()
        s["node"] = getattr(self._fn, "__name__", "FnNode")
        return s
