"""The staged graph compiler: ``normalize -> annotate -> place -> emit``.

``FFGraph.lower(plan)`` used to be an all-or-nothing switch — the whole graph
on host threads or the whole graph on the JAX mesh.  This module turns
lowering into an explicit compile pipeline, the way the FastFlow runtime
layers arbitrary networks over its core channels:

1. **normalize** — the :meth:`FFGraph.optimize` normal-form rewrites
   (pipeline flattening, collector–emitter collapse, farm/pipeline fusion);
2. **annotate** — attach a :class:`CostEstimate` to every IR node from the
   paper's Sec. 13 algebra in ``core/perf_model.py``: per-item host time from
   ``costs=``, ``ff_cost``/``ff_flops``/``ff_bytes`` attributes on the
   worker, or by timing the node on a ``sample`` item; device time from the
   TPU roofline when FLOPs are declared.  With a ``sample``, annotate also
   measures a *GIL-sensitivity* signal (the node timed solo vs. under two
   concurrent threads) unless the worker declares ``ff_releases_gil``;
3. **place** — assign each top-level stage a :class:`Placement` across the
   four-tier host side plus the mesh: host *thread* vs. host *process* vs.
   host *remote* (``host_remote``, a worker pool on other hosts reached
   over the TCP lanes of ``core/net.py`` — unlocked by
   ``compile(remote_workers=[...])``) vs. *device*.  Thread-vs-process-vs-
   remote comes from the GIL signal and the startup-calibrated hop costs
   (``perf_model.calibrate`` replaces the baked-in constants with measured
   ones, including the loopback-measured network hop); host-vs-device from
   the roofline comparison; farm widths from
   :func:`~repro.core.perf_model.choose_farm_width`; all overridable per
   node;
4. **emit** — build the runner: all-host -> :class:`~repro.core.graph.
   HostRunner`; all-device -> :class:`~repro.core.graph.DeviceRunner`;
   process-placed farm stages become :class:`~repro.core.process.
   ProcessFarmNode` boundary nodes (OS-process workers over the
   shared-memory SPSC rings of ``core/shm.py``; ``autoscale`` farms carry
   an AutoscaleLB over the shm lanes) and process-placed ``all_to_all``
   stages become :class:`~repro.core.process.ProcessA2ANode` (left/right
   worker processes over an ``ShmMPMCGrid`` lane grid, router in the left
   children, sequence-ordered collection) inside a
   :class:`ProcessRunner`; remote-placed farm stages become
   :class:`~repro.core.net.RemoteFarmNode` boundary nodes (workers on
   other hosts over credit-windowed TCP lanes, sequence-ordered, crash-
   surfacing, cluster-autoscaling) inside a :class:`RemoteRunner`; mixed
   host/device -> :class:`HybridRunner`, host
   stages over SPSC queues feeding device segments on the mesh through
   device-put boundary nodes (:class:`_DeviceStageNode` stacks a microbatch,
   ``device_put``s it with the data-axis sharding, runs the jitted segment,
   and streams the unstacked results downstream).  Thread -> process ->
   device programs compose: a process farm is just one more host stage to
   the hybrid runner.

``emit`` also closes the two device lowerings the monolithic ``lower()``
lacked: ``all_to_all`` becomes MoE-style dispatch/combine
(``core.device.a2a_dispatch``, reusing ``kernels/router_topk.py`` +
``expert_capacity``), and ``wrap_around`` lowers through
``core.device.feedback_scan`` when ``feedback_steps`` is given.
"""

from __future__ import annotations

import dataclasses
import os
import pickle
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from . import perf_model as pm
from .fuse import FusedSegment, fuse_device_segments, segment_key
from .graph import (A2AG, DeviceRunner, FarmG, FFGraph, GraphError,
                    HostRunner, MapG, PipeG, SeqG, StageHandle, _device_fn,
                    _is_pure_seq, _pure_of)
from .node import GO_ON, FFNode
from .process import ProcessA2ANode, ProcessFarmNode, fn_picklable

# Baked-in cost-model fallbacks.  ``perf_model.calibrate()`` measures the
# real values on this machine at startup (cached on disk); auto placement
# consumes the calibration, these constants only back annotate/place before
# any calibration exists (see perf_model.DEFAULT_CALIBRATION, kept in sync).
HOST_PEAK_FLOPS = 5e10
HOST_QUEUE_OVERHEAD_S = 2e-5
DEVICE_DISPATCH_S = 2e-5
DEFAULT_T_TASK_S = 5e-5

_TARGETS = ("host", "host_process", "host_remote", "device")


@dataclasses.dataclass
class CompileConfig:
    """Every compile-time knob of the staged pipeline in one value.

    ``FFGraph.compile(config=CompileConfig(...))`` is the supported spelling;
    the old flat kwargs (``compile(plan, mode=..., capacity=...)``) remain as
    a deprecated shim that builds this dataclass and warns once per call.
    Field semantics are unchanged from the old kwargs — see
    :func:`compile_graph` for the full story per knob.  The one new field is
    ``feedback_cond``: a per-item predicate ``cond(state) -> bool`` that lets
    a ``wrap_around`` graph terminate data-dependently — on host the runner
    evaluates it on every item coming off the feedback edge (deliver when
    false), on device the loop lowers through
    :func:`~repro.core.device.feedback_while` (``jax.lax.while_loop``)
    instead of the fixed-turn ``feedback_scan``; ``feedback_steps`` then acts
    as an optional safety cap on the turn count.

    The overlapped device boundary (three knobs).  ``overlap=True`` (the
    default) makes every :class:`_DeviceStageNode` software-pipeline its
    microbatches through a depth-K in-flight window: the jitted segment for
    microbatch *i* is dispatched asynchronously (JAX async dispatch — no
    per-batch ``block_until_ready``) and its device->host copy-out is only
    awaited once *K-1* newer microbatches have been dispatched behind it, so
    host stacking + ``device_put`` of microbatch *i+1* and the copy-out of
    *i-1* ride under the compute of *i*.  ``overlap=False`` restores the
    strictly synchronous put -> compute -> copy-out boundary (A/B
    benchmarks, parity tests); results are byte-identical either way — only
    the synchronization point moves.  ``microbatch=`` overrides the
    boundary's stacking depth (default: ``device_batch`` heuristic, 8x the
    mesh axis), ``inflight=`` the window depth K (default: the roofline
    autotuner's ``device_overlap:window`` sweep winner, else 2).  Feedback
    (``wrap_around``) graphs always compile the synchronous boundary: items
    circulate one at a time, and a window holding results back would
    deadlock the loop."""

    plan: Any = None
    mode: str = "auto"
    costs: Optional[Dict] = None
    sample: Any = None
    placements: Optional[Dict] = None
    capacity: int = 512
    results_capacity: int = 4096
    axis: str = "data"
    feedback_steps: Optional[int] = None
    feedback_cond: Optional[Callable] = None
    device_batch: Optional[int] = None
    a2a_capacity_factor: Optional[float] = None
    normalize: bool = True
    shm_slot_bytes: int = 1 << 16
    adaptive: bool = False
    remote_workers: Optional[Sequence] = None
    net_credit: int = 32
    transport: Any = None
    fuse: bool = True
    overlap: bool = True
    microbatch: Optional[int] = None
    inflight: Optional[int] = None


@dataclasses.dataclass
class CostEstimate:
    """Per-node cost, in host-seconds per item plus declared work terms.

    ``releases_gil`` is the GIL-sensitivity signal: ``True`` when the node's
    work runs concurrently under CPython threads (I/O, large BLAS, device
    dispatch), ``False`` when it serializes on the GIL (the process tier's
    reason to exist), ``None`` when undeclared and unmeasured."""

    t_task: float = DEFAULT_T_TASK_S
    flops: float = 0.0
    bytes: float = 0.0
    source: str = "default"  # default | declared | given | observed | measured | derived
    releases_gil: Optional[bool] = None

    def host_time(self, width: int = 1) -> float:
        """Per-item service time on a ``width``-worker *thread* farm.  A
        GIL-bound task gains nothing from extra threads."""
        if self.releases_gil is False:
            return self.t_task
        return self.t_task / max(1, width)

    def process_time(self, width: int = 1, hop_s: float = 2e-4) -> float:
        """Per-item service time on a ``width``-worker *process* farm: true
        parallelism, floored by the shared-memory lane hop."""
        return max(self.t_task / max(1, width), hop_s)

    def remote_time(self, width: int = 1, hop_s: float = 5e-4) -> float:
        """Per-item service time on a ``width``-worker *remote* farm: true
        parallelism across hosts, floored by the network-lane hop."""
        return max(self.t_task / max(1, width), hop_s)

    def device_time(self, n_chips: int = 1,
                    dispatch_s: float = DEVICE_DISPATCH_S) -> Optional[float]:
        """Roofline per-item time on the mesh, or None when no work terms
        are declared (an unmeasurable node never wins a device slot)."""
        if self.flops <= 0:
            return None
        terms = pm.roofline(self.flops, self.bytes, 0.0, max(1, n_chips))
        return terms.step_time_s + dispatch_s


@dataclasses.dataclass
class Placement:
    """Where one top-level stage runs.  ``width`` is the farm worker count
    (threads, processes, or the mesh axis size); ``reason`` records the
    cost-model comparison for reports/tests."""

    target: str = "host"    # "host" | "host_process" | "host_remote" | "device"
    width: Optional[int] = None
    reason: str = ""


def _as_placement(v: Any) -> Placement:
    if isinstance(v, Placement):
        if v.target not in _TARGETS:
            raise GraphError(f"Placement target must be one of {_TARGETS} "
                             f"(got {v.target!r})")
        return v
    if v in _TARGETS:
        return Placement(target=v, reason="override")
    raise GraphError(f"placement override must be one of {_TARGETS} or a "
                     f"Placement (got {v!r})")


# ---------------------------------------------------------------------------
# Stage 2: annotate
# ---------------------------------------------------------------------------
def _measure(fn: Callable, sample: Any, repeat: int = 3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(sample)
        best = min(best, time.perf_counter() - t0)
    return max(best, 1e-9)


def _probe_gil_release(fn: Callable, sample: Any,
                       solo: float) -> Optional[bool]:
    """Does ``fn`` run concurrently under CPython threads?  Time it under
    two concurrent threads: a GIL-bound task's per-call time stays ~solo
    (the threads serialize), a GIL-releasing one drops toward solo/2.
    Returns None when the task is too fast (noise) or too slow (probe cost)
    to measure."""
    import threading
    if solo < 1e-4 or solo > 0.25 or (os.cpu_count() or 1) < 2:
        return None
    k = max(2, min(16, int(2e-3 / solo) + 1))

    def loop() -> None:
        for _ in range(k):
            fn(sample)

    threads = [threading.Thread(target=loop) for _ in range(2)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    per_call = (time.perf_counter() - t0) / (2 * k)
    return per_call < 0.75 * solo


def _estimate(key: Any, costs: Dict, sample: Any) -> CostEstimate:
    """Cost for one worker object: explicit ``costs=`` entry > declared
    ``ff_cost``/``ff_flops`` attributes > timing on ``sample`` > default.
    The GIL signal comes from a declared ``ff_releases_gil`` attribute, or —
    when the node was timed on a sample anyway — from the two-thread
    concurrency probe."""
    if key is not None:
        rg = getattr(key, "ff_releases_gil", None)
        if rg is not None:
            rg = bool(rg)
        try:
            given = costs.get(key)
        except TypeError:           # unhashable worker object
            given = None
        if given is not None:
            if isinstance(given, CostEstimate):
                return given
            return CostEstimate(t_task=float(given), source="given",
                                releases_gil=rg)
        fl = float(getattr(key, "ff_flops", 0.0) or 0.0)
        by = float(getattr(key, "ff_bytes", 0.0) or 0.0)
        t = getattr(key, "ff_cost", None)
        if t is not None:
            return CostEstimate(float(t), fl, by, "declared",
                                releases_gil=rg)
        if fl > 0.0:
            peak = pm.get_calibration(measure=False).peak_flops
            return CostEstimate(fl / peak, fl, by, "declared",
                                releases_gil=rg)
        if callable(key):
            # runtime history beats a fresh sample probe: the adaptive
            # supervisor's perf_model.observe() feeds measured service
            # times + GIL signals back per callable, so re-compiling a
            # previously-run worker needs no sample= at all
            obs = pm.lookup_observed(pm.fn_key(key))
            if obs is not None:
                org = rg if rg is not None else obs.get("releases_gil")
                return CostEstimate(float(obs["t_task"]), source="observed",
                                    releases_gil=org)
        if sample is not None and callable(key):
            try:
                solo = _measure(key, sample)
                if rg is None:
                    rg = _probe_gil_release(key, sample, solo)
                return CostEstimate(solo, source="measured", releases_gil=rg)
            except Exception:       # noqa: BLE001 - sample may not fit the fn
                pass
        if rg is not None:
            return CostEstimate(source="default", releases_gil=rg)
    return CostEstimate()


def annotate(graph: FFGraph, costs: Optional[Dict] = None,
             sample: Any = None) -> FFGraph:
    """Attach a :class:`CostEstimate` to every IR node (in place).

    Leaf costs come from :func:`_estimate`; composites follow the paper's
    algebra — a pipeline worker's per-item time is the sum of its stages, a
    farm node carries its *worker's* per-item time (the farm service time is
    width-dependent and belongs to ``place``)."""
    costs = costs or {}
    memo: Dict[int, CostEstimate] = {}    # replicated workers share one fn

    def merge_gil(subs: List[CostEstimate]) -> Optional[bool]:
        gs = [c.releases_gil for c in subs]
        if any(g is False for g in gs):
            return False
        if gs and all(g is True for g in gs):
            return True
        return None

    def est(key: Any, smp: Any) -> CostEstimate:
        k = id(key)
        if k not in memo:
            memo[k] = _estimate(key, costs, smp)
        return memo[k]

    def visit(n: Any) -> CostEstimate:
        if isinstance(n, SeqG):
            n.cost = est(n.node, sample if n.pure else None)
        elif isinstance(n, PipeG):
            subs = [visit(s) for s in n.stages]
            n.cost = CostEstimate(t_task=sum(c.t_task for c in subs),
                                  flops=sum(c.flops for c in subs),
                                  bytes=sum(c.bytes for c in subs),
                                  source="derived",
                                  releases_gil=merge_gil(subs))
        elif isinstance(n, FarmG):
            subs = [visit(w) for w in n.workers]
            key = n.fn if n.fn is not None else None
            c = est(key, sample) if key is not None else subs[0]
            if c.source == "default" and subs[0].source != "default":
                c = subs[0]
            for part in (n.emitter, n.collector):
                if part is not None:
                    visit(part)
            n.cost = c
        elif isinstance(n, A2AG):
            ls = [visit(x) for x in n.left]
            rs = [visit(x) for x in n.right]
            n.cost = CostEstimate(
                t_task=(sum(c.t_task for c in ls) / len(ls)
                        + sum(c.t_task for c in rs) / len(rs)),
                flops=sum(c.flops for c in (*ls, *rs)),
                bytes=sum(c.bytes for c in (*ls, *rs)),
                source="derived", releases_gil=merge_gil([*ls, *rs]))
        elif isinstance(n, MapG):
            for x in (n.splitter, *n.workers, n.composer):
                visit(x)
            n.cost = CostEstimate(source="default")
        else:
            return CostEstimate()
        return n.cost

    visit(graph.root)
    return graph


# ---------------------------------------------------------------------------
# Stage 3: place
# ---------------------------------------------------------------------------
def _top_stages(graph: FFGraph) -> List[Any]:
    return list(graph.root.stages) if isinstance(graph.root, PipeG) \
        else [graph.root]


def _device_eligible(n: Any) -> bool:
    """Can this stage lower onto the mesh at all?"""
    if isinstance(n, A2AG):
        return all(_is_pure_seq(x) for x in (*n.left, *n.right))
    try:
        _device_fn(n)
        return True
    except GraphError:
        return False


def _process_ineligible_reason(n: Any) -> Optional[str]:
    """Why this stage cannot run on the process tier (None when it can).

    The process tier ships each worker's ``svc`` callable to a child once at
    startup, so it needs pure (stateless-callable) workers: a farm with
    pure-or-absent emitter/collector and the default round-robin schedule
    (``autoscale`` is fine — the process farm carries its own AutoscaleLB
    over the shm lanes), or an ``all_to_all`` whose left/right workers and
    router all pickle."""
    if isinstance(n, A2AG):
        fns = [_pure_of(x) for x in (*n.left, *n.right)]
        if any(f is None for f in fns):
            return "a2a workers must be pure callables to ship to a process"
        if not all(fn_picklable(f) for f in fns):
            return "a2a worker callable is not picklable for process startup"
        if n.router is not None and not fn_picklable(n.router):
            return "a2a router is not picklable for process startup"
        return None
    if not isinstance(n, FarmG):
        return "only farm and all_to_all stages process-lower"
    if n.lb is not None or n.ondemand is not None:
        return "custom lb/ondemand schedules are thread-tier only"
    fns = [n.fn] if n.fn is not None else [_pure_of(w) for w in n.workers]
    if any(f is None for f in fns):
        return "stateful workers cannot ship to a worker process"
    for part in (n.emitter, n.collector):
        if part is not None and _pure_of(part) is None:
            return "process farm needs pure emitter/collector"
    if not all(fn_picklable(f) for f in fns):
        return "worker callable is not picklable for process startup"
    return None


def _net_picklable(fn: Callable) -> bool:
    # the remote tier ships the callable over TCP (tag FN), so it must
    # pickle *by value or importable reference* for real — the fork-based
    # leniency of fn_picklable() does not cross a host boundary
    try:
        pickle.dumps(fn)
        return True
    except Exception:   # noqa: BLE001 - closures, lambdas, local defs
        return False


def _remote_ineligible_reason(n: Any,
                              pool: Optional[Sequence]) -> Optional[str]:
    """Why this stage cannot run on the remote tier (None when it can).

    The remote tier ships each worker's ``svc`` callable over a network lane
    (tag ``FN``) to a worker pool from ``compile(remote_workers=[...])``, so
    beyond the process tier's purity requirements the callable must
    genuinely pickle (fork cannot carry a closure across hosts) and a pool
    must exist to connect to.  Farms only — the a2a grid stays on-box."""
    if not isinstance(n, FarmG):
        return "only farm stages remote-lower"
    if not pool:
        return "no remote worker pool (pass compile(remote_workers=[...]))"
    if n.lb is not None or n.ondemand is not None:
        return "custom lb/ondemand schedules are thread-tier only"
    fns = [n.fn] if n.fn is not None else [_pure_of(w) for w in n.workers]
    if any(f is None for f in fns):
        return "stateful workers cannot ship to a remote worker"
    for part in (n.emitter, n.collector):
        if part is not None and _pure_of(part) is None:
            return "remote farm needs pure emitter/collector"
    if not all(_net_picklable(f) for f in fns):
        return "worker callable does not pickle for the network handshake"
    return None


def _mesh_axis_size(plan: Any, axis: str) -> int:
    return int(dict(plan.mesh.shape).get(axis, 1))


def place(graph: FFGraph, plan: Any = None, overrides: Optional[Dict] = None,
          axis: str = "data", feedback_steps: Optional[int] = None,
          feedback_cond: Optional[Callable] = None, mode: str = "auto",
          remote_pool: Optional[Sequence] = None) -> FFGraph:
    """Assign each top-level stage a :class:`Placement` (in place).

    Targets span the four-tier host side plus the mesh: a stage goes to
    the *device* when it can lower there, a plan was given, and the roofline
    estimate beats the best host service time; a farm of GIL-bound workers
    goes to the *process* tier when true parallelism over the calibrated
    shared-memory hop beats GIL-serialized threads, or to the *remote* tier
    (``host_remote``) when a worker pool (``remote_pool``, the compile
    call's ``remote_workers=``) is wide enough that parallelism over the
    calibrated network hop beats both; everything else runs on host
    *threads*.  Widths come from
    :func:`~repro.core.perf_model.choose_farm_width` over the calibrated
    channel costs.  ``overrides`` maps a stage index or worker object (the
    callable/FFNode the stage was built from) to a :class:`Placement` (or
    ``"host"``/``"host_process"``/``"host_remote"``/``"device"``).  A
    ``wrap_around`` graph places on the device only as a whole (every stage
    eligible) and only when ``feedback_steps`` says how many synchronous
    turns to run."""
    overrides = overrides or {}
    stages = _top_stages(graph)
    n_cpu = max(1, os.cpu_count() or 1)
    n_chips = _mesh_axis_size(plan, axis) if plan is not None else 1
    # calibrated channel/peak constants: the (one-time, disk-cached)
    # measurement only triggers when a decision could actually use the
    # process tier — a stage must be process-eligible AND measurably
    # GIL-bound (the tier is unreachable on an unknown signal), otherwise
    # the cheap cached-or-default lookup suffices
    def _gil_bound(s: Any) -> bool:
        c = s.cost
        return isinstance(c, CostEstimate) and c.releases_gil is False

    need_measure = mode in ("process", "remote") or (
        mode == "auto" and not graph._wrap
        and any((_process_ineligible_reason(s) is None
                 or _remote_ineligible_reason(s, remote_pool) is None)
                and _gil_bound(s) for s in stages))
    calib = pm.get_calibration(measure=need_measure)
    n_pool = len(remote_pool) if remote_pool else 0

    def override_for(i: int, s: Any) -> Optional[Placement]:
        # keys are stage indices or the hashable user objects a stage wraps
        # (IR dataclasses themselves are mutable and unhashable)
        for key in (i, getattr(s, "node", None), getattr(s, "fn", None)):
            if key is None:
                continue
            try:
                if key in overrides:
                    return _as_placement(overrides[key])
            except TypeError:
                continue
        return None

    # a feedback graph runs its loop through one target: device only when
    # the whole graph lowers there and the loop is bounded — by a turn
    # count (feedback_scan) or an exit predicate (feedback_while)
    wrap_device_ok = (graph._wrap and plan is not None
                      and (feedback_steps is not None
                           or feedback_cond is not None)
                      and not any(isinstance(s, A2AG) for s in stages)
                      and all(_device_eligible(s) for s in stages))

    # fused-run lengths: after core/fuse.py, adjacent device stages share
    # ONE _DeviceStageNode boundary, so a stage inside a candidate run of
    # length L pays device_dispatch_s / L (its share of the one real
    # dispatch) plus the calibrated fused_segment_s marginal — which is why
    # fused device placement wins at much smaller stage grain than the old
    # one-dispatch-per-stage model allowed
    def _device_candidate(i: int, s: Any) -> bool:
        ov = override_for(i, s)
        if ov is not None:
            return ov.target == "device"
        if plan is None or graph._wrap or mode not in ("auto", "device"):
            return False
        if isinstance(s, FarmG) and s.autoscale:
            return False
        c = s.cost if isinstance(s.cost, CostEstimate) else CostEstimate()
        return _device_eligible(s) and c.flops > 0

    run_len = [1] * len(stages)
    i = 0
    while i < len(stages):
        if _device_candidate(i, stages[i]):
            j = i
            while j < len(stages) and _device_candidate(j, stages[j]):
                j += 1
            for k in range(i, j):
                run_len[k] = j - i
            i = j
        else:
            i += 1

    for i, s in enumerate(stages):
        ov = override_for(i, s)
        c = s.cost if isinstance(s.cost, CostEstimate) else CostEstimate()
        proc_reason = _process_ineligible_reason(s)
        if isinstance(s, FarmG) and not s.autoscale:
            t_emit = getattr(getattr(s.emitter, "cost", None), "t_task", 0.0)
            t_coll = getattr(getattr(s.collector, "cost", None), "t_task", 0.0)
            host_width = (len(s.workers) if not s.n_auto else
                          pm.choose_farm_width(c.t_task, n_cpu,
                                               t_emit=t_emit,
                                               t_collect=t_coll,
                                               overhead=calib.queue_hop_s))
            proc_width = (len(s.workers) if not s.n_auto else
                          pm.choose_farm_width(
                              c.t_task, n_cpu, t_emit=t_emit,
                              t_collect=t_coll,
                              overhead=calib.proc_hop_effective_s()))
        elif isinstance(s, FarmG):
            host_width = len(s.workers) if not s.n_auto else n_cpu
            proc_width = host_width
        elif isinstance(s, A2AG):
            # both sides' widths are fixed by the graph; "width" reports the
            # total worker-process count of the stage
            host_width = 1
            proc_width = len(s.left) + len(s.right)
        else:
            host_width = 1
            proc_width = 1
        remote_reason = _remote_ineligible_reason(s, remote_pool)
        # a replicated farm spreads over the whole pool; a fixed worker
        # list caps at its own width (one pool address per callable)
        remote_width = 0 if not isinstance(s, FarmG) else (
            n_pool if (s.n_auto or s.fn is not None)
            else min(len(s.workers), n_pool))
        if ov is not None:
            if ov.target == "host_process" and proc_reason is not None:
                raise GraphError(f"stage {i} ({s.describe()}) cannot be "
                                 f"process-placed: {proc_reason}")
            if ov.target == "host_remote" and remote_reason is not None:
                raise GraphError(f"stage {i} ({s.describe()}) cannot be "
                                 f"remote-placed: {remote_reason}")
            if ov.width is None:
                w = {"device": n_chips, "host_process": proc_width,
                     "host_remote": remote_width, "host": host_width}[ov.target]
                ov = dataclasses.replace(ov, width=w)
            s.placement = ov
            continue
        if mode == "host":
            s.placement = Placement("host", host_width, "forced host")
            continue
        if mode == "process":
            if proc_reason is None:
                s.placement = Placement("host_process", proc_width,
                                        "forced process")
            else:
                s.placement = Placement("host", host_width,
                                        f"forced process, but {proc_reason}")
            continue
        if mode == "remote":
            if remote_reason is None:
                s.placement = Placement("host_remote", remote_width,
                                        "forced remote")
            else:
                s.placement = Placement("host", host_width,
                                        f"forced remote, but {remote_reason}")
            continue
        if mode == "device":
            s.placement = Placement("device", n_chips, "forced device")
            continue
        if graph._wrap:
            target = "device" if wrap_device_ok else "host"
            s.placement = Placement(
                target, n_chips if target == "device" else host_width,
                "feedback loop lowers as one unit")
            continue
        # -- cost-driven three-way decision --------------------------------
        # autoscale is a host-runtime request (grow/shrink the active
        # worker set from observed lane depth): a device farm has no lanes
        # to observe, so autoscale drops the device candidate but keeps the
        # thread-vs-process comparison — a demonstrably GIL-bound farm
        # autoscales its *processes* instead of threads
        autoscale = isinstance(s, FarmG) and s.autoscale
        host_t = max(c.host_time(host_width), calib.queue_hop_s)
        dev_dispatch = (calib.device_dispatch_s / max(1, run_len[i])
                        + calib.fused_segment_s)
        dev_t = (c.device_time(n_chips, dev_dispatch)
                 if plan is not None and not autoscale
                 and _device_eligible(s) else None)
        if dev_t is not None:
            # the overlapped boundary: a fused device run pays
            # max(transfer, compute) + the unhidden remainder, never their
            # sum — the h2d put of microbatch i+1 and the d2h copy-out of
            # i-1 ride under the compute of i (calibrated overlap_eff says
            # how much actually hides on this host).  The item crosses the
            # boundary once per fused run, so the per-stage byte estimate
            # amortizes over the run length.
            xfer = (c.bytes / max(1, run_len[i])) * (
                1.0 / (calib.h2d_bw_gbs * 1e9)
                + 1.0 / (calib.d2h_bw_gbs * 1e9)) if c.bytes > 0 else 0.0
            dev_t = calib.boundary_time(xfer, dev_t)
        # the process tier only pays off for demonstrably GIL-bound work
        # wide enough to parallelize (an unknown signal stays on threads),
        # and only past a hysteresis margin over the thread estimate — a
        # candidate inside the margin drops out entirely rather than
        # vetoing the host/device comparison
        proc_t = None
        if proc_reason is None and c.releases_gil is False \
                and proc_width >= 2:
            if isinstance(s, A2AG):
                # the two sides pipeline across the shm grid: service time
                # is the slower side over its width, floored by the hops
                nL, nR = len(s.left), len(s.right)
                t_l = sum(getattr(x.cost, "t_task", DEFAULT_T_TASK_S)
                          for x in s.left) / nL
                t_r = sum(getattr(x.cost, "t_task", DEFAULT_T_TASK_S)
                          for x in s.right) / nR
                # the farm/a2a lanes are batched (push_many/pop_many), so
                # the amortized hop is the honest per-item price here
                t = pm.a2a_service_time(t_l, t_r, nL, nR,
                                        calib.proc_hop_effective_s())
            else:
                t = c.process_time(proc_width, calib.proc_hop_effective_s())
            if t < 0.8 * host_t:
                proc_t = t
        # the remote tier competes on the same terms: GIL-bound work wide
        # enough to amortize the (much larger) network hop, past the same
        # hysteresis margin — and it must also beat the on-box process tier
        remote_t = None
        if remote_reason is None and c.releases_gil is False \
                and remote_width >= 2:
            t = c.remote_time(remote_width, calib.net_hop_s)
            if t < 0.8 * host_t and (proc_t is None or t < proc_t):
                remote_t = t
        candidates = {"host": host_t}
        if dev_t is not None:
            candidates["device"] = dev_t
        if proc_t is not None:
            candidates["host_process"] = proc_t
        if remote_t is not None:
            candidates["host_remote"] = remote_t
        target = min(candidates, key=candidates.get)
        if target == "device":
            s.placement = Placement(
                "device", n_chips,
                f"roofline {dev_t*1e6:.1f}us < host {host_t*1e6:.1f}us"
                + (f" (dispatch amortized over fused run of {run_len[i]})"
                   if run_len[i] > 1 else ""))
        elif target == "host_remote":
            s.placement = Placement(
                "host_remote", remote_width,
                ("autoscale on the remote tier: " if autoscale else "")
                + f"GIL-bound: {remote_width} remote workers "
                f"{remote_t*1e6:.1f}us < threads {host_t*1e6:.1f}us "
                f"(calibrated net hop {calib.net_hop_s*1e6:.1f}us, "
                f"{calib.source})")
        elif target == "host_process":
            s.placement = Placement(
                "host_process", proc_width,
                ("autoscale on the process tier: " if autoscale else "")
                + f"GIL-bound: {proc_width} processes {proc_t*1e6:.1f}us < "
                f"threads {host_t*1e6:.1f}us "
                f"(calibrated hop {calib.proc_hop_effective_s()*1e6:.1f}us, "
                f"{calib.source})")
        else:
            host_reason = "autoscale requested (host runtime)" \
                if autoscale else ("stateful/host-only"
                    if plan is not None and not _device_eligible(s) else (
                        "no declared FLOPs"
                        if dev_t is None and plan is not None
                        else ("no plan" if plan is None else
                              f"host {host_t*1e6:.1f}us <= roofline "
                              f"{dev_t*1e6:.1f}us")))
            s.placement = Placement("host", host_width, host_reason)
    return graph


# ---------------------------------------------------------------------------
# Stage 4: emit
# ---------------------------------------------------------------------------
def make_device_batched(graph: FFGraph, plan: Any, axis: str = "data",
                        feedback_steps: Optional[int] = None,
                        feedback_cond: Optional[Callable] = None,
                        a2a_capacity_factor: Optional[float] = None,
                        ) -> Tuple[Callable, int]:
    """Build the batch-level device function for a graph (or subgraph).

    Returns ``(batched(xs, offset), axis_multiple)``: ``xs`` is the stacked
    batch, ``offset`` the absolute stream index of its first item (position
    matters to ``all_to_all`` routing parity with the host feeder), and the
    batch length must be a multiple of ``axis_multiple`` (callers pad).

    ``a2a_capacity_factor`` bounds the all_to_all expert lanes via
    ``expert_capacity`` (over-capacity items are dropped); the default
    ``None`` is lossless — every lane sized to the batch, matching the host
    semantics at the price of nR-fold redundant expert compute."""
    import jax
    import jax.numpy as jnp
    from . import device as dev

    if plan is None:
        raise GraphError("device lowering needs a ShardingPlan (compile "
                         "mode/override asked for the device with plan=None)")
    mesh_axis = _mesh_axis_size(plan, axis)

    if graph._wrap:
        if feedback_steps is None and feedback_cond is None:
            raise GraphError(
                "device feedback needs a bound: pass feedback_steps=K "
                "(lowers through core.device.feedback_scan) or "
                "feedback_cond=pred (lowers through "
                "core.device.feedback_while) to compile(), or use the host "
                "path / feedback_scan directly")
        fn, uses_farm = _device_fn(graph.root)

        if feedback_cond is not None:
            # data-dependent turn count: lax.while_loop, vmap-safe (each
            # lane freezes once its own cond goes false), with
            # feedback_steps as an optional hard cap
            def item_fn(x):
                final, _ = dev.feedback_while(
                    lambda s: (fn(s), 0.0), x, feedback_cond,
                    max_steps=feedback_steps)
                return final
        else:
            def item_fn(x):
                final, _ = dev.feedback_scan(lambda s: (fn(s), 0.0), x,
                                             feedback_steps, collect=False)
                return final

        if uses_farm:
            inner = dev.farm_map(lambda xs: jax.vmap(item_fn)(xs),
                                 plan.mesh, axis=axis)
            return (lambda xs, offset: inner(xs)), mesh_axis
        inner = jax.vmap(item_fn)
        return (lambda xs, offset: inner(xs)), 1

    stages = _top_stages(graph)
    parts: List[Tuple[str, Callable]] = []    # ("map", f(xs)) | ("a2a", f(xs, t))
    mult = 1
    seg: List[Any] = []

    def close_seg() -> None:
        nonlocal mult
        if not seg:
            return
        sub = seg[0] if len(seg) == 1 else PipeG(list(seg))
        fn, uses_farm = _device_fn(sub)
        if uses_farm:
            parts.append(("map", dev.farm_map(
                lambda xs, _f=fn: jax.vmap(_f)(xs), plan.mesh, axis=axis)))
            mult = max(mult, mesh_axis)
        else:
            parts.append(("map", jax.vmap(fn)))
        seg.clear()

    for s in stages:
        if isinstance(s, A2AG):
            if not all(_is_pure_seq(x) for x in (*s.left, *s.right)):
                raise GraphError("device all_to_all lowering needs pure "
                                 "(callable) left/right workers")
            close_seg()
            parts.append(("a2a", dev.a2a_dispatch(
                [x.node for x in s.left], [x.node for x in s.right],
                router=s.router,
                mesh=plan.mesh if mesh_axis > 1 else None, axis=axis,
                capacity_factor=a2a_capacity_factor)))
            mult = max(mult, mesh_axis)
        else:
            seg.append(s)
    close_seg()

    def batched(xs, offset):
        # items may be pytrees (e.g. dict batches); a2a stages need arrays
        t_idx = offset + jnp.arange(jax.tree.leaves(xs)[0].shape[0])
        for kind, f in parts:
            xs = f(xs) if kind == "map" else f(xs, t_idx)
        return xs

    return batched, mult


class _DeviceStageNode(FFNode):
    """The device-put boundary node: one host pipeline stage that stacks a
    microbatch, moves it onto the mesh with the data-axis sharding, runs the
    jitted device segment, and streams the unstacked results downstream.
    The SPSC queues around it are exactly FastFlow's bounded lanes — the
    device never waits on the host unless the host truly falls behind.

    With ``overlap`` (the default) the boundary is *software-pipelined*
    through a depth-K in-flight window, the double-buffered SPSC hand-off of
    the 2009 TR applied to the most expensive hop in the system: dispatching
    microbatch *i* does NOT synchronize — the jitted call returns
    unfinalized arrays (JAX async dispatch), a device->host copy is started
    eagerly (``copy_to_host_async``), and the result is only awaited when
    *K-1* newer microbatches have been dispatched behind it.  Host stacking
    + ``device_put`` of microbatch *i+1* and the copy-out of *i-1* thus ride
    under the compute of *i*.  Retirement is FIFO, so exact input order is
    preserved; the bytes are identical to the synchronous boundary because
    the same jitted program sees the same stacked inputs — only the
    synchronization point moves.  ``inflight=1`` (or ``overlap=False``)
    degenerates to the strictly synchronous put -> compute -> copy path."""

    def __init__(self, batched: Callable, axis_mult: int, device_batch: int,
                 sharding: Any = None, label: str = "device",
                 jit_key: Optional[tuple] = None, overlap: bool = True,
                 inflight: int = 2):
        super().__init__()
        import collections
        from .fuse import jit_segment
        # jit through the fused-segment cache: re-compile() of the same
        # graph (the adaptive Supervisor's re-place path) reuses the traced
        # program instead of re-jitting a fresh closure
        self._batched = jit_segment(batched, jit_key)
        self._mult = max(1, axis_mult)
        self._B = max(int(device_batch), self._mult)
        self._sharding = sharding
        self._label = label
        self._buf: List[Any] = []
        self._off = 0
        self._flushes = 0
        self._inflight = max(1, int(inflight)) if overlap else 1
        self._window = collections.deque()   # FIFO of (n, ys) in flight
        self._abandoned = False
        # boundary accounting (cumulative seconds; under _stats_lock):
        # host-side submit (stack + put + async dispatch), copy-out wait
        # (compute remainder + d2h), and the share of that wait paid while
        # the window was full — the stall the Supervisor retunes against
        self._t_submit = 0.0
        self._t_drain = 0.0
        self._t_stall = 0.0
        self._retired = 0

    def svc(self, item: Any) -> Any:
        if self._abandoned:
            return GO_ON            # shutdown: drop instead of dispatching
        self._buf.append(item)
        if len(self._buf) >= self._B:
            self._dispatch()
        return GO_ON

    def svc_end(self) -> None:
        try:
            if self._buf and not self._abandoned:
                self._dispatch()    # the final partial microbatch
            while self._window:     # drain the in-flight window, in order
                self._retire(*self._window.popleft())
        except BaseException as e:   # noqa: BLE001
            # svc_end runs outside the svc try-block: record the error
            # ourselves and never leave submitted work unawaited
            if self.error is None:
                self.error = e
            self._window.clear()
            self._buf = []
            raise

    def abandon(self) -> None:
        """Shutdown path (:meth:`HybridRunner.shutdown`): drop the partial
        buffer and stop emitting.  The node's own thread still *retires*
        every in-flight microbatch in ``svc_end`` — awaiting the dispatched
        work releases its device buffers — but discards the results instead
        of pushing them at a consumer that is gone."""
        self._abandoned = True
        self._buf = []

    def _dispatch(self) -> None:
        import jax
        import jax.numpy as jnp
        import numpy as np
        t0 = time.perf_counter()
        items = [jax.tree.map(np.asarray, x) for x in self._buf]
        self._buf = []
        n = len(items)
        pad = (-n) % self._mult
        items = items + items[:1] * pad
        # stack on the host, ONE device put per leaf (jnp.asarray
        # canonicalizes dtypes exactly like the per-item path did)
        xs = jax.tree.map(lambda *ts: jnp.asarray(np.stack(ts)), *items)
        if self._sharding is not None:
            xs = jax.device_put(xs, self._sharding)
        # async dispatch: the jitted call returns unfinalized arrays — no
        # block_until_ready here; the sync happens at retirement
        ys = self._batched(xs, jnp.int32(self._off))
        self._off += n
        self._flushes += 1
        with self._stats_lock:
            self._t_submit += time.perf_counter() - t0
        if self._inflight <= 1:
            # the synchronous boundary (overlap off): await in place —
            # byte- and order-identical to the pre-overlap behavior
            self._retire(n, ys)
            return
        # start the d2h copy behind the compute so retirement mostly finds
        # the bytes already landed host-side (backends without the method
        # just pay the copy at retirement, as before)
        for leaf in jax.tree.leaves(ys):
            copy = getattr(leaf, "copy_to_host_async", None)
            if copy is not None:
                try:
                    copy()
                except Exception:   # noqa: BLE001 - optional fast path
                    pass
        self._window.append((n, ys))
        while len(self._window) > self._inflight:
            t1 = time.perf_counter()
            self._retire(*self._window.popleft())
            with self._stats_lock:
                self._t_stall += time.perf_counter() - t1

    def _retire(self, n: int, ys: Any) -> None:
        import jax
        import numpy as np
        t0 = time.perf_counter()
        # ONE device->host copy per output leaf, then numpy slicing — per-item
        # jax indexing pays a dispatch per item and dominates small batches
        host = jax.tree.map(np.asarray, ys)
        with self._stats_lock:
            self._t_drain += time.perf_counter() - t0
            self._retired += n
        if self._abandoned:
            return
        for i in range(n):
            self.ff_send_out(jax.tree.map(lambda t: t[i], host))

    def set_window(self, inflight: Optional[int] = None,
                   microbatch: Optional[int] = None) -> None:
        """Live boundary retune (the Supervisor's ``_boundary_act``).  Both
        take effect at the next dispatch on the node's own thread: growing
        the window lets more microbatches ride in flight, shrinking it
        retires eagerly until the window fits again."""
        if microbatch is not None:
            self._B = max(int(microbatch), self._mult)
        if inflight is not None:
            self._inflight = max(1, int(inflight))

    def make_handle(self, desc: Optional[str] = None) -> "DeviceBoundaryHandle":
        return DeviceBoundaryHandle(desc or f"device[{self._label}]", self)

    def node_stats(self) -> dict:
        s = super().node_stats()
        s["node"] = f"device[{self._label}]"
        s["backend"] = "device"
        s["flushes"] = self._flushes
        with self._stats_lock:
            drain = self._t_drain
            s["boundary"] = {
                "mode": "overlapped" if self._inflight > 1 else "sync",
                "microbatch": self._B, "inflight": self._inflight,
                "window": len(self._window), "retired": self._retired,
                "submit_s": round(self._t_submit, 6),
                "drain_s": round(drain, 6),
                "stall_s": round(self._t_stall, 6),
                "stall_frac": round(self._t_stall / drain, 4) if drain > 0
                else 0.0,
            }
        return s


class DeviceBoundaryHandle(StageHandle):
    """:class:`~repro.core.graph.StageHandle` over a
    :class:`_DeviceStageNode`: read-only stats (including the ``boundary``
    block — submit/drain/stall split) plus the in-flight window retune
    surface (``set_window``) the Supervisor's boundary policy drives.  Not
    ``reconfigurable`` — the boundary has no tier to migrate or farm width
    to resize; ``boundary_tunable`` is its own capability flag."""

    boundary_tunable = True

    def __init__(self, desc: str, node: _DeviceStageNode):
        super().__init__(desc, node, tier="device")
        self._node = node

    def stats(self) -> dict:
        return self._node.node_stats()

    def set_window(self, inflight: Optional[int] = None,
                   microbatch: Optional[int] = None) -> None:
        self._node.set_window(inflight=inflight, microbatch=microbatch)


class HybridRunner(HostRunner):
    """A mixed-placement graph: host stages over SPSC queues feeding device
    segments through :class:`_DeviceStageNode` boundary nodes (and possibly
    process farms through :class:`~repro.core.process.ProcessFarmNode`).
    Same surface as :class:`HostRunner`; ``placements`` records the
    compiler's per-stage decisions."""

    def shutdown(self, timeout: float = 10.0) -> None:
        """Best-effort unwind of a mid-stream hybrid runner: abandon every
        device boundary FIRST — their ``svc`` drops instead of dispatching
        and their ``svc_end`` still awaits (then discards) every in-flight
        microbatch, so dispatched device work is drained rather than leaked
        and the boundary thread can never wedge pushing results at a
        results queue nobody reads — then run the normal host unwind (EOS
        feed + join)."""
        for st in self._top_members():
            if isinstance(st, _DeviceStageNode):
                st.abandon()
        super().shutdown(timeout)


class ProcessRunner(HostRunner):
    """A host network whose process-placed farm stages run their workers as
    OS processes over the shared-memory SPSC rings of ``core/shm.py`` — the
    multicore-true host tier.  Same surface as :class:`HostRunner`; thread
    stages and process farms share one streaming network."""


class RemoteRunner(HostRunner):
    """A host network whose remote-placed farm stages run their workers on
    other hosts over the TCP network lanes of ``core/net.py`` — the
    distributed tier.  Same surface as :class:`HostRunner`; thread stages,
    process farms, and remote farms share one streaming network."""


def _lower_remote_stage(s: Any, p: Placement,
                        remote_pool: Optional[Sequence],
                        credit: int = 32) -> SeqG:
    """Replace a remote-placed farm with its boundary node
    (:class:`~repro.core.net.RemoteFarmNode`): to the rest of the
    (thread-tier) network it is one ordinary host stage whose workers happen
    to answer over TCP."""
    from .net import RemoteFarmNode
    reason = _remote_ineligible_reason(s, remote_pool)
    if reason is not None:
        raise GraphError(f"cannot remote-lower {s.describe()}: {reason}")
    n_pool = len(remote_pool)
    width = max(1, min(p.width or n_pool, n_pool))
    fns = [s.fn] * width if s.fn is not None \
        else [_pure_of(w) for w in s.workers][:width]
    pre = _pure_of(s.emitter) if s.emitter is not None else None
    post = _pure_of(s.collector) if s.collector is not None else None
    node = RemoteFarmNode(
        fns, list(remote_pool)[:len(fns)], pre=pre, post=post,
        credit=credit, autoscale=s.autoscale,
        label=f"remote_farm[{len(fns)}]"
        + ("@autoscale" if s.autoscale else ""))
    return SeqG(node)


def _lower_process_stage(s: Any, p: Placement, capacity: int,
                         transport: Any) -> SeqG:
    """Replace a process-placed farm or all_to_all with its boundary node:
    to the rest of the (thread-tier) network it is one ordinary host
    stage.  ``transport`` (a :class:`~repro.core.shm.TransportConfig`) caps
    the ring depths (``ring_slots`` per farm lane, ``grid_slots`` per a2a
    grid segment — the grid is nL x nR eagerly allocated, so shallower) and
    sizes the slots and the slab arena."""
    reason = _process_ineligible_reason(s)
    if reason is not None:
        raise GraphError(f"cannot process-lower {s.describe()}: {reason}")
    if isinstance(s, A2AG):
        lfns = [_pure_of(x) for x in s.left]
        rfns = [_pure_of(x) for x in s.right]
        node = ProcessA2ANode(
            lfns, rfns, router=s.router,
            capacity=capacity, transport=transport,
            label=f"process_a2a[{len(lfns)}x{len(rfns)}]")
        return SeqG(node)
    width = max(1, p.width or len(s.workers))
    fns = [s.fn] * width if s.fn is not None \
        else [_pure_of(w) for w in s.workers]
    pre = _pure_of(s.emitter) if s.emitter is not None else None
    post = _pure_of(s.collector) if s.collector is not None else None
    node = ProcessFarmNode(
        fns, pre=pre, post=post,
        capacity=capacity, transport=transport,
        autoscale=s.autoscale,
        label=f"process_farm[{len(fns)}]"
        + ("@autoscale" if s.autoscale else ""))
    return SeqG(node)


def _maybe_adaptive_node(s: Any, p: Placement, capacity: int,
                         slot_bytes: int,
                         transport: Any = None) -> Optional[Any]:
    """``compile(adaptive=True)``: lower an eligible farm stage to an
    :class:`~repro.core.runtime.AdaptiveFarmNode` — one host boundary node
    whose engine (thread farm / process farm) the runtime supervisor can
    resize and migrate live.  Eligible = a farm built from one replicated
    pure worker with pure-or-absent emitter/collector and the default
    schedule (the same shape ``autoscale`` requires); anything else returns
    None and lowers exactly as without ``adaptive``.

    Note the semantics opt-in: an adaptive farm's collector is
    sequence-ordered on BOTH tiers (output order == input order, matching
    the process/device lowerings and making migration order-safe), which is
    stricter than the plain thread farm's arrival order."""
    if not isinstance(s, FarmG) or p.target in ("device", "host_remote"):
        return None
    if s.fn is None or s.lb is not None or s.ondemand is not None:
        return None
    for part in (s.emitter, s.collector):
        if part is not None and _pure_of(part) is None:
            return None
    from .runtime import AdaptiveFarmNode
    can_proc = _process_ineligible_reason(s) is None
    width = max(1, p.width or len(s.workers))
    c = s.cost if isinstance(s.cost, CostEstimate) else None
    return AdaptiveFarmNode(
        s.fn, width,
        pre=_pure_of(s.emitter) if s.emitter is not None else None,
        post=_pure_of(s.collector) if s.collector is not None else None,
        tier=("host_process" if (p.target == "host_process" and can_proc)
              else "host"),
        # SHALLOW engine lanes on purpose: a migration drains whatever is
        # already inside the engine on the OLD tier, so bounding in-flight
        # work keeps the drain (and reconfig latency) cheap — the rest of
        # the backlog waits in the node's input queue, which survives the
        # swap.  A few items per lane is all throughput needs.
        capacity=max(2, min(capacity, 8)), slot_bytes=slot_bytes,
        transport=transport,
        label=f"adaptive_farm[{width}]", can_process=can_proc,
        thread_est_s=(c.host_time(width) if c is not None else None))


def _materialize_widths(n: Any) -> None:
    """Host-side auto farms get their cost-chosen width before building."""
    if isinstance(n, PipeG):
        for s in n.stages:
            _materialize_widths(s)
    elif isinstance(n, FarmG):
        if (n.n_auto and not n.autoscale and n.fn is not None
                and getattr(n.placement, "width", None)):
            n.workers = [SeqG(n.fn, pure=True)
                         for _ in range(max(1, n.placement.width))]
        for w in n.workers:
            _materialize_widths(w)


def emit(graph: FFGraph, plan: Any = None, *, capacity: int = 512,
         results_capacity: int = 4096, axis: str = "data",
         feedback_steps: Optional[int] = None,
         feedback_cond: Optional[Callable] = None,
         device_batch: Optional[int] = None,
         a2a_capacity_factor: Optional[float] = None,
         shm_slot_bytes: int = 1 << 16, adaptive: bool = False,
         remote_workers: Optional[Sequence] = None,
         net_credit: int = 32, transport: Any = None,
         fuse: bool = True, overlap: bool = True,
         microbatch: Optional[int] = None,
         inflight: Optional[int] = None) -> Any:
    """Build the runner for a placed graph (stage 4).

    Device placements go through the :mod:`~repro.core.fuse` pass first:
    every maximal run of adjacent device-placed stages lowers as ONE
    compiled segment — a single jitted program behind a single
    :class:`_DeviceStageNode` boundary (hybrid graphs) or a single
    :class:`~repro.core.graph.DeviceRunner` part (all-device graphs).
    ``fuse=False`` restores the pre-fusion one-program-per-stage emit (A/B
    benchmarks, parity tests).

    ``overlap``/``microbatch``/``inflight`` shape the host<->device
    boundary those segments run behind — the depth-K asynchronous in-flight
    window of :class:`_DeviceStageNode` (hybrid) and the microbatch
    software pipeline of :class:`~repro.core.graph.DeviceRunner`
    (all-device); see :class:`CompileConfig` for the semantics and
    defaults.

    ``transport`` (a :class:`~repro.core.shm.TransportConfig`, or a dict of
    its fields) tunes every shared-memory lane the lowering builds:
    ``ring_slots`` (farm-lane depth cap, default 64), ``grid_slots`` (a2a
    grid-segment depth cap, default 32 — the grid is nL x nR eagerly
    allocated), ``slot_bytes`` (fixed slot payload, default 64 KiB),
    ``arena_bytes`` (slab arena for oversize ndarrays, default 4 MiB),
    ``bounded`` (False grows uSPSC segment chains instead of
    back-pressuring), and ``batch``/``flush_s`` (vectored-lane flush
    policy).  When omitted, the legacy ``shm_slot_bytes=`` knob still sizes
    the slots and everything else takes the defaults."""
    from .shm import TransportConfig, as_transport
    tc = (as_transport(transport) if transport is not None
          else TransportConfig(slot_bytes=shm_slot_bytes))
    stages = _top_stages(graph)
    placements = [s.placement if isinstance(s.placement, Placement)
                  else Placement("host") for s in stages]
    report = list(zip([s.describe() for s in stages], placements))

    # adaptive mode lowers eligible farms FIRST, into AdaptiveFarmNode
    # boundary stages that carry their own (re-placeable) tier engine; the
    # rest of emit sees them as plain host stages
    adaptive_proc = False
    if adaptive:
        lowered = []
        for i, (s, p) in enumerate(zip(stages, placements)):
            node = _maybe_adaptive_node(s, p, capacity, tc.slot_bytes,
                                        transport=tc)
            if node is None:
                lowered.append(s)
                continue
            lowered.append(SeqG(node))
            adaptive_proc = adaptive_proc or node.tier == "host_process"
            report[i] = (report[i][0],
                         dataclasses.replace(p, reason=(p.reason + "; "
                                                        "adaptive").lstrip("; ")))
            placements[i] = dataclasses.replace(p, target="host")
        g2 = FFGraph(lowered[0] if len(lowered) == 1 else PipeG(lowered))
        g2._wrap = graph._wrap
        graph, stages = g2, lowered

    # remote-placed farms lower next, into RemoteFarmNode boundary stages
    # (workers on other hosts over TCP lanes): from here on the rest of
    # emit sees them as host stages
    has_remote = any(p.target == "host_remote" for p in placements)
    if has_remote:
        lowered = [(_lower_remote_stage(s, p, remote_workers, net_credit)
                    if p.target == "host_remote" else s)
                   for s, p in zip(stages, placements)]
        g2 = FFGraph(lowered[0] if len(lowered) == 1 else PipeG(lowered))
        g2._wrap = graph._wrap
        graph, stages = g2, lowered
        placements = [dataclasses.replace(p, target="host")
                      if p.target == "host_remote" else p
                      for p in placements]

    # process-placed farms and a2a stages lower next, into
    # ProcessFarmNode / ProcessA2ANode boundary stages: from here on the
    # rest of emit sees them as host stages, which is what lets thread ->
    # process -> device -> remote programs compose freely
    has_process = any(p.target == "host_process" for p in placements)
    if has_process:
        lowered = [(_lower_process_stage(s, p, capacity, tc)
                    if p.target == "host_process" else s)
                   for s, p in zip(stages, placements)]
        g2 = FFGraph(lowered[0] if len(lowered) == 1 else PipeG(lowered))
        g2._wrap = graph._wrap
        graph, stages = g2, lowered
        placements = [dataclasses.replace(p, target="host")
                      if p.target == "host_process" else p
                      for p in placements]
    targets = {p.target for p in placements}

    if targets == {"device"}:
        runner = DeviceRunner(graph, plan, axis=axis,
                              feedback_steps=feedback_steps,
                              feedback_cond=feedback_cond,
                              a2a_capacity_factor=a2a_capacity_factor,
                              fuse=fuse, overlap=overlap,
                              microbatch=microbatch, inflight=inflight)
    elif targets == {"host"}:
        _materialize_widths(graph.root)
        cls = RemoteRunner if has_remote else (
            ProcessRunner if (has_process or adaptive_proc) else HostRunner)
        runner = cls(graph, capacity=capacity,
                     results_capacity=results_capacity,
                     feedback_cond=feedback_cond)
    else:
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh_axis = _mesh_axis_size(plan, axis)
        # in a feedback loop items circulate one at a time: a buffering
        # boundary node would starve the loop waiting for a full microbatch
        # — and an async in-flight window holding results back would
        # deadlock it outright, so wrap graphs force the sync boundary
        if device_batch is None:
            device_batch = 1 if graph._wrap else 8 * mesh_axis
        if microbatch is not None:
            device_batch = max(1, int(microbatch))
        if graph._wrap:
            overlap = False
        if inflight is None:
            rec = pm.lookup_autotuned("device_overlap:window")
            inflight = int(rec.get("inflight", 2)) if rec else 2
        new_stages: List[Any] = []
        for entry, p in fuse_device_segments(stages, placements,
                                             enable=fuse):
            if not isinstance(entry, FusedSegment):
                new_stages.append(entry)
                continue
            sub = entry.subgraph()
            batched, mult = make_device_batched(
                sub, plan, axis=axis,
                a2a_capacity_factor=a2a_capacity_factor)
            sharding = (NamedSharding(plan.mesh, P(axis))
                        if mult > 1 else None)
            new_stages.append(SeqG(
                _DeviceStageNode(batched, mult, device_batch,
                                 sharding=sharding,
                                 label=entry.describe(),
                                 jit_key=segment_key(
                                     sub, device_batch, mult, plan, axis,
                                     a2a_capacity_factor),
                                 overlap=overlap, inflight=inflight)))
        _materialize_widths(PipeG(new_stages))
        hg = FFGraph(new_stages[0] if len(new_stages) == 1
                     else PipeG(new_stages))
        hg._wrap = graph._wrap
        runner = HybridRunner(hg, capacity=capacity,
                              results_capacity=results_capacity,
                              feedback_cond=feedback_cond)
    runner.placements = report
    return runner


# ---------------------------------------------------------------------------
# The pipeline driver
# ---------------------------------------------------------------------------
def compile_graph(graph: FFGraph, plan: Any = None, *,
                  config: Optional[CompileConfig] = None,
                  **kwargs: Any) -> Any:
    """Run the staged pipeline: normalize -> annotate -> place -> emit.

    All knobs live on :class:`CompileConfig`; ``compile_graph(g, config=c)``
    is the canonical call.  The flat spelling ``compile_graph(g, plan,
    mode=..., capacity=...)`` still works — the kwargs are folded into a
    config (unknown names raise ``TypeError``) — but mixing ``config=`` with
    a positional plan or extra kwargs is an error.

    ``fuse=False`` disables the device-segment fusion pass (one compiled
    program per device stage instead of one per maximal adjacent run) —
    for A/B benchmarks and fused-vs-unfused parity tests only.

    Note: stage-index keys in ``placements=`` refer to the *normalized*
    graph's top-level stages (normalize may collapse/fuse stages); worker
    objects (the callables/FFNodes stages were built from) survive the
    rewrites and are the stabler key.

    ``adaptive=True`` lowers eligible farm stages (one replicated pure
    worker, pure-or-absent emitter/collector, default schedule) to
    reconfigurable :class:`~repro.core.runtime.AdaptiveFarmNode` boundary
    stages whose width and thread/process tier a
    :class:`~repro.core.runtime.Supervisor` can change live, from observed
    stats; their collectors are sequence-ordered on both tiers.  With no
    supervisor attached an adaptive runner behaves like the static one.

    ``remote_workers=["host:port", ...]`` (or ``(host, port)`` tuples)
    names a pool of :func:`~repro.core.net.worker_main` worker pools and
    unlocks the ``host_remote`` target: ``place`` costs eligible farms
    against the calibrated network hop (``mode="remote"`` forces it), and
    ``emit`` lowers them to :class:`~repro.core.net.RemoteFarmNode`
    boundary stages with a ``net_credit``-deep in-flight window per lane.

    ``transport=`` (a :class:`~repro.core.shm.TransportConfig` or a dict of
    its fields) tunes every shared-memory lane of the process tier — ring
    depths, slot size, arena size, bounded-vs-uSPSC, batch flush policy;
    see :func:`emit` for the knobs and their defaults.  It supersedes the
    legacy ``shm_slot_bytes=`` when both are given.

    ``feedback_cond=pred`` makes a ``wrap_around`` loop data-dependent:
    on host the runner evaluates ``pred(item)`` on every item coming off
    the feedback edge and delivers it when false; on device the loop
    lowers through :func:`~repro.core.device.feedback_while`
    (``lax.while_loop``) with ``feedback_steps`` as an optional turn cap."""
    if config is not None:
        if plan is not None or kwargs:
            raise GraphError("compile_graph(config=...) does not combine "
                             "with a positional plan or extra kwargs — put "
                             "everything on the CompileConfig")
        cfg = config
    else:
        try:
            cfg = CompileConfig(plan=plan, **kwargs)
        except TypeError as e:
            raise TypeError(f"compile_graph(): {e}; see CompileConfig for "
                            "the supported knobs") from None
    if cfg.mode not in ("auto", "host", "process", "remote", "device"):
        raise GraphError(f"unknown compile mode {cfg.mode!r}")
    if cfg.mode == "device" and cfg.plan is None:
        raise GraphError("compile(mode=\"device\") needs a ShardingPlan")
    if cfg.mode == "remote" and not cfg.remote_workers:
        raise GraphError("compile(mode=\"remote\") needs remote_workers="
                         "[\"host:port\", ...]")
    g = graph.optimize() if cfg.normalize else graph
    # forced modes still need costs for width selection (n="auto" farms),
    # so annotate runs whenever the caller supplied cost information
    if cfg.mode == "auto" or cfg.costs or cfg.sample is not None:
        annotate(g, costs=cfg.costs, sample=cfg.sample)
    place(g, cfg.plan, overrides=cfg.placements, axis=cfg.axis,
          feedback_steps=cfg.feedback_steps,
          feedback_cond=cfg.feedback_cond, mode=cfg.mode,
          remote_pool=cfg.remote_workers)
    return emit(g, cfg.plan, capacity=cfg.capacity,
                results_capacity=cfg.results_capacity, axis=cfg.axis,
                feedback_steps=cfg.feedback_steps,
                feedback_cond=cfg.feedback_cond,
                device_batch=cfg.device_batch,
                a2a_capacity_factor=cfg.a2a_capacity_factor,
                shm_slot_bytes=cfg.shm_slot_bytes, adaptive=cfg.adaptive,
                remote_workers=cfg.remote_workers,
                net_credit=cfg.net_credit,
                transport=cfg.transport, fuse=cfg.fuse,
                overlap=cfg.overlap, microbatch=cfg.microbatch,
                inflight=cfg.inflight)
