"""The staged graph compiler: ``normalize -> annotate -> place -> emit``.

``FFGraph.lower(plan)`` used to be an all-or-nothing switch — the whole graph
on host threads or the whole graph on the JAX mesh.  This module turns
lowering into an explicit compile pipeline, the way the FastFlow runtime
layers arbitrary networks over its core channels:

1. **normalize** — the :meth:`FFGraph.optimize` normal-form rewrites
   (pipeline flattening, collector–emitter collapse, farm/pipeline fusion);
2. **annotate** — attach a :class:`CostEstimate` to every IR node from the
   paper's Sec. 13 algebra in ``core/perf_model.py``: per-item host time from
   ``costs=``, ``ff_cost``/``ff_flops``/``ff_bytes`` attributes on the
   worker, or by timing the node on a ``sample`` item; device time from the
   TPU roofline when FLOPs are declared;
3. **place** — assign each top-level stage a :class:`Placement` (host thread
   vs. device) by comparing the host farm service time against the roofline
   estimate, choose host farm widths with
   :func:`~repro.core.perf_model.choose_farm_width`, honor per-node
   overrides;
4. **emit** — build the runner: all-host -> :class:`~repro.core.graph.
   HostRunner`; all-device -> :class:`~repro.core.graph.DeviceRunner`; mixed
   -> :class:`HybridRunner`, host stages over SPSC queues feeding device
   segments on the mesh through device-put boundary nodes
   (:class:`_DeviceStageNode` stacks a microbatch, ``device_put``s it with
   the data-axis sharding, runs the jitted segment, and streams the
   unstacked results downstream).

``emit`` also closes the two device lowerings the monolithic ``lower()``
lacked: ``all_to_all`` becomes MoE-style dispatch/combine
(``core.device.a2a_dispatch``, reusing ``kernels/router_topk.py`` +
``expert_capacity``), and ``wrap_around`` lowers through
``core.device.feedback_scan`` when ``feedback_steps`` is given.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from . import perf_model as pm
from .graph import (A2AG, DeviceRunner, FarmG, FFGraph, GraphError,
                    HostRunner, MapG, PipeG, SeqG, _device_fn, _is_pure_seq)
from .node import GO_ON, FFNode

# Cost-model constants: a host core's useful peak (for flops-declared nodes
# with no measured time), the SPSC channel's own service time (the farm
# width floor), and the per-microbatch host<->device boundary cost.
HOST_PEAK_FLOPS = 5e10
HOST_QUEUE_OVERHEAD_S = 2e-5
DEVICE_DISPATCH_S = 2e-5
DEFAULT_T_TASK_S = 5e-5


@dataclasses.dataclass
class CostEstimate:
    """Per-node cost, in host-seconds per item plus declared work terms."""

    t_task: float = DEFAULT_T_TASK_S
    flops: float = 0.0
    bytes: float = 0.0
    source: str = "default"     # default | declared | given | measured | derived

    def host_time(self, width: int = 1) -> float:
        """Per-item service time on a ``width``-worker host farm."""
        return self.t_task / max(1, width)

    def device_time(self, n_chips: int = 1) -> Optional[float]:
        """Roofline per-item time on the mesh, or None when no work terms
        are declared (an unmeasurable node never wins a device slot)."""
        if self.flops <= 0:
            return None
        terms = pm.roofline(self.flops, self.bytes, 0.0, max(1, n_chips))
        return terms.step_time_s + DEVICE_DISPATCH_S


@dataclasses.dataclass
class Placement:
    """Where one top-level stage runs.  ``width`` is the host farm worker
    count (or the mesh axis size for device farms); ``reason`` records the
    cost-model comparison for reports/tests."""

    target: str = "host"        # "host" | "device"
    width: Optional[int] = None
    reason: str = ""


def _as_placement(v: Any) -> Placement:
    if isinstance(v, Placement):
        if v.target not in ("host", "device"):
            raise GraphError(f"Placement target must be 'host' or 'device' "
                             f"(got {v.target!r})")
        return v
    if v in ("host", "device"):
        return Placement(target=v, reason="override")
    raise GraphError(f"placement override must be 'host', 'device', or a "
                     f"Placement (got {v!r})")


# ---------------------------------------------------------------------------
# Stage 2: annotate
# ---------------------------------------------------------------------------
def _measure(fn: Callable, sample: Any, repeat: int = 3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(sample)
        best = min(best, time.perf_counter() - t0)
    return max(best, 1e-9)


def _estimate(key: Any, costs: Dict, sample: Any) -> CostEstimate:
    """Cost for one worker object: explicit ``costs=`` entry > declared
    ``ff_cost``/``ff_flops`` attributes > timing on ``sample`` > default."""
    if key is not None:
        try:
            given = costs.get(key)
        except TypeError:           # unhashable worker object
            given = None
        if given is not None:
            if isinstance(given, CostEstimate):
                return given
            return CostEstimate(t_task=float(given), source="given")
        fl = float(getattr(key, "ff_flops", 0.0) or 0.0)
        by = float(getattr(key, "ff_bytes", 0.0) or 0.0)
        t = getattr(key, "ff_cost", None)
        if t is not None:
            return CostEstimate(float(t), fl, by, "declared")
        if fl > 0.0:
            return CostEstimate(fl / HOST_PEAK_FLOPS, fl, by, "declared")
        if sample is not None and callable(key):
            try:
                return CostEstimate(_measure(key, sample), source="measured")
            except Exception:       # noqa: BLE001 - sample may not fit the fn
                pass
    return CostEstimate()


def annotate(graph: FFGraph, costs: Optional[Dict] = None,
             sample: Any = None) -> FFGraph:
    """Attach a :class:`CostEstimate` to every IR node (in place).

    Leaf costs come from :func:`_estimate`; composites follow the paper's
    algebra — a pipeline worker's per-item time is the sum of its stages, a
    farm node carries its *worker's* per-item time (the farm service time is
    width-dependent and belongs to ``place``)."""
    costs = costs or {}
    memo: Dict[int, CostEstimate] = {}    # replicated workers share one fn

    def est(key: Any, smp: Any) -> CostEstimate:
        k = id(key)
        if k not in memo:
            memo[k] = _estimate(key, costs, smp)
        return memo[k]

    def visit(n: Any) -> CostEstimate:
        if isinstance(n, SeqG):
            n.cost = est(n.node, sample if n.pure else None)
        elif isinstance(n, PipeG):
            subs = [visit(s) for s in n.stages]
            n.cost = CostEstimate(t_task=sum(c.t_task for c in subs),
                                  flops=sum(c.flops for c in subs),
                                  bytes=sum(c.bytes for c in subs),
                                  source="derived")
        elif isinstance(n, FarmG):
            subs = [visit(w) for w in n.workers]
            key = n.fn if n.fn is not None else None
            c = est(key, sample) if key is not None else subs[0]
            if c.source == "default" and subs[0].source != "default":
                c = subs[0]
            for part in (n.emitter, n.collector):
                if part is not None:
                    visit(part)
            n.cost = c
        elif isinstance(n, A2AG):
            ls = [visit(x) for x in n.left]
            rs = [visit(x) for x in n.right]
            n.cost = CostEstimate(
                t_task=(sum(c.t_task for c in ls) / len(ls)
                        + sum(c.t_task for c in rs) / len(rs)),
                flops=sum(c.flops for c in (*ls, *rs)),
                bytes=sum(c.bytes for c in (*ls, *rs)),
                source="derived")
        elif isinstance(n, MapG):
            for x in (n.splitter, *n.workers, n.composer):
                visit(x)
            n.cost = CostEstimate(source="default")
        else:
            return CostEstimate()
        return n.cost

    visit(graph.root)
    return graph


# ---------------------------------------------------------------------------
# Stage 3: place
# ---------------------------------------------------------------------------
def _top_stages(graph: FFGraph) -> List[Any]:
    return list(graph.root.stages) if isinstance(graph.root, PipeG) \
        else [graph.root]


def _device_eligible(n: Any) -> bool:
    """Can this stage lower onto the mesh at all?"""
    if isinstance(n, A2AG):
        return all(_is_pure_seq(x) for x in (*n.left, *n.right))
    try:
        _device_fn(n)
        return True
    except GraphError:
        return False


def _mesh_axis_size(plan: Any, axis: str) -> int:
    return int(dict(plan.mesh.shape).get(axis, 1))


def place(graph: FFGraph, plan: Any = None, overrides: Optional[Dict] = None,
          axis: str = "data", feedback_steps: Optional[int] = None,
          mode: str = "auto") -> FFGraph:
    """Assign each top-level stage a :class:`Placement` (in place).

    A stage goes to the device when it *can* lower there, a plan was given,
    and the roofline estimate beats the best host farm service time; host
    farm widths come from :func:`~repro.core.perf_model.choose_farm_width`.
    ``overrides`` maps a stage index or worker object (the callable/FFNode
    the stage was built from) to a :class:`Placement` (or
    ``"host"``/``"device"``).  A ``wrap_around``
    graph places on the device only as a whole (every stage eligible) and
    only when ``feedback_steps`` says how many synchronous turns to run."""
    overrides = overrides or {}
    stages = _top_stages(graph)
    n_cpu = max(1, os.cpu_count() or 1)
    n_chips = _mesh_axis_size(plan, axis) if plan is not None else 1

    def override_for(i: int, s: Any) -> Optional[Placement]:
        # keys are stage indices or the hashable user objects a stage wraps
        # (IR dataclasses themselves are mutable and unhashable)
        for key in (i, getattr(s, "node", None), getattr(s, "fn", None)):
            if key is None:
                continue
            try:
                if key in overrides:
                    return _as_placement(overrides[key])
            except TypeError:
                continue
        return None

    # a feedback graph runs its loop through one target: device only when
    # the whole graph lowers there and a turn count was given
    wrap_device_ok = (graph._wrap and plan is not None
                      and feedback_steps is not None
                      and not any(isinstance(s, A2AG) for s in stages)
                      and all(_device_eligible(s) for s in stages))

    for i, s in enumerate(stages):
        ov = override_for(i, s)
        c = s.cost if isinstance(s.cost, CostEstimate) else CostEstimate()
        if isinstance(s, FarmG) and not s.autoscale:
            t_emit = getattr(getattr(s.emitter, "cost", None), "t_task", 0.0)
            t_coll = getattr(getattr(s.collector, "cost", None), "t_task", 0.0)
            host_width = (len(s.workers) if not s.n_auto else
                          pm.choose_farm_width(c.t_task, n_cpu,
                                               t_emit=t_emit,
                                               t_collect=t_coll,
                                               overhead=HOST_QUEUE_OVERHEAD_S))
        elif isinstance(s, FarmG):
            host_width = len(s.workers) if not s.n_auto else n_cpu
        else:
            host_width = 1
        if ov is not None:
            if ov.width is None:
                ov = dataclasses.replace(
                    ov, width=n_chips if ov.target == "device" else host_width)
            s.placement = ov
            continue
        if mode == "host" or plan is None:
            s.placement = Placement("host", host_width, "forced host"
                                    if mode == "host" else "no plan")
            continue
        if mode == "device":
            s.placement = Placement("device", n_chips, "forced device")
            continue
        if graph._wrap:
            target = "device" if wrap_device_ok else "host"
            s.placement = Placement(
                target, n_chips if target == "device" else host_width,
                "feedback loop lowers as one unit")
            continue
        if isinstance(s, FarmG) and s.autoscale:
            # autoscale is a host-runtime request (grow/shrink threads from
            # lane depth); a device farm has no lanes to observe — honor the
            # flag unless an explicit override forces the device
            s.placement = Placement("host", host_width,
                                    "autoscale requested (host runtime)")
            continue
        if not _device_eligible(s):
            s.placement = Placement("host", host_width, "stateful/host-only")
            continue
        dev_t = c.device_time(n_chips)
        host_t = c.host_time(host_width)
        if dev_t is not None and dev_t < host_t:
            s.placement = Placement(
                "device", n_chips,
                f"roofline {dev_t*1e6:.1f}us < host {host_t*1e6:.1f}us")
        else:
            s.placement = Placement(
                "host", host_width,
                "no declared FLOPs" if dev_t is None else
                f"host {host_t*1e6:.1f}us <= roofline {dev_t*1e6:.1f}us")
    return graph


# ---------------------------------------------------------------------------
# Stage 4: emit
# ---------------------------------------------------------------------------
def make_device_batched(graph: FFGraph, plan: Any, axis: str = "data",
                        feedback_steps: Optional[int] = None,
                        a2a_capacity_factor: Optional[float] = None,
                        ) -> Tuple[Callable, int]:
    """Build the batch-level device function for a graph (or subgraph).

    Returns ``(batched(xs, offset), axis_multiple)``: ``xs`` is the stacked
    batch, ``offset`` the absolute stream index of its first item (position
    matters to ``all_to_all`` routing parity with the host feeder), and the
    batch length must be a multiple of ``axis_multiple`` (callers pad).

    ``a2a_capacity_factor`` bounds the all_to_all expert lanes via
    ``expert_capacity`` (over-capacity items are dropped); the default
    ``None`` is lossless — every lane sized to the batch, matching the host
    semantics at the price of nR-fold redundant expert compute."""
    import jax
    import jax.numpy as jnp
    from . import device as dev

    if plan is None:
        raise GraphError("device lowering needs a ShardingPlan (compile "
                         "mode/override asked for the device with plan=None)")
    mesh_axis = _mesh_axis_size(plan, axis)

    if graph._wrap:
        if feedback_steps is None:
            raise GraphError(
                "device feedback needs a turn count: pass feedback_steps=K "
                "to compile() (lowers through core.device.feedback_scan), "
                "or use the host path / feedback_scan directly")
        fn, uses_farm = _device_fn(graph.root)

        def item_fn(x):
            final, _ = dev.feedback_scan(lambda s: (fn(s), 0.0), x,
                                         feedback_steps, collect=False)
            return final

        if uses_farm:
            inner = dev.farm_map(lambda xs: jax.vmap(item_fn)(xs),
                                 plan.mesh, axis=axis)
            return (lambda xs, offset: inner(xs)), mesh_axis
        inner = jax.vmap(item_fn)
        return (lambda xs, offset: inner(xs)), 1

    stages = _top_stages(graph)
    parts: List[Tuple[str, Callable]] = []    # ("map", f(xs)) | ("a2a", f(xs, t))
    mult = 1
    seg: List[Any] = []

    def close_seg() -> None:
        nonlocal mult
        if not seg:
            return
        sub = seg[0] if len(seg) == 1 else PipeG(list(seg))
        fn, uses_farm = _device_fn(sub)
        if uses_farm:
            parts.append(("map", dev.farm_map(
                lambda xs, _f=fn: jax.vmap(_f)(xs), plan.mesh, axis=axis)))
            mult = max(mult, mesh_axis)
        else:
            parts.append(("map", jax.vmap(fn)))
        seg.clear()

    for s in stages:
        if isinstance(s, A2AG):
            if not all(_is_pure_seq(x) for x in (*s.left, *s.right)):
                raise GraphError("device all_to_all lowering needs pure "
                                 "(callable) left/right workers")
            close_seg()
            parts.append(("a2a", dev.a2a_dispatch(
                [x.node for x in s.left], [x.node for x in s.right],
                router=s.router,
                mesh=plan.mesh if mesh_axis > 1 else None, axis=axis,
                capacity_factor=a2a_capacity_factor)))
            mult = max(mult, mesh_axis)
        else:
            seg.append(s)
    close_seg()

    def batched(xs, offset):
        # items may be pytrees (e.g. dict batches); a2a stages need arrays
        t_idx = offset + jnp.arange(jax.tree.leaves(xs)[0].shape[0])
        for kind, f in parts:
            xs = f(xs) if kind == "map" else f(xs, t_idx)
        return xs

    return batched, mult


class _DeviceStageNode(FFNode):
    """The device-put boundary node: one host pipeline stage that stacks a
    microbatch, moves it onto the mesh with the data-axis sharding, runs the
    jitted device segment, and streams the unstacked results downstream.
    The SPSC queues around it are exactly FastFlow's bounded lanes — the
    device never waits on the host unless the host truly falls behind."""

    def __init__(self, batched: Callable, axis_mult: int, device_batch: int,
                 sharding: Any = None, label: str = "device"):
        super().__init__()
        import jax
        self._batched = jax.jit(batched)
        self._mult = max(1, axis_mult)
        self._B = max(int(device_batch), self._mult)
        self._sharding = sharding
        self._label = label
        self._buf: List[Any] = []
        self._off = 0

    def svc(self, item: Any) -> Any:
        self._buf.append(item)
        if len(self._buf) >= self._B:
            self._flush()
        return GO_ON

    def svc_end(self) -> None:
        if self._buf:
            try:
                self._flush()       # the final partial microbatch
            except BaseException as e:   # noqa: BLE001
                self.error = e      # svc_end runs outside the svc try-block
                raise

    def _flush(self) -> None:
        import jax
        import jax.numpy as jnp
        items = [jax.tree.map(jnp.asarray, x) for x in self._buf]
        self._buf = []
        n = len(items)
        pad = (-n) % self._mult
        items = items + items[:1] * pad
        xs = jax.tree.map(lambda *ts: jnp.stack(ts), *items)
        if self._sharding is not None:
            xs = jax.device_put(xs, self._sharding)
        ys = jax.block_until_ready(self._batched(xs, jnp.int32(self._off)))
        self._off += n
        for i in range(n):
            self.ff_send_out(jax.tree.map(lambda t: t[i], ys))


class HybridRunner(HostRunner):
    """A mixed-placement graph: host stages over SPSC queues feeding device
    segments through :class:`_DeviceStageNode` boundary nodes.  Same surface
    as :class:`HostRunner`; ``placements`` records the compiler's per-stage
    decisions."""

    placements: List[Tuple[str, Placement]] = []

    def describe_placements(self) -> str:
        return "\n".join(f"  [{p.target:6s}] {desc}"
                         + (f" width={p.width}" if p.width else "")
                         + (f"  # {p.reason}" if p.reason else "")
                         for desc, p in self.placements)


def _materialize_widths(n: Any) -> None:
    """Host-side auto farms get their cost-chosen width before building."""
    if isinstance(n, PipeG):
        for s in n.stages:
            _materialize_widths(s)
    elif isinstance(n, FarmG):
        if (n.n_auto and not n.autoscale and n.fn is not None
                and getattr(n.placement, "width", None)):
            n.workers = [SeqG(n.fn, pure=True)
                         for _ in range(max(1, n.placement.width))]
        for w in n.workers:
            _materialize_widths(w)


def emit(graph: FFGraph, plan: Any = None, *, capacity: int = 512,
         results_capacity: int = 4096, axis: str = "data",
         feedback_steps: Optional[int] = None,
         device_batch: Optional[int] = None,
         a2a_capacity_factor: Optional[float] = None) -> Any:
    """Build the runner for a placed graph (stage 4)."""
    stages = _top_stages(graph)
    placements = [s.placement if isinstance(s.placement, Placement)
                  else Placement("host") for s in stages]
    report = list(zip([s.describe() for s in stages], placements))
    targets = {p.target for p in placements}

    if targets == {"device"}:
        runner = DeviceRunner(graph, plan, axis=axis,
                              feedback_steps=feedback_steps,
                              a2a_capacity_factor=a2a_capacity_factor)
    elif targets == {"host"}:
        _materialize_widths(graph.root)
        runner = HostRunner(graph, capacity=capacity,
                            results_capacity=results_capacity)
    else:
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh_axis = _mesh_axis_size(plan, axis)
        # in a feedback loop items circulate one at a time: a buffering
        # boundary node would starve the loop waiting for a full microbatch
        if device_batch is None:
            device_batch = 1 if graph._wrap else 8 * mesh_axis
        new_stages: List[Any] = []
        run: List[Any] = []

        def close_run() -> None:
            if not run:
                return
            sub = FFGraph(run[0] if len(run) == 1 else PipeG(list(run)))
            batched, mult = make_device_batched(
                sub, plan, axis=axis,
                a2a_capacity_factor=a2a_capacity_factor)
            sharding = (NamedSharding(plan.mesh, P(axis))
                        if mult > 1 else None)
            new_stages.append(SeqG(
                _DeviceStageNode(batched, mult, device_batch,
                                 sharding=sharding,
                                 label=sub.root.describe())))
            run.clear()

        for s, p in zip(stages, placements):
            if p.target == "device":
                run.append(s)
            else:
                close_run()
                new_stages.append(s)
        close_run()
        _materialize_widths(PipeG(new_stages))
        hg = FFGraph(new_stages[0] if len(new_stages) == 1
                     else PipeG(new_stages))
        hg._wrap = graph._wrap
        runner = HybridRunner(hg, capacity=capacity,
                              results_capacity=results_capacity)
    runner.placements = report
    return runner


# ---------------------------------------------------------------------------
# The pipeline driver
# ---------------------------------------------------------------------------
def compile_graph(graph: FFGraph, plan: Any = None, *, mode: str = "auto",
                  normalize: bool = True, costs: Optional[Dict] = None,
                  sample: Any = None, placements: Optional[Dict] = None,
                  capacity: int = 512, results_capacity: int = 4096,
                  axis: str = "data", feedback_steps: Optional[int] = None,
                  device_batch: Optional[int] = None,
                  a2a_capacity_factor: Optional[float] = None) -> Any:
    """Run the staged pipeline: normalize -> annotate -> place -> emit.

    Note: stage-index keys in ``placements=`` refer to the *normalized*
    graph's top-level stages (normalize may collapse/fuse stages); worker
    objects (the callables/FFNodes stages were built from) survive the
    rewrites and are the stabler key."""
    if mode not in ("auto", "host", "device"):
        raise GraphError(f"unknown compile mode {mode!r}")
    if mode == "device" and plan is None:
        raise GraphError("compile(mode=\"device\") needs a ShardingPlan")
    g = graph.optimize() if normalize else graph
    # forced modes still need costs for width selection (n="auto" farms),
    # so annotate runs whenever the caller supplied cost information
    if mode == "auto" or costs or sample is not None:
        annotate(g, costs=costs, sample=sample)
    place(g, plan, overrides=placements, axis=axis,
          feedback_steps=feedback_steps, mode=mode)
    return emit(g, plan, capacity=capacity,
                results_capacity=results_capacity, axis=axis,
                feedback_steps=feedback_steps, device_batch=device_batch,
                a2a_capacity_factor=a2a_capacity_factor)
