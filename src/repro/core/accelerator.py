"""FastFlow *software accelerator* mode (paper Sec. 9), with the TPU mesh as
the accelerator device.

The paper's accelerator replaces ``y = f(x)`` with::

    acc.run_then_freeze(); acc.offload(x); ...; ok, y = acc.load_result()

Here ``f`` is a compiled SPMD step function.  JAX's asynchronous dispatch is
the offload queue (the call returns immediately with futures); a bounded host
SPSC queue provides back-pressure so the host cannot run unboundedly ahead of
the device — exactly the role of the bounded lock-free queue in FastFlow.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional

import jax

from .node import EOS
from .queues import SPSCQueue


class JaxAccelerator:
    """Offload ``fn(*task)`` calls onto the device mesh asynchronously.

    - ``run_then_freeze()``  start the dispatcher thread (compiles on first task)
    - ``offload(task)``      enqueue a task (a tuple of args for ``fn``)
    - ``offload(FF_EOS)``    signal end-of-stream
    - ``load_result()``      blocking: (ok, result); ok=False after EOS
    - ``load_result_nb()``   non-blocking variant
    - ``wait()``             join; returns 0/-1 like run_and_wait_end
    """

    def __init__(self, fn: Callable, max_inflight: int = 8,
                 donate: bool = False):
        self._fn = fn
        self._in: SPSCQueue = SPSCQueue(max(2, max_inflight))
        self._out: SPSCQueue = SPSCQueue(4096)
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None
        self._t0 = self._t1 = 0.0
        self.offloaded = 0

    # -- paper API -------------------------------------------------------------
    def run_then_freeze(self) -> int:
        self._t0 = time.perf_counter()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="jax-accelerator")
        self._thread.start()
        return 0

    def offload(self, task: Any) -> None:
        self._in.push(task)
        if task is not EOS:
            self.offloaded += 1

    def load_result(self, timeout: Optional[float] = None) -> tuple[bool, Any]:
        item = self._out.pop(timeout)
        if item is EOS:
            return False, None
        # a result may be a pytree of DeviceArrays: block for data readiness
        jax.block_until_ready(item)
        return True, item

    def load_result_nb(self) -> tuple[bool, Any]:
        ok, item = self._out.try_pop()
        if not ok or item is EOS:
            return False, None
        jax.block_until_ready(item)
        return True, item

    def wait(self, timeout: Optional[float] = None) -> int:
        if self._thread is not None:
            self._thread.join(timeout)
        self._t1 = time.perf_counter()
        return -1 if self.error is not None else 0

    def ffTime(self) -> float:
        return (self._t1 - self._t0) * 1e3

    # -- dispatcher --------------------------------------------------------------
    def _loop(self) -> None:
        try:
            while True:
                task = self._in.pop()
                if task is EOS:
                    break
                args = task if isinstance(task, tuple) else (task,)
                # async dispatch: returns immediately, device queues the work
                result = self._fn(*args)
                self._out.push(result)
        except BaseException as e:  # noqa: BLE001
            self.error = e
            import traceback
            traceback.print_exc()
        finally:
            self._out.push(EOS)
