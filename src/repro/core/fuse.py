"""Device-segment fusion — the pass between ``place`` and ``emit``.

The paper's layered lesson is that composition must collapse into cheap
communication: a FastFlow pipeline of N stages costs N lock-free hops, not N
OS handoffs.  Our device tier used to violate the analogous rule — ``emit``
jitted each device-placed stage as its own program, so a run of N adjacent
device stages paid N dispatches and N host round-trips per microbatch.  This
pass restores the invariant: it walks the placed stage list and greedily
merges every maximal run of adjacent ``device`` placements into one
:class:`FusedSegment`, which ``emit`` lowers to a single
``_DeviceStageNode`` (hybrid graphs) or a single ``DeviceRunner`` part
(all-device graphs) — one ``jax.jit``, one device-put in, one out,
regardless of how many stages composed into the run.

Inside a segment the existing ``make_device_batched`` composition applies:
pipelines of pure stages compose into one function, farm and ``ffmap``
stages fold in as vmapped (mesh: ``shard_map``-ed) bodies, ``all_to_all``
becomes the fused Pallas dispatch/combine kernel, and ``wrap_around`` tails
run through ``feedback_scan``.

The module also owns the **jitted-segment cache**: repeated ``compile()``
calls of the same graph (the adaptive Supervisor re-places and re-emits on
live stats) used to rebuild ``jax.jit`` wrappers around fresh closures,
retracing identical programs.  :func:`jit_segment` keys the jitted callable
by (fused-stage identity, ``device_batch``, axis multiple, mesh, capacity
factor) so the second compile reuses the traced program.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, List, Optional, Sequence, Tuple

from .graph import A2AG, FarmG, FFGraph, MapG, PipeG, SeqG


@dataclasses.dataclass
class FusedSegment:
    """A maximal run of contiguous device-placed top-level stages, lowered
    as ONE compiled program."""

    stages: List[Any]

    def describe(self) -> str:
        return " + ".join(s.describe() for s in self.stages)

    def subgraph(self) -> FFGraph:
        return FFGraph(self.stages[0] if len(self.stages) == 1
                       else PipeG(list(self.stages)))


def fuse_device_segments(stages: Sequence[Any], placements: Sequence[Any],
                         enable: bool = True) -> List[Tuple[Any, Any]]:
    """Group the placed stage list into ``(entry, placement)`` pairs where
    every maximal run of adjacent ``device`` placements becomes one
    :class:`FusedSegment` (its placement carries the widest width of the
    run).  ``enable=False`` degrades to one single-stage segment per device
    stage — the pre-fusion emit, kept for A/B benchmarks and parity tests."""
    out: List[Tuple[Any, Any]] = []
    run: List[Any] = []
    runp: List[Any] = []

    def close() -> None:
        if not run:
            return
        p = runp[0]
        if len(run) > 1:
            p = dataclasses.replace(
                p, width=max((q.width or 1) for q in runp),
                reason=f"fused run of {len(run)} device stages; " + p.reason)
        out.append((FusedSegment(list(run)), p))
        run.clear()
        runp.clear()

    for s, p in zip(stages, placements):
        if getattr(p, "target", "host") == "device":
            run.append(s)
            runp.append(p)
            if not enable:
                close()
        else:
            close()
            out.append((s, p))
    close()
    return out


# ---------------------------------------------------------------------------
# Jitted-segment cache
# ---------------------------------------------------------------------------
_JIT_CACHE: "OrderedDict[tuple, Any]" = OrderedDict()
_JIT_CACHE_MAX = 64
_hits = 0
_misses = 0


def _fingerprint(n: Any) -> Any:
    """Hashable identity of a device-lowerable IR node: the user callables
    (hashable by identity) plus the structure around them.  Raises TypeError
    for anything it cannot fingerprint — callers then skip caching."""
    if n is None:
        return None
    if isinstance(n, FFGraph):
        return ("graph", _fingerprint(n.root), n._wrap)
    if isinstance(n, SeqG):
        return ("seq", n.node, n.pure)
    if isinstance(n, PipeG):
        return ("pipe",) + tuple(_fingerprint(s) for s in n.stages)
    if isinstance(n, FarmG):
        return ("farm", n.fn, tuple(_fingerprint(w) for w in n.workers),
                _fingerprint(n.emitter), _fingerprint(n.collector), n.n_auto)
    if isinstance(n, MapG):
        return ("map", _fingerprint(n.splitter),
                tuple(_fingerprint(w) for w in n.workers),
                _fingerprint(n.composer))
    if isinstance(n, A2AG):
        return ("a2a", tuple(_fingerprint(x) for x in n.left),
                tuple(_fingerprint(x) for x in n.right), n.router)
    raise TypeError(f"no fingerprint for {type(n).__name__}")


def segment_key(sub: Any, device_batch: int, axis_mult: int, plan: Any,
                axis: str, a2a_capacity_factor: Optional[float] = None,
                feedback_steps: Optional[int] = None,
                feedback_cond: Optional[Any] = None) -> Optional[tuple]:
    """Cache key for a fused segment's jitted program, or None when any
    component resists fingerprinting (unhashable callables, odd meshes) —
    an uncacheable segment just jits fresh, never errors.  ``feedback_cond``
    (the data-dependent loop predicate) keys by callable identity, like the
    stage callables themselves."""
    try:
        mesh = getattr(plan, "mesh", None)
        try:
            mesh_id: Any = hash(mesh) if mesh is not None else None
        except TypeError:
            mesh_id = id(mesh)
        key = (_fingerprint(sub), int(device_batch), int(axis_mult),
               mesh_id, axis, a2a_capacity_factor, feedback_steps,
               feedback_cond)
        hash(key)
        return key
    except TypeError:
        return None


def jit_segment(batched: Any, key: Optional[tuple]) -> Any:
    """``jax.jit(batched)`` with a bounded cross-compile cache: the same
    fused segment (same key) returns the SAME jitted callable, so its traced
    programs survive re-``compile()`` of an identical graph."""
    global _hits, _misses
    import jax
    if key is None:
        return jax.jit(batched)
    f = _JIT_CACHE.get(key)
    if f is not None:
        _JIT_CACHE.move_to_end(key)
        _hits += 1
        return f
    f = jax.jit(batched)
    _JIT_CACHE[key] = f
    _misses += 1
    while len(_JIT_CACHE) > _JIT_CACHE_MAX:
        _JIT_CACHE.popitem(last=False)
    return f


def segment_cache_info() -> dict:
    return {"size": len(_JIT_CACHE), "hits": _hits, "misses": _misses,
            "max": _JIT_CACHE_MAX}


def segment_cache_clear() -> None:
    global _hits, _misses
    _JIT_CACHE.clear()
    _hits = 0
    _misses = 0
