"""L2 — the "arbitrary streaming network" layer for the device side.

A :class:`ShardingPlan` is the compiled form of a skeleton composition: it maps
*logical* tensor axes (batch, embed, heads, ffn, vocab, expert, seq, ...) onto
*mesh* axes.  The farm skeleton contributes the ``batch``/``fsdp`` mapping
(emitter = scatter over data axis, collector = gradient reduction), the map
skeleton contributes ``tp``/``seq`` (Split/Compose over the model axis), and
the MoE farm contributes ``expert`` (MPMC all-to-all).

Models never mention mesh axes directly; they annotate tensors with logical
axis names and call :meth:`ShardingPlan.constrain`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis vocabulary ----------------------------------------------------
#   batch     global batch                     -> (pod, data)
#   fsdp      parameter shard dim (ZeRO-3)     -> data (optionally +pod)
#   tp        tensor-parallel dim (heads/ffn/vocab/experts)
#   sp        sequence dim of activations between blocks (Megatron-SP)
#   cp        sequence dim inside context-parallel attention
#   none      replicated

DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "tp": ("model",),
    "sp": ("model",),
    "cp": ("model",),
    "expert": ("model",),
    "layers": (),      # stacked scan dim — never sharded
    "none": (),
}


@dataclasses.dataclass
class ShardingPlan:
    """Logical-axis -> mesh-axis mapping plus activation-constraint policy."""

    mesh: Mesh
    rules: Dict[str, Tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))
    # toggles used by the perf hillclimb
    sequence_parallel: bool = True      # shard residuals over model axis (SP)
    fsdp_params: bool = True            # ZeRO-3 weight sharding over data
    constrain_activations: bool = True

    def __post_init__(self):
        self._axis_names = set(self.mesh.axis_names)

    # -- resolution ----------------------------------------------------------
    def axes(self, logical: Optional[str]):
        """Resolve a logical axis to mesh axes present in this mesh."""
        if logical is None or logical == "none":
            return None
        if logical == "sp" and not self.sequence_parallel:
            return None
        if logical not in self.rules:
            raise KeyError(f"unknown logical axis {logical!r}")
        names = tuple(a for a in self.rules[logical] if a in self._axis_names)
        if not names:
            return None
        return names if len(names) > 1 else names[0]

    def pspec(self, *logicals: Optional[str]) -> P:
        return P(*[self.axes(l) for l in logicals])

    def sharding(self, *logicals: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(*logicals))

    def _fit_dim(self, dim: int, logical: Optional[str]):
        """Mesh axes for one dim, dropping axes that don't divide it
        (partial sharding — e.g. batch=1 decode replicates over data)."""
        if logical == "fsdp" and not self.fsdp_params:
            return None
        ax = self.axes(logical)
        if ax is None:
            return None
        axes_t = ax if isinstance(ax, tuple) else (ax,)
        keep, prod = [], 1
        for a in axes_t:
            n = self.mesh.shape[a]
            if dim % (prod * n) == 0:
                keep.append(a)
                prod *= n
        if not keep:
            return None
        return tuple(keep) if len(keep) > 1 else keep[0]

    def spec_for_shape(self, shape: Sequence[int],
                       logicals: Sequence[Optional[str]]) -> P:
        return P(*[self._fit_dim(d, l) for d, l in zip(shape, logicals)])

    def constrain(self, x, *logicals: Optional[str]):
        if not self.constrain_activations:
            return x
        spec = self.spec_for_shape(x.shape, logicals)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def gather_fsdp(self, w, axes: Sequence[Optional[str]]):
        """ZeRO-3 weight gather at the use site: drop the 'fsdp' dims so
        GSPMD all-gathers the (small, bf16) weight shards instead of
        partial-summing (large, f32) activations over the data axis."""
        if not self.fsdp_params:
            return w
        un = tuple(None if a == "fsdp" else a for a in axes)
        return self.constrain(w, *un)

    # -- parameter specs -------------------------------------------------------
    def param_spec(self, logical_axes: Sequence[Optional[str]],
                   shape: Optional[Sequence[int]] = None) -> P:
        """Spec for a parameter given per-dim logical names.  Honors the
        ``fsdp_params`` toggle; with a shape, drops non-dividing axes."""
        if shape is not None:
            return self.spec_for_shape(shape, logical_axes)
        out = []
        for l in logical_axes:
            if l == "fsdp" and not self.fsdp_params:
                out.append(None)
            else:
                out.append(self.axes(l))
        return P(*out)

    def sharding_for(self, logical_axes: Sequence[Optional[str]],
                     shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.param_spec(logical_axes, shape))

    def tree_shardings(self, logical_tree) -> Any:
        """Map a pytree of per-dim logical-axis tuples to NamedShardings."""
        return jax.tree.map(
            lambda la: NamedSharding(self.mesh, self.param_spec(la)),
            logical_tree,
            is_leaf=lambda x: isinstance(x, tuple))

    # -- derived sizes ---------------------------------------------------------
    def axis_size(self, logical: str) -> int:
        ax = self.axes(logical)
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[ax]

    @property
    def dp(self) -> int:
        return self.axis_size("batch")

    @property
    def tp(self) -> int:
        return self.axis_size("tp")


def single_device_plan() -> ShardingPlan:
    """A trivial plan over whatever single-device mesh exists (tests/CPU)."""
    import numpy as np
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, axis_names=("data", "model"))
    return ShardingPlan(mesh=mesh)
