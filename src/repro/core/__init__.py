"""Core of the framework — FastFlow's layered streaming-network model,
adapted from shared-memory multicores to TPU pods, unified behind one
composable *building blocks* graph API and one staged graph compiler.

Layer 1-2 (``core.queues``, ``core.shm``, ``core.net``): lock-free SPSC
ring buffers, composed into SPMC / MPSC / MPMC networks — the channels every
host skeleton runs over.  ``core.queues`` is the thread-tier instance;
``core.shm`` lays the same fixed-slot ring out in
``multiprocessing.shared_memory`` so the ring crosses OS processes —
FastFlow's actual multicore claim — in three lane tiers: the bounded SPSC
ring (raw-numpy slab fast path, pickled-bytes fallback, back-pressure when
full), the *uSPSC* unbounded tier of the 2009 FastFlow TR
(``ShmUSPSCQueue``: a linked chain of ring segments grown on overflow and
retired on drain, so the producer never blocks), and the ``ShmArena`` slab
for ndarrays larger than a ring slot (shipped as arena offsets, never
pickled).  Every lane moves items *vectored* — ``push_many``/``pop_many``
pay one atomic index write and one spin per batch, with small non-array
items coalescing into single batch slots — and ``compile(transport=...)``
(a ``TransportConfig``) tunes ring depths, slot/arena sizes,
bounded-vs-uSPSC, and the batch flush policy per compile.  ``core.net``
speaks the same slot protocol over TCP (length-prefixed frames, u64 seqs,
EOS/ERR control, plus credit-window back-pressure and heartbeats) so the
lane crosses the *host* boundary — the distributed tier.

Layer 3 (``core.node``, ``core.skeletons``): the paper-faithful host
runtime — ``ff_node`` (``svc``/``svc_init``/``svc_end``), ``Pipeline``,
``Farm`` (emitter / collector / load balancers / on-demand / autoscaling),
``FFMap``, ``wrap_around`` feedback, and the accelerator mode
(``run_then_freeze`` / ``offload`` / ``load_result`` / ``FF_EOS`` / ``wait``).

Building blocks (``core.graph``): the declarative front door.  Programs are
written as an ``FFGraph`` of composable blocks — ``seq``, ``pipeline``,
``farm`` (including ``n="auto"`` and ``autoscale=True`` widths), ``ffmap``,
``all_to_all`` (FastFlow 3's ``ff_a2a``), plus ``wrap_around`` feedback.

The staged compiler (``core.compiler``): ``FFGraph.compile(plan)`` runs four
explicit stages —

1. **normalize**: the ``optimize()`` normal-form rewrites (pipeline
   flattening, collector-emitter collapse, farm/pipeline fusion);
2. **annotate**: a ``CostEstimate`` per node from the paper's Sec. 13
   algebra in ``core.perf_model`` (declared ``ff_cost``/``ff_flops``,
   explicit ``costs=``, or timing the node on a ``sample`` item), plus a
   GIL-sensitivity signal: set ``fn.ff_releases_gil = True`` on workers
   whose hot loop drops the GIL (I/O, large BLAS calls, jitted device
   steps) or ``False`` on ones that hold it (pure-Python / small-array
   numpy); undeclared workers are probed by timing the node solo vs. under
   two concurrent threads when a ``sample`` is available;
3. **place**: a ``Placement`` per top-level stage across the *four-tier*
   host side plus the mesh — host *thread*, host *process* (a GIL-bound
   farm or ``all_to_all`` gains true parallelism worth more than the
   shared-memory hop), host *remote* (``host_remote``: the farm's workers
   live in ``python -m repro.launch.worker`` pools on other hosts, reached
   over the network lanes of ``core.net`` and unlocked by
   ``compile(remote_workers=["host:port", ...])`` — chosen when
   parallelism over the network hop beats both on-box tiers, or forced
   with ``mode="remote"``), or *device* — consuming the constants
   ``perf_model.calibrate()`` measures at startup (host peak FLOP/s,
   thread-queue hop, process-lane hop per item AND amortized over a
   vectored batch — the batched hop is what the process tier is actually
   charged — slab-arena bandwidth, loopback network hop, device
   dispatch; cached on disk,
   ``REPRO_FF_CACHE``/``XDG_CACHE_HOME``-relocatable for hermetic CI, and
   degrading to in-memory constants with a warning when the cache dir is
   unwritable) instead of baked-in defaults; farm width from
   ``choose_farm_width``, a2a service time from ``a2a_service_time``; all
   overridable per node;
4. **emit**: ``HostRunner`` (threads over SPSC queues), ``ProcessRunner``
   (process-placed farms run OS-process workers over the shared-memory
   rings of ``core.shm``, bridged into the thread network by
   ``core.process.ProcessFarmNode`` — order-preserving, crash-surfacing,
   optionally autoscaling its active worker set from shm lane depth;
   process-placed ``all_to_all`` stages run left/right worker processes
   over the ``core.shm.ShmMPMCGrid`` lane grid via
   ``core.process.ProcessA2ANode``, the router shipped to the left
   children and sequence numbers riding the slot headers),
   ``RemoteRunner`` (remote-placed farms run ``core.net.RemoteFarmNode``
   boundary nodes: per-worker TCP lanes with a bounded credit window,
   sequence-ordered collection, heartbeat crash surfacing, and
   ``set_active``-driven *cluster autoscaling* — AutoscaleLB and the
   runtime Supervisor grow/shrink the active remote worker set from
   observed lane depth), ``DeviceRunner`` (the mesh via ``core.device``),
   or the *hybrid* runner — host stages over SPSC queues feeding device
   segments through device-put boundary nodes.  Thread -> process ->
   remote -> device programs compose in one graph; every block has a
   backend on each eligible tier.

``emit`` covers every block on both targets: farms are ``shard_map`` over
the data axis, ``all_to_all`` lowers to ONE fused Pallas dispatch/combine
kernel (``kernels.a2a_fused`` via ``core.device.a2a_dispatch``: route,
capacity position, expert compute, and stream-order combine in a single
``pallas_call``, per-expert lane cursors in VMEM scratch, ``expert_capacity``
sizing the lanes, and the kernel itself sharded over the mesh in the
lossless case), and ``wrap_around`` lowers through
``core.device.feedback_scan`` when ``feedback_steps=K`` bounds the loop or
through ``core.device.feedback_while`` (a masked, vmap-safe
``lax.while_loop``) when ``feedback_cond=`` gives a data-dependent exit
predicate.  All compile knobs consolidate into one
:class:`~repro.core.compiler.CompileConfig` dataclass —
``graph.compile(config=CompileConfig(...))`` is the supported surface, and
the legacy flat kwargs remain as a one-``DeprecationWarning`` shim.
``lower(plan)`` stays as a thin compat wrapper forcing all-host
(``plan=None``) or all-device placement.  The data pipeline, the serving
engine, and the launch entry points are all expressed as FFGraph programs
compiled through this pipeline.

Device-segment fusion (``core.fuse``): between ``place`` and ``emit``,
every maximal run of *adjacent* device-placed stages is greedily merged
into one ``FusedSegment`` and lowered as ONE compiled program — a single
``jax.jit``, one device put in, one host copy out per run, whether the run
is a pipeline of pure stages, vmapped farm/``ffmap`` bodies, a fused-a2a
hop, or a ``feedback_scan`` tail.  This is the paper's layered lesson
applied to the device tier: composition must collapse into cheap
communication, so N composed stages cost one dispatch, not N host
round-trips.  ``compile(fuse=False)`` restores the one-program-per-stage
emit (per-stage observability, A/B benchmarks —
``benchmarks/bench_core.py``'s ``device_fusion_speedup`` gates the win in
CI).  Jitted segments are cached across ``compile()`` calls keyed by
fused-stage identity, so the adaptive Supervisor's re-place path reuses
traced programs instead of retracing; ``place`` amortizes the calibrated
``device_dispatch_s`` over each candidate run (plus the measured
``fused_segment_s`` marginal), which is what lets fused device placement
win at much smaller stage grain, and kernel tile sizes come from
``benchmarks/roofline.py --autotune`` winners persisted in the
``perf_model`` cache.

The overlapped device boundary: fusion made each host<->device hop cost one
dispatch; overlap makes those dispatches *asynchronous and software-
pipelined*.  Both boundary emits — the hybrid runner's
``_DeviceStageNode`` and ``DeviceRunner``'s microbatched whole-graph path —
keep a depth-K in-flight window of microbatches riding JAX's async
dispatch: microbatch i+1 stacks and dispatches, and i-1's device->host copy
completes (``copy_to_host_async``), while i computes; nothing calls
``block_until_ready`` until the window is full, and FIFO retirement
preserves exact input order.  Three ``CompileConfig`` knobs govern it —
``overlap`` (default True), ``microbatch`` (the boundary's stacking unit,
``device_batch``'s modern name), and ``inflight`` (the window depth,
defaulting to the ``device_overlap:window`` winner the
``roofline.py --autotune`` depth sweep persists in the ``perf_model``
cache).  ``overlap=False`` (or ``inflight=1``) restores the strictly
synchronous put -> compute -> copy-out boundary and is byte-identical —
the same jitted programs see the same stacked inputs; only the
synchronization point moves — which is what the ``device_overlap_speedup``
bench gates in CI.  ``place`` costs a fused device run at
``max(transfer, compute)`` through the calibrated h2d/d2h bandwidths and
overlap efficiency (calib cache v5), boundary nodes publish
submit/drain/stall stats through a ``boundary_tunable``
``DeviceBoundaryHandle``, and the runtime Supervisor retunes the window
depth live from the observed stall fraction.  Feedback (``wrap_around``)
graphs force the sync boundary — a window holding results back would
starve the loop.

The adaptive runtime (``core.runtime``) closes the stats -> placement loop
*at runtime*: ``compile(adaptive=True)`` lowers eligible farms to
reconfigurable ``AdaptiveFarmNode`` boundary stages (sequence-ordered on
both host tiers), every runner exposes a uniform per-stage ``StageHandle``
surface (stats + resize/migrate), and a ``Supervisor`` thread samples it —
growing/shrinking active worker sets from observed lane depth (the
AutoscaleLB policy generalized to any adaptive farm on either tier),
migrating a farm thread <-> process mid-stream when the observed
GIL-serialized service time crosses the other tier's estimate (drain to a
quiescent EOS-style barrier, hot-swap the engine behind the stage's
boundary queues, resume — order and error semantics unchanged), and feeding
measured service times, GIL signals, and hop costs back into the
calibration cache via ``perf_model.observe`` so the *next* ``compile()``'s
``place()`` decisions improve.  Calibration is no longer a startup-only
event.  With ``adaptive=False`` (the default) nothing here runs.

Device side: ``core.plan`` maps logical tensor axes onto mesh axes,
``core.device`` holds the mesh lowerings, ``core.accelerator`` treats a
compiled SPMD step as an offload target, and ``core.perf_model`` extends the
paper's Sec. 13 cost model with a TPU roofline.
"""

from .node import EOS, GO_ON, FFNode, FnNode
from .queues import MPMCQueue, MPSCQueue, QueueClosed, SPMCQueue, SPSCQueue
from .skeletons import (AutoscaleLB, BroadcastLB, Farm, FF_EOS, FFMap,
                        LoadBalancer, OnDemandLB, Pipeline, RoundRobinLB,
                        Skeleton, ThreadFarmNode)
from .shm import (BatchedLaneWriter, ShmArena, ShmMPMCGrid, ShmMPSCQueue,
                  ShmSPMCQueue, ShmSPSCQueue, ShmUSPSCQueue, TransportConfig,
                  as_transport)
from .graph import (A2ASkeleton, Deliver, FFGraph, GraphError, Runner,
                    StageHandle, all_to_all, farm, ffmap, pipeline, seq)
from .graph import HostRunner, DeviceRunner
from .process import ProcessA2ANode, ProcessFarmNode, WorkerCrashed
from .net import (NetLane, RemoteFarmNode, RemoteStageHandle,
                  spawn_loopback_pool, worker_main)
from .compiler import (CompileConfig, CostEstimate, HybridRunner, Placement,
                       ProcessRunner, RemoteRunner, annotate, compile_graph,
                       emit, place)
from .runtime import (AdaptiveFarmNode, AdaptiveStageHandle,
                      ReplacementEvent, SLOPolicy, Supervisor)
from .accelerator import JaxAccelerator
from .plan import DEFAULT_RULES, ShardingPlan, single_device_plan
from . import device, perf_model

__all__ = [
    "EOS", "GO_ON", "FF_EOS", "FFNode", "FnNode",
    "SPSCQueue", "SPMCQueue", "MPSCQueue", "MPMCQueue", "QueueClosed",
    "ShmSPSCQueue", "ShmSPMCQueue", "ShmMPSCQueue", "ShmMPMCGrid",
    "ShmUSPSCQueue", "ShmArena", "TransportConfig", "BatchedLaneWriter",
    "as_transport",
    "Pipeline", "Farm", "FFMap", "Skeleton", "ThreadFarmNode",
    "LoadBalancer", "RoundRobinLB", "OnDemandLB", "BroadcastLB",
    "AutoscaleLB",
    "FFGraph", "GraphError", "Deliver", "Runner", "StageHandle",
    "HostRunner", "DeviceRunner", "HybridRunner", "ProcessRunner",
    "A2ASkeleton", "ProcessFarmNode", "ProcessA2ANode", "WorkerCrashed",
    "NetLane", "RemoteFarmNode", "RemoteStageHandle", "RemoteRunner",
    "spawn_loopback_pool", "worker_main",
    "AdaptiveFarmNode", "AdaptiveStageHandle", "ReplacementEvent",
    "SLOPolicy", "Supervisor",
    "seq", "pipeline", "farm", "ffmap", "all_to_all",
    "CompileConfig", "CostEstimate", "Placement", "annotate", "place",
    "emit", "compile_graph",
    "JaxAccelerator", "ShardingPlan", "single_device_plan", "DEFAULT_RULES",
    "device", "perf_model",
]
