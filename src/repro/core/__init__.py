"""Core of the framework — FastFlow's layered streaming-network model,
adapted from shared-memory multicores to TPU pods, unified behind one
composable *building blocks* graph API and one staged graph compiler.

Layer 1-2 (``core.queues``): lock-free SPSC ring buffers, composed into
SPMC / MPSC / MPMC networks — the channels every host skeleton runs over.

Layer 3 (``core.node``, ``core.skeletons``): the paper-faithful host
runtime — ``ff_node`` (``svc``/``svc_init``/``svc_end``), ``Pipeline``,
``Farm`` (emitter / collector / load balancers / on-demand / autoscaling),
``FFMap``, ``wrap_around`` feedback, and the accelerator mode
(``run_then_freeze`` / ``offload`` / ``load_result`` / ``FF_EOS`` / ``wait``).

Building blocks (``core.graph``): the declarative front door.  Programs are
written as an ``FFGraph`` of composable blocks — ``seq``, ``pipeline``,
``farm`` (including ``n="auto"`` and ``autoscale=True`` widths), ``ffmap``,
``all_to_all`` (FastFlow 3's ``ff_a2a``), plus ``wrap_around`` feedback.

The staged compiler (``core.compiler``): ``FFGraph.compile(plan)`` runs four
explicit stages —

1. **normalize**: the ``optimize()`` normal-form rewrites (pipeline
   flattening, collector-emitter collapse, farm/pipeline fusion);
2. **annotate**: a ``CostEstimate`` per node from the paper's Sec. 13
   algebra in ``core.perf_model`` (declared ``ff_cost``/``ff_flops``,
   explicit ``costs=``, or timing the node on a ``sample`` item);
3. **place**: a ``Placement`` per top-level stage — host thread vs. device,
   farm width from ``choose_farm_width``, overridable per node;
4. **emit**: ``HostRunner`` (threads over SPSC queues), ``DeviceRunner``
   (the mesh via ``core.device``), or the *hybrid* runner — host stages over
   SPSC queues feeding device segments through device-put boundary nodes.

``emit`` covers every block on both targets: farms are ``shard_map`` over
the data axis, ``all_to_all`` lowers to MoE-style dispatch/combine
(``core.device.a2a_dispatch``, reusing the ``router_topk`` kernel and
``expert_capacity``), and ``wrap_around`` lowers through
``core.device.feedback_scan`` when ``compile(feedback_steps=K)`` bounds the
loop.  ``lower(plan)`` stays as a thin compat wrapper forcing all-host
(``plan=None``) or all-device placement.  The data pipeline, the serving
engine, and the launch entry points are all expressed as FFGraph programs
compiled through this pipeline.

Device side: ``core.plan`` maps logical tensor axes onto mesh axes,
``core.device`` holds the mesh lowerings, ``core.accelerator`` treats a
compiled SPMD step as an offload target, and ``core.perf_model`` extends the
paper's Sec. 13 cost model with a TPU roofline.
"""

from .node import EOS, GO_ON, FFNode, FnNode
from .queues import MPMCQueue, MPSCQueue, QueueClosed, SPMCQueue, SPSCQueue
from .skeletons import (AutoscaleLB, BroadcastLB, Farm, FF_EOS, FFMap,
                        LoadBalancer, OnDemandLB, Pipeline, RoundRobinLB,
                        Skeleton)
from .graph import (A2ASkeleton, Deliver, FFGraph, GraphError, Runner,
                    all_to_all, farm, ffmap, pipeline, seq)
from .graph import HostRunner, DeviceRunner
from .compiler import (CostEstimate, HybridRunner, Placement, annotate,
                       compile_graph, emit, place)
from .accelerator import JaxAccelerator
from .plan import DEFAULT_RULES, ShardingPlan, single_device_plan
from . import device, perf_model

__all__ = [
    "EOS", "GO_ON", "FF_EOS", "FFNode", "FnNode",
    "SPSCQueue", "SPMCQueue", "MPSCQueue", "MPMCQueue", "QueueClosed",
    "Pipeline", "Farm", "FFMap", "Skeleton",
    "LoadBalancer", "RoundRobinLB", "OnDemandLB", "BroadcastLB",
    "AutoscaleLB",
    "FFGraph", "GraphError", "Deliver", "Runner", "HostRunner",
    "DeviceRunner", "HybridRunner", "A2ASkeleton",
    "seq", "pipeline", "farm", "ffmap", "all_to_all",
    "CostEstimate", "Placement", "annotate", "place", "emit",
    "compile_graph",
    "JaxAccelerator", "ShardingPlan", "single_device_plan", "DEFAULT_RULES",
    "device", "perf_model",
]
