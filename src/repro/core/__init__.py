"""Core of the framework — FastFlow's layered streaming-network model,
adapted from shared-memory multicores to TPU pods, unified behind one
composable *building blocks* graph API.

Layer 1-2 (``core.queues``): lock-free SPSC ring buffers, composed into
SPMC / MPSC / MPMC networks — the channels every host skeleton runs over.

Layer 3 (``core.node``, ``core.skeletons``): the paper-faithful host
runtime — ``ff_node`` (``svc``/``svc_init``/``svc_end``), ``Pipeline``,
``Farm`` (emitter / collector / load balancers / on-demand), ``FFMap``,
``wrap_around`` feedback, and the accelerator mode
(``run_then_freeze`` / ``offload`` / ``load_result`` / ``FF_EOS`` / ``wait``).

Building blocks (``core.graph``): the declarative front door.  Programs are
written as an ``FFGraph`` of composable blocks — ``seq``, ``pipeline``,
``farm``, ``ffmap``, ``all_to_all`` (FastFlow 3's ``ff_a2a``), plus
``wrap_around`` feedback — normalised by ``optimize()`` (pipeline
flattening, collector-emitter collapse, farm/pipeline fusion) and executed
through the single polymorphic ``lower(plan)``: ``plan=None`` lowers onto
host threads over the SPSC networks; a ``ShardingPlan`` lowers pure
farm/pipeline graphs onto the JAX mesh via ``core.device`` (shard_map farms,
jit+vmap stages — feedback and all_to_all device lowering are roadmap items;
use ``core.device.feedback_scan``/``tensor_map`` directly meanwhile).  The
data pipeline, the serving engine, and the launch entry points are all
expressed as FFGraph programs.

Device side: ``core.plan`` maps logical tensor axes onto mesh axes,
``core.device`` holds the mesh lowerings, ``core.accelerator`` treats a
compiled SPMD step as an offload target, and ``core.perf_model`` extends the
paper's Sec. 13 cost model with a TPU roofline.
"""

from .node import EOS, GO_ON, FFNode, FnNode
from .queues import MPMCQueue, MPSCQueue, QueueClosed, SPMCQueue, SPSCQueue
from .skeletons import (BroadcastLB, Farm, FF_EOS, FFMap, LoadBalancer,
                        OnDemandLB, Pipeline, RoundRobinLB, Skeleton)
from .graph import (A2ASkeleton, Deliver, FFGraph, GraphError, Runner,
                    all_to_all, farm, ffmap, pipeline, seq)
from .graph import HostRunner, DeviceRunner
from .accelerator import JaxAccelerator
from .plan import DEFAULT_RULES, ShardingPlan, single_device_plan
from . import device, perf_model

__all__ = [
    "EOS", "GO_ON", "FF_EOS", "FFNode", "FnNode",
    "SPSCQueue", "SPMCQueue", "MPSCQueue", "MPMCQueue", "QueueClosed",
    "Pipeline", "Farm", "FFMap", "Skeleton",
    "LoadBalancer", "RoundRobinLB", "OnDemandLB", "BroadcastLB",
    "FFGraph", "GraphError", "Deliver", "Runner", "HostRunner",
    "DeviceRunner", "A2ASkeleton",
    "seq", "pipeline", "farm", "ffmap", "all_to_all",
    "JaxAccelerator", "ShardingPlan", "single_device_plan", "DEFAULT_RULES",
    "device", "perf_model",
]
