# The paper's primary contribution — the FastFlow structured-parallel
# skeleton framework, adapted from shared-memory multicores to TPU pods.
#
# Host layer (paper-faithful API): queues, ff_node, Pipeline/Farm/FFMap,
# load balancers, feedback, accelerator mode.
# Device layer: skeleton lowering onto a JAX mesh (core.device), the
# logical-axis sharding plan (core.plan), and the Sec. 13 performance
# model extended with a TPU roofline (core.perf_model).

from .node import EOS, GO_ON, FFNode, FnNode
from .queues import MPMCQueue, MPSCQueue, QueueClosed, SPMCQueue, SPSCQueue
from .skeletons import (BroadcastLB, Farm, FF_EOS, FFMap, LoadBalancer,
                        OnDemandLB, Pipeline, RoundRobinLB, Skeleton)
from .accelerator import JaxAccelerator
from .plan import DEFAULT_RULES, ShardingPlan, single_device_plan
from . import device, perf_model

__all__ = [
    "EOS", "GO_ON", "FF_EOS", "FFNode", "FnNode",
    "SPSCQueue", "SPMCQueue", "MPSCQueue", "MPMCQueue", "QueueClosed",
    "Pipeline", "Farm", "FFMap", "Skeleton",
    "LoadBalancer", "RoundRobinLB", "OnDemandLB", "BroadcastLB",
    "JaxAccelerator", "ShardingPlan", "single_device_plan", "DEFAULT_RULES",
    "device", "perf_model",
]
