"""Adaptive runtime — close the stats -> placement loop at runtime.

The staged compiler (core/compiler.py) chooses farm widths and
thread/process/device placement ONCE, at ``compile()``, from
startup-calibrated constants; every runner exposes ``stats()`` (per-node
service-time EMA, items, lane depths) but until now nothing consumed them
while the network ran.  This module is the consumer — the FastFlow
accelerator picture (paper Sec. 9) taken to its conclusion: a running
streaming network is a *service* whose configuration is continuously
re-derived from what the service actually observes.

Three mechanisms, composed:

- :class:`AdaptiveFarmNode` — the reconfigurable farm stage
  ``compile(adaptive=True)`` emits for every eligible farm.  ONE host node
  whose *engine* is either a thread-tier farm
  (:class:`~repro.core.skeletons.ThreadFarmNode`) or the process-tier
  :class:`~repro.core.process.ProcessFarmNode` — both sequence-ordered,
  both drainable — behind the node's ordinary boundary queues.  Its
  reconfigure ops: ``set_active`` (live width change: moves the routing
  boundary between 1 and the built width, the AutoscaleLB mechanism driven
  externally) and ``migrate`` (live tier change: drain the current engine
  to a quiescent boundary with an EOS-style barrier on its lanes, hot-swap
  the engine for the other tier's lowering — reusing the ProcessFarmNode
  build path, no new worker machinery — and resume; the stream
  back-pressures on the node's bounded input lane meanwhile, and output
  order is exactly input order on both sides of the swap).

- :class:`Supervisor` — samples the uniform
  :class:`~repro.core.graph.StageHandle` surface across a runner's stages
  every ``interval`` seconds and acts on the reconfigurable ones:

  * **width policy** (the AutoscaleLB thresholds, generalized to any
    adaptive farm on either tier): mean active-lane depth above ``hi``
    activates one more worker, below ``lo`` retires one;
  * **migration policy**: a thread-placed farm whose workers are
    demonstrably serializing on the GIL (``gil_ratio`` = CPU/wall of the
    worker calls well below 1 under >=2 concurrently active workers) and
    whose process-tier estimate ``max(cpu_ema / width, hop)`` beats the
    observed per-item delivery time past a hysteresis margin migrates
    thread -> process; a process-placed farm whose observed per-item time
    has collapsed into the shm hop (hop-dominated: the channel costs more
    than it buys) migrates back to threads;
  * **cost-model refinement**: snapshots feed
    :func:`~repro.core.perf_model.observe`, so measured service times, GIL
    signals, and hop costs flow back into the calibration cache and the
    *next* ``compile()``'s ``place()`` starts from history instead of a
    fresh sample probe — calibration stops being a startup-only event.

Disabled (no supervisor started, ``adaptive=False``), nothing here runs and
compiled graphs behave exactly as before.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from . import perf_model as pm
from .graph import GraphError, Runner, StageHandle
from .node import FFNode
from .process import ProcessFarmNode
from .skeletons import ThreadFarmNode

_TIERS = ("host", "host_process")


@dataclasses.dataclass
class ReplacementEvent:
    """One supervisor/stage action, for reports and tests."""

    t: float                    # wall-clock time of the event
    stage: str                  # stage label
    kind: str                   # "migrate" | "grow" | "shrink"
    detail: str                 # human-readable what/why
    latency_ms: Optional[float] = None

    def __str__(self) -> str:
        lat = f" ({self.latency_ms:.1f}ms)" if self.latency_ms else ""
        return f"[{self.kind}] {self.stage}: {self.detail}{lat}"


@dataclasses.dataclass
class SLOPolicy:
    """Overload policy for SLO-controllable stages (the serving engine's
    admission stage): how hard to push back as backlog approaches capacity,
    instead of queueing unboundedly.

    Pressure is the backlog/capacity ratio of the stage's ``stats()["slo"]``
    block.  Below ``degrade_at`` the stage runs unconstrained (level 0); in
    [``degrade_at``, ``shed_at``) it *degrades* (level 1: new requests'
    ``max_new_tokens`` capped at ``degrade_tokens``, early-exit thresholds
    tightened by ``exit_margin``); at ``shed_at`` and above it *sheds*
    (level 2: new submissions rejected with a typed ``Overloaded`` result).
    The controlled stage always enforces its own hard cap inline — the
    supervisor policy moves the soft thresholds below it."""

    degrade_at: float = 0.5
    shed_at: float = 0.9
    degrade_tokens: int = 8
    exit_margin: float = 0.5

    def level(self, backlog: int, capacity: int) -> int:
        ratio = backlog / max(1, capacity)
        if ratio >= self.shed_at:
            return 2
        if ratio >= self.degrade_at:
            return 1
        return 0


class AdaptiveFarmNode(FFNode):
    """A farm stage that can be re-placed *while the stream runs*.

    To the surrounding network this is one ordinary host node (like
    :class:`~repro.core.process.ProcessFarmNode`); internally it delegates
    to a tier *engine* — :class:`~repro.core.skeletons.ThreadFarmNode` or
    :class:`~repro.core.process.ProcessFarmNode` — that shares one surface:
    ``svc`` routes an item in, a collector thread delivers sequence-ordered
    results via the node's output, ``svc_end`` drains every accepted item
    (or surfaces the error) before returning, ``set_active`` moves the
    routing boundary.

    ``migrate(tier)`` is the hot swap: take the node lock (pausing intake —
    upstream back-pressures on the node's bounded input queue), drain the
    current engine to its quiescent boundary via ``svc_end`` (the EOS-style
    barrier), build the other tier's engine through its normal constructor,
    bind it to the same output, and resume.  Output order is globally
    input order because both engines are sequence-ordered and the drain is
    a full barrier.  A worker crash during the drain aborts the swap and
    surfaces exactly as it would mid-stream (``WorkerCrashed`` et al.)."""

    ff_adaptive = True
    _engine: Optional[FFNode] = None

    def __init__(self, fn: Callable, width: int,
                 pre: Optional[Callable] = None,
                 post: Optional[Callable] = None, tier: str = "host",
                 capacity: int = 64, slot_bytes: int = 1 << 16,
                 label: str = "adaptive_farm", can_process: bool = True,
                 thread_est_s: Optional[float] = None,
                 transport=None):
        super().__init__()
        if tier not in _TIERS:
            raise GraphError(f"adaptive tier must be one of {_TIERS}")
        if tier == "host_process" and not can_process:
            raise GraphError(f"{label}: worker is not process-eligible but "
                             "was placed on the process tier")
        self._fn = fn
        self._width = max(1, int(width))
        self._pre = pre
        self._post = post
        self._cap = capacity
        self._slot_bytes = slot_bytes
        self._transport = transport
        self._label = label
        self._can_process = can_process
        self.thread_est_s = thread_est_s
        self._tier = tier
        self._reconf_lock = threading.RLock()
        self._ended = False
        self.migrations: List[ReplacementEvent] = []
        self._error_: Optional[BaseException] = None
        self._engine = self._build_engine(tier, self._width)

    # surface the engine's asynchronous failures (its collector thread sets
    # engine.error) through the node's own error attribute, which is what
    # the runner's _error() walk and svc-raise path consume
    @property
    def error(self) -> Optional[BaseException]:
        if self._error_ is not None:
            return self._error_
        eng = self._engine
        return eng.error if eng is not None else None

    @error.setter
    def error(self, e: Optional[BaseException]) -> None:
        self._error_ = e

    @property
    def tier(self) -> str:
        return self._tier

    @property
    def width(self) -> int:
        return self._width

    @property
    def active_workers(self) -> int:
        eng = self._engine
        return eng.active_workers if eng is not None else 0

    def _build_engine(self, tier: str, active: int) -> FFNode:
        fns = [self._fn] * self._width
        if tier == "host_process":
            eng = ProcessFarmNode(fns, pre=self._pre, post=self._post,
                                  capacity=self._cap,
                                  slot_bytes=self._slot_bytes,
                                  transport=self._transport,
                                  label=f"{self._label}/process")
        else:
            eng = ThreadFarmNode(fns, pre=self._pre, post=self._post,
                                 capacity=self._cap,
                                 label=f"{self._label}/thread")
        eng.set_active(active)
        return eng

    # -- node protocol --------------------------------------------------------
    def svc_init(self) -> int:
        with self._reconf_lock:
            self._engine._bind(self._out, self._id)
            return self._engine.svc_init()

    def svc(self, item: Any) -> Any:
        # the lock is the migration barrier: an item is either fully handed
        # to the old engine (and drained before the swap) or routed to the
        # new one — never dropped between engines
        with self._reconf_lock:
            if self._error_ is not None:
                raise self._error_
            return self._engine.svc(item)

    def svc_end(self) -> None:
        with self._reconf_lock:
            self._ended = True
            eng = self._engine
            if eng is not None:
                eng.svc_end()
                if self._error_ is None and eng.error is not None:
                    self._error_ = eng.error

    # -- reconfigure ops ------------------------------------------------------
    def set_active(self, k: int) -> None:
        with self._reconf_lock:
            self._engine.set_active(k)

    def can_migrate(self, target: str) -> bool:
        return target in _TIERS and (target != "host_process"
                                     or self._can_process)

    def migrate(self, target: str) -> bool:
        """Drain-and-swap to ``target`` ("host" | "host_process"); returns
        True when a swap happened, False when already there.  Raises the
        stage's error when a worker failed before/while draining — the swap
        is aborted and the error surfaces exactly as a mid-stream failure
        would."""
        if target not in _TIERS:
            raise GraphError(f"migrate target must be one of {_TIERS} "
                             f"(got {target!r})")
        if target == "host_process" and not self._can_process:
            raise GraphError(f"{self._label}: worker fn is not picklable — "
                             "cannot migrate to the process tier")
        with self._reconf_lock:
            if self._error_ is not None:
                raise self._error_
            if target == self._tier:
                return False
            if self._ended:
                # the stream finished (svc_end drained and released the
                # engine) while this migrate was queued on the lock: there
                # is nothing left to re-place
                return False
            t0 = time.perf_counter()
            old = self._engine
            old.svc_end()             # the EOS-style barrier: drain + join
            if old.error is not None:
                # crash during the drain: abort the swap, surface the error
                self._error_ = old.error
                raise self._error_
            active = old.active_workers
            eng = self._build_engine(target, active)
            eng._bind(self._out, self._id)
            if eng.svc_init() < 0:
                raise RuntimeError(f"{self._label}: engine svc_init failed")
            self._engine = eng
            from_tier, self._tier = self._tier, target
            dt_ms = (time.perf_counter() - t0) * 1e3
            self.migrations.append(ReplacementEvent(
                time.time(), self._label, "migrate",
                f"{from_tier} -> {target}", dt_ms))
            return True

    # -- stats ----------------------------------------------------------------
    def node_stats(self) -> dict:
        with self._reconf_lock:
            s = self._engine.node_stats()
            s["node"] = self._label
            s["tier"] = self._tier
            s["adaptive"] = True
            s["max_width"] = self._width
            s["migrations"] = len(self.migrations)
            return s

    def make_handle(self, desc: Optional[str] = None) -> "AdaptiveStageHandle":
        return AdaptiveStageHandle(desc or self._label, self)


class AdaptiveStageHandle(StageHandle):
    """Reconfigurable :class:`~repro.core.graph.StageHandle` over an
    :class:`AdaptiveFarmNode`: live ``resize`` and ``migrate``."""

    reconfigurable = True

    def __init__(self, desc: str, node: AdaptiveFarmNode):
        super().__init__(desc, node)
        self.node = node

    @property
    def tier(self) -> str:
        return self.node.tier

    @property
    def max_width(self) -> int:
        return self.node.width

    @property
    def events(self) -> List[ReplacementEvent]:
        return self.node.migrations

    def stats(self) -> dict:
        return self.node.node_stats()

    def can_migrate(self, target: str) -> bool:
        return self.node.can_migrate(target)

    def resize(self, width: int) -> bool:
        self.node.set_active(width)
        return True

    def migrate(self, target: str) -> bool:
        return self.node.migrate(target)


class Supervisor:
    """Sample every stage of a runner; resize/migrate the adaptive ones;
    feed the cost model.

    ``start()`` spawns a daemon sampling thread; ``stop()`` joins it and
    persists what was learned into the calibration cache
    (``perf_model.observe(write=True)``).  All policies are per-stage and
    carry hysteresis + a per-stage cooldown so the supervisor cannot flap.
    A supervisor over a runner with no adaptive stages is a pure observer —
    useful on its own, since the observations refine later compiles.
    Overlapped device boundaries (``boundary_tunable`` handles) get their
    in-flight window depth retuned live from observed boundary stall stats
    (``_boundary_act``).

    Policy knobs (defaults chosen to act within a few sampling windows
    without reacting to one noisy sample): ``hi``/``lo`` are the
    AutoscaleLB-style mean-lane-depth thresholds for growing/shrinking the
    active worker set; ``gil_threshold`` is the CPU/wall ratio below which
    thread workers count as GIL-serialized; ``hysteresis`` is the margin the
    other tier's estimate must win by; ``hop_factor`` marks a process stage
    hop-dominated when its observed per-item time falls under ``hop_factor
    * hop``."""

    def __init__(self, runner: Runner, interval: float = 0.05,
                 resize: bool = True, migrate: bool = True,
                 observe: bool = True, hi: float = 2.0, lo: float = 0.25,
                 gil_threshold: float = 0.8, hysteresis: float = 0.8,
                 hop_factor: float = 3.0, cooldown_s: float = 1.0,
                 min_window_items: int = 4, observe_every: int = 10,
                 slo: Optional[SLOPolicy] = None):
        self.runner = runner
        self.handles: List[StageHandle] = list(runner.stage_handles())
        self.slo = slo or SLOPolicy()
        self._slo_levels: Dict[int, int] = {}
        self._observed_final = False
        self.interval = interval
        self.resize_enabled = resize
        self.migrate_enabled = migrate
        self.observe_enabled = observe
        self.hi = hi
        self.lo = lo
        self.gil_threshold = gil_threshold
        self.hysteresis = hysteresis
        self.hop_factor = hop_factor
        self.cooldown_s = cooldown_s
        self.min_window_items = min_window_items
        self.observe_every = max(1, observe_every)
        self.events: List[ReplacementEvent] = []
        self.samples = 0
        self.observed_facts = 0
        self.loop_time_s = 0.0          # supervisor overhead accounting
        self._win: Dict[int, tuple] = {}
        self._bwin: Dict[int, tuple] = {}   # boundary stall windows
        self._cooldown: Dict[int, float] = {}
        self._ticks = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "Supervisor":
        if self._thread is not None:
            raise RuntimeError("supervisor already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="ff-supervisor")
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        """Stop the sampling loop and flush the final observation.
        Idempotent: a second (or concurrent) stop joins nothing and does not
        re-observe — callers may stop unconditionally, whether or not the
        supervisor was ever started."""
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout)
        if self.observe_enabled and not self._observed_final:
            self._observed_final = True
            snaps = []
            for h in self.handles:
                try:
                    snaps.append(h.stats())
                except Exception:       # noqa: BLE001 - stage already gone
                    pass
            self.observed_facts += pm.observe({"stages": snaps}, write=True)

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            t0 = time.perf_counter()
            try:
                self._tick()
            except Exception:           # noqa: BLE001 - never kill sampling
                pass
            self.loop_time_s += time.perf_counter() - t0

    # -- one sampling tick ----------------------------------------------------
    def _tick(self) -> None:
        snaps = []
        for i, h in enumerate(self.handles):
            try:
                s = h.stats()
            except Exception:           # noqa: BLE001 - stage already gone
                continue
            snaps.append(s)
            self.samples += 1
            if h.reconfigurable:
                self._act(i, h, s)
            if getattr(h, "slo_controllable", False):
                self._slo_act(i, h, s)
            if getattr(h, "boundary_tunable", False):
                self._boundary_act(i, h, s)
        self._ticks += 1
        if self.observe_enabled and self._ticks % self.observe_every == 0:
            self.observed_facts += pm.observe({"stages": snaps})

    def _record(self, stage: str, kind: str, detail: str,
                latency_ms: Optional[float] = None) -> None:
        self.events.append(ReplacementEvent(time.time(), stage, kind, detail,
                                            latency_ms))

    def _slo_act(self, i: int, h: StageHandle, s: dict) -> None:
        """Overload policy for SLO-controllable stages: derive the pressure
        level from the stage's backlog-vs-capacity ratio and push it down
        through ``set_pressure`` — 0 unconstrained, 1 degrade (cap tokens,
        tighten early exit), 2 shed (reject new submissions with
        ``Overloaded``).  The stage's own inline hard cap stays the
        backstop; this moves the soft thresholds under it."""
        slo = s.get("slo") or {}
        backlog = int(slo.get("backlog", 0) or 0)
        capacity = int(slo.get("capacity", 0) or 0)
        if capacity <= 0:
            return
        level = self.slo.level(backlog, capacity)
        prev = self._slo_levels.get(i, 0)
        if level == prev:
            return
        self._slo_levels[i] = level
        try:
            h.set_pressure(level, self.slo)
        except Exception:               # noqa: BLE001 - stage already gone
            return
        kind = {0: "restore", 1: "degrade", 2: "shed"}[level]
        self._record(s.get("node", h.desc), kind,
                     f"backlog {backlog}/{capacity} "
                     f"({backlog / max(1, capacity):.0%}): pressure "
                     f"{prev} -> {level}")

    def _boundary_act(self, i: int, h: StageHandle, s: dict) -> None:
        """Window policy for overlapped device boundaries
        (:class:`~repro.core.compiler.DeviceBoundaryHandle`): watch the
        *stall* share of the boundary's drain time over the sampling
        window — drain paid while the in-flight window was full means the
        host had to wait for device work that a deeper window would have
        hidden, so grow ``inflight``; a window that never stalls is deeper
        than the pipeline needs, so shrink it back.  Same hysteresis
        discipline as the tier policies: per-stage cooldown, a minimum
        number of retired items per window, and a dead band between the
        grow and shrink thresholds so the depth cannot flap."""
        b = s.get("boundary") or {}
        if b.get("mode") != "overlapped":
            return
        now = time.monotonic()
        retired = int(b.get("retired", 0) or 0)
        stall = float(b.get("stall_s", 0.0) or 0.0)
        drain = float(b.get("drain_s", 0.0) or 0.0)
        prev = self._bwin.get(i)
        self._bwin[i] = (now, retired, stall, drain)
        if prev is None or now < self._cooldown.get(i, 0.0):
            return
        d_items = retired - prev[1]
        d_stall, d_drain = stall - prev[2], drain - prev[3]
        if d_items < self.min_window_items or d_drain <= 0.0:
            return
        frac = d_stall / d_drain
        k = int(b.get("inflight", 2) or 2)
        stage = s.get("node", h.desc)
        if frac > 0.5 and k < 8:
            h.set_window(inflight=k + 1)
            self._record(stage, "retune",
                         f"boundary stalled {frac:.0%} of drain over "
                         f"{d_items} items: inflight {k} -> {k + 1}")
        elif frac < 0.05 and k > 2:
            h.set_window(inflight=k - 1)
            self._record(stage, "retune",
                         f"boundary never stalls ({frac:.0%}): inflight "
                         f"{k} -> {k - 1}")
        else:
            return
        self._cooldown[i] = now + self.cooldown_s
        self._bwin.pop(i, None)         # the old window spans two depths

    def _act(self, i: int, h: StageHandle, s: dict) -> None:
        now = time.monotonic()
        # observed per-item delivery time over the sampling window
        delivered = int(s.get("delivered", 0) or 0)
        prev = self._win.get(i)
        self._win[i] = (now, delivered)
        t_obs = None
        if prev is not None and delivered - prev[1] >= self.min_window_items:
            t_obs = (now - prev[0]) / (delivered - prev[1])
        active = int(s.get("active", 0) or 0)
        depths = s.get("lane_depths") or []
        depth = (sum(depths[:active]) / active) if active and depths else 0.0
        stage = s.get("node", h.desc)
        max_w = getattr(h, "max_width", active)
        # -- width policy (AutoscaleLB generalized) ------------------------
        if self.resize_enabled and active:
            if depth > self.hi and active < max_w:
                h.resize(active + 1)
                self._record(stage, "grow",
                             f"mean lane depth {depth:.1f} > {self.hi}: "
                             f"active {active} -> {active + 1}")
            elif depth < self.lo and active > 1:
                h.resize(active - 1)
                self._record(stage, "shrink",
                             f"mean lane depth {depth:.2f} < {self.lo}: "
                             f"active {active} -> {active - 1}")
        # -- migration policy ----------------------------------------------
        if not self.migrate_enabled or t_obs is None \
                or now < self._cooldown.get(i, 0.0):
            return
        calib = pm.get_calibration(measure=False)
        tier = s.get("tier")
        if tier == "host" and h.can_migrate("host_process"):
            cpu = float(s.get("svc_cpu_ema_s", 0.0) or 0.0)
            ratio = s.get("gil_ratio")
            # the farm lanes batch their hops, so charge the amortized cost
            hop = calib.proc_hop_effective_s()
            proc_est = max(cpu / max(1, max_w), hop)
            # the GIL-serialization evidence, either form: (a) worker calls'
            # CPU/wall ratio well below 1 under >=2 concurrently active
            # workers (they wait on the GIL, not on work), or (b) observed
            # per-item throughput no better than one worker's serial CPU
            # time even though the stage could go wider — threads are
            # buying nothing
            serialized = (ratio is not None and active >= 2
                          and ratio < self.gil_threshold) \
                or (max_w >= 2 and t_obs >= 0.8 * cpu)
            # migrate only when the work is also (c) substantively
            # CPU-bound — not blocking/IO, whose low CPU/wall ratio looks
            # like GIL wait but gains nothing from processes, (d)
            # backlogged (the stage is the bottleneck), and (e) predicted
            # to win past the hysteresis margin
            if (cpu > 5.0 * hop and serialized
                    and depth >= 1.0
                    and proc_est < self.hysteresis * t_obs):
                self._migrate(i, h, "host_process",
                              f"GIL-serialized (cpu/wall "
                              f"{ratio if ratio is None else round(ratio, 2)}"
                              f", observed {t_obs*1e6:.0f}us/item vs cpu "
                              f"{cpu*1e6:.0f}us): proc est "
                              f"{proc_est*1e6:.0f}us wins")
                # the decision was costed at full width: grant it, the
                # depth policy will shrink an over-provisioned farm later
                if h.tier == "host_process":
                    h.resize(max_w)
        elif tier == "host_process":
            hop = float(s.get("hop_ema_s", 0.0) or 0.0) \
                or calib.proc_hop_effective_s()
            cpu = float(s.get("svc_cpu_ema_s", 0.0) or 0.0)
            if cpu > 0.0:
                # true-service-time comparison: the workers now ship their
                # own CPU clocks back over the result lanes (WorkerStats),
                # so the policy compares what a thread farm would actually
                # cost — serial cpu per item, floored by the thread-queue
                # hop — against observed delivery, past the same hysteresis
                # margin the forward policy uses
                thread_est = max(cpu, calib.queue_hop_s)
                if thread_est < self.hysteresis * t_obs:
                    self._migrate(i, h, "host",
                                  f"worker cpu {cpu*1e6:.0f}us/item: thread "
                                  f"est {thread_est*1e6:.0f}us beats "
                                  f"observed {t_obs*1e6:.0f}us/item")
                return
            # no worker CPU record yet (short stream, stats in flight):
            # fall back to the hop-domination heuristic.  Per-WORKER
            # service time, not per-item delivery gap: a wide,
            # well-parallelized farm delivers every t_task/width — frequent
            # deliveries alone must not read as "hop-dominated" (that would
            # ping-pong against the forward policy above, which only fires
            # for cpu > 5x hop; this fires only below hop_factor x hop)
            per_worker = t_obs * max(1, active)
            if per_worker < self.hop_factor * hop:
                self._migrate(i, h, "host",
                              f"hop-dominated: {per_worker*1e6:.0f}us/item "
                              f"per worker < {self.hop_factor:.0f}x shm hop "
                              f"{hop*1e6:.0f}us")

    def _migrate(self, i: int, h: StageHandle, target: str,
                 why: str) -> None:
        stage = h.desc
        t0 = time.perf_counter()
        try:
            moved = h.migrate(target)
        except Exception as e:          # noqa: BLE001 - error surfaces on the
            #                             stage/runner; record and stand down
            self._record(stage, "migrate",
                         f"-> {target} failed: {e!r}")
            self._cooldown[i] = time.monotonic() + 10.0 * self.cooldown_s
            return
        if moved:
            self._record(stage, "migrate", f"-> {target}: {why}",
                         (time.perf_counter() - t0) * 1e3)
        self._cooldown[i] = time.monotonic() + self.cooldown_s
        self._win.pop(i, None)          # the old window spans two tiers

    # -- reporting ------------------------------------------------------------
    def stats(self) -> dict:
        return {"samples": self.samples, "ticks": self._ticks,
                "events": len(self.events),
                "observed_facts": self.observed_facts,
                "loop_time_s": self.loop_time_s}
