"""L3 — streaming-network patterns (FastFlow Secs. 2, 4-12).

Host-side, paper-faithful skeletons: ``Pipeline`` and ``Farm`` (with emitter /
collector / custom load balancers / on-demand scheduling / broadcast), the
``wrap_around`` feedback channel, arbitrary nesting (farms of pipelines,
pipelines of farms), and the *accelerator* usage mode
(``run_then_freeze`` / ``offload`` / ``load_result`` / ``FF_EOS`` / ``wait``).

These host skeletons run real threads over the SPSC networks of
core/queues.py and carry the data pipeline and the serving front-end of the
framework.  Their device-side lowering (the same patterns expressed as
pjit/shard_map programs over a TPU mesh) lives in core/device.py; the bridge
that treats a compiled SPMD step as a farm worker is core/accelerator.py.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, List, Optional, Sequence

from .node import EOS, GO_ON, FFNode, FnNode, spawn_drainer
from .queues import MPSCQueue, QueueClosed, SPMCQueue, SPSCQueue

FF_EOS = EOS  # paper's name for the end-of-stream mark


# ---------------------------------------------------------------------------
# Load balancers (paper Sec. 8.3)
# ---------------------------------------------------------------------------
class LoadBalancer:
    """FastFlow ``ff_loadbalancer``: decides the worker for each task.

    Subclass and override ``selectworker`` for custom policies, or call
    ``set_victim(i)`` from an emitter right before ``ff_send_out`` (Sec. 8.3).
    ``BROADCAST`` sends the task to every worker (Sec. 8.3.1 / MISD).
    """

    BROADCAST = -1

    def __init__(self):
        self._victim: Optional[int] = None
        self.nworkers: int = 0
        self._lanes: Optional[SPMCQueue] = None

    def _attach(self, lanes: SPMCQueue) -> None:
        self._lanes = lanes
        self.nworkers = len(lanes.lanes)

    def getnworkers(self) -> int:
        return self.nworkers

    def set_victim(self, idx: int) -> None:
        self._victim = idx

    def broadcast_task(self, task: Any) -> None:
        self._lanes.broadcast(task)

    def selectworker(self, task: Any) -> int:
        raise NotImplementedError

    def route(self, task: Any) -> None:
        if self._victim is not None:
            idx, self._victim = self._victim, None
        else:
            idx = self.selectworker(task)
        if idx == self.BROADCAST:
            self._lanes.broadcast(task)
        else:
            self._lanes.push_to(idx, task)


class RoundRobinLB(LoadBalancer):
    """Default farm scheduling (paper Sec. 8)."""

    def __init__(self):
        super().__init__()
        self._next = 0

    def selectworker(self, task: Any) -> int:
        i = self._next
        self._next = (self._next + 1) % self.nworkers
        return i


class OnDemandLB(LoadBalancer):
    """Auto-scheduling approximation (paper Sec. 8.3.2): first worker whose
    queue length is <= threshold."""

    def __init__(self, threshold: int = 1):
        super().__init__()
        self.threshold = threshold

    def route(self, task: Any) -> None:
        if self._victim is not None:
            idx, self._victim = self._victim, None
            self._lanes.push_to(idx, task)
        else:
            self._lanes.push_ondemand(task, self.threshold)

    def selectworker(self, task: Any) -> int:  # pragma: no cover
        return 0


class BroadcastLB(LoadBalancer):
    """Every task goes to every worker (MISD farm, Sec. 8.3.1)."""

    def selectworker(self, task: Any) -> int:
        return self.BROADCAST


class AutoscaleLB(LoadBalancer):
    """Autoscaling farm schedule: grow/shrink the *active* worker set from
    observed queue depth.

    All workers exist from the start (a parked worker blocked on an empty
    lane costs nothing — FastFlow's blocking mode); scaling moves the
    round-robin routing boundary between ``min_workers`` and
    ``max_workers``.  Every ``adjust_every`` routed tasks the balancer looks
    at the mean depth of the active lanes: above ``hi`` it activates one
    more worker, below ``lo`` it retires the last one (items already queued
    on a retired lane still get processed — the worker only stops receiving
    new work).

    The balancer is backend-agnostic: it only needs an attached lane bundle
    with a ``lanes`` list of ``len()``-able queues.  The thread farm
    attaches its ``SPMCQueue``; the process farm
    (``core.process.ProcessFarmNode`` with ``autoscale=True``) attaches its
    ``ShmSPMCQueue``, so the same depth signal scales OS-process workers
    parked on their shm idle gates — no process is ever forked at
    runtime."""

    def __init__(self, min_workers: int = 1, max_workers: Optional[int] = None,
                 hi: float = 2.0, lo: float = 0.25, adjust_every: int = 16):
        super().__init__()
        self.min_workers = max(1, min_workers)
        self.max_workers = max_workers
        self.hi = hi
        self.lo = lo
        self.adjust_every = max(1, adjust_every)
        self.cur = self.min_workers
        self.grown = 0
        self.shrunk = 0
        self._routed = 0
        self._next = 0

    def _attach(self, lanes: SPMCQueue) -> None:
        super()._attach(lanes)
        if self.max_workers is None:
            self.max_workers = self.nworkers
        self.max_workers = min(self.max_workers, self.nworkers)
        self.cur = min(max(self.cur, self.min_workers), self.max_workers)

    def _adjust(self) -> None:
        depth = sum(len(self._lanes.lanes[i]) for i in range(self.cur)) / self.cur
        if depth > self.hi and self.cur < self.max_workers:
            self.cur += 1
            self.grown += 1
        elif depth < self.lo and self.cur > self.min_workers:
            self.cur -= 1
            self.shrunk += 1

    def selectworker(self, task: Any) -> int:
        self._routed += 1
        if self._routed % self.adjust_every == 0:
            self._adjust()
        i = self._next % self.cur
        self._next = (i + 1) % self.cur
        return i


# ---------------------------------------------------------------------------
# Skeleton base: anything that can sit in a streaming network
# ---------------------------------------------------------------------------
class Skeleton:
    """Common protocol so skeletons nest arbitrarily (paper Sec. 10)."""

    def __init__(self):
        self._out: Optional[Callable[[Any], None]] = None
        self._in_q: Optional[SPSCQueue] = None
        self._running = False
        self._t0 = 0.0
        self._t1 = 0.0
        self._wrap = False

    # wiring -----------------------------------------------------------------
    def _bind(self, out_fn: Optional[Callable[[Any], None]], node_id: int = -1) -> None:
        self._out = out_fn

    def _make_input(self, capacity: int = 512) -> SPSCQueue:
        if self._in_q is None:
            self._in_q = SPSCQueue(capacity)
        return self._in_q

    def wrap_around(self) -> None:
        """Feedback channel (paper Sec. 11): route this skeleton's output
        stream back to its own input.  Only valid for the outermost skeleton."""
        self._wrap = True

    # lifecycle ----------------------------------------------------------------
    def _start(self, in_q: Optional[SPSCQueue]) -> None:
        raise NotImplementedError

    def _join(self, timeout: Optional[float] = None) -> None:
        raise NotImplementedError

    def _error(self) -> Optional[BaseException]:
        raise NotImplementedError

    def _alive(self) -> bool:
        raise NotImplementedError

    # paper API ---------------------------------------------------------------
    def run_and_wait_end(self) -> int:
        self._t0 = time.perf_counter()
        if self._wrap:
            q = self._make_input()
            self._bind_feedback(q)
        self._start(self._in_q)
        self._join()
        self._t1 = time.perf_counter()
        return -1 if self._error() is not None else 0

    def run_then_freeze(self) -> int:
        """Accelerator mode (paper Sec. 9): start with an externally fed
        input stream; offload() pushes tasks, FF_EOS terminates."""
        self._t0 = time.perf_counter()
        q = self._make_input()
        self._results: SPSCQueue = SPSCQueue(4096)
        if self._out is None:
            self._bind(lambda item: self._results.push(item))
        self._start(q)
        self._running = True
        return 0

    def offload(self, task: Any) -> None:
        if self._in_q is None:
            raise RuntimeError("offload before run_then_freeze")
        self._in_q.push(task)

    def load_result(self, timeout: Optional[float] = None) -> tuple[bool, Any]:
        """Blocking result retrieval; returns (False, None) at end-of-stream."""
        item = self._results.pop(timeout)
        if item is EOS:
            return False, None
        return True, item

    def load_result_nb(self) -> tuple[bool, Any]:
        ok, item = self._results.try_pop()
        if not ok:
            return False, None
        if item is EOS:
            return False, None
        return True, item

    def wait(self, timeout: Optional[float] = None) -> int:
        self._join(timeout)
        self._t1 = time.perf_counter()
        self._running = False
        return -1 if self._error() is not None else 0

    def _bind_feedback(self, q: SPSCQueue) -> None:
        def feed(item: Any) -> None:
            if item is not EOS:
                q.push(item)
        self._bind(feed)

    def ffTime(self) -> float:
        """Milliseconds spent in the skeleton run (paper Sec. 14)."""
        return (self._t1 - self._t0) * 1e3

    def ffStats(self) -> dict:
        return {}

    def stats(self) -> dict:
        """Structured runtime stats (per-node service-time EMA, items
        processed, max observed lane depth) for ``runner.stats()``."""
        return {"type": type(self).__name__.lower()}


def _stat_of(x: Any) -> dict:
    """Stats for one network member: an FFNode or a nested Skeleton."""
    if isinstance(x, FFNode):
        return x.node_stats()
    if isinstance(x, Skeleton):
        return x.stats()
    return {}


def _as_runnable(obj) -> "Skeleton | FFNode":
    if isinstance(obj, (Skeleton, FFNode)):
        return obj
    if callable(obj):
        return FnNode(obj)
    raise TypeError(f"cannot use {obj!r} as a streaming-network node")


def _start_runnable(r, in_q, out_fn, node_id=0):
    r._bind(out_fn, node_id)
    r._start(in_q)


# ---------------------------------------------------------------------------
# Pipeline (paper Secs. 4-6)
# ---------------------------------------------------------------------------
class Pipeline(Skeleton):
    def __init__(self, *stages, capacity: int = 512):
        super().__init__()
        self._stages: List = [_as_runnable(s) for s in stages]
        self._cap = capacity
        self._qs: List[SPSCQueue] = []

    def add_stage(self, stage) -> "Pipeline":
        self._stages.append(_as_runnable(stage))
        return self

    def _start(self, in_q: Optional[SPSCQueue]) -> None:
        if not self._stages:
            raise RuntimeError("empty pipeline")
        n = len(self._stages)
        self._qs = [SPSCQueue(self._cap) for _ in range(n - 1)]
        out = self._out if self._out is not None else (lambda item: None)
        for i, st in enumerate(self._stages):
            stage_in = in_q if i == 0 else self._qs[i - 1]
            if i == n - 1:
                stage_out = out
            else:
                q = self._qs[i]
                stage_out = q.push
            _start_runnable(st, stage_in, stage_out, node_id=i)

    def _join(self, timeout: Optional[float] = None) -> None:
        for st in self._stages:
            st._join(timeout)

    def _error(self) -> Optional[BaseException]:
        for st in self._stages:
            e = st.error if isinstance(st, FFNode) else st._error()
            if e is not None:
                return e
        return None

    def _alive(self) -> bool:
        return any(st._alive() for st in self._stages)

    def ffStats(self) -> dict:
        return {f"stage{i}": getattr(s, "svc_calls", None)
                for i, s in enumerate(self._stages)}

    def stats(self) -> dict:
        return {"type": "pipeline",
                "stages": [_stat_of(s) for s in self._stages],
                "lane_max_depth": [q.max_depth for q in self._qs]}


# ---------------------------------------------------------------------------
# Farm (paper Secs. 8-9)
# ---------------------------------------------------------------------------
class _CollectorRunner:
    """Runs the collector node: drains worker lanes fairly, counts EOS from
    every worker before terminating (FastFlow collector semantics)."""

    def __init__(self, node: Optional[FFNode], mpsc: MPSCQueue,
                 out_fn: Callable[[Any], None], n_workers: int):
        import threading
        self.node = node
        self.mpsc = mpsc
        self.out = out_fn
        self.n_workers = n_workers
        self.error: Optional[BaseException] = None
        self.thread = threading.Thread(target=self._run, daemon=True,
                                       name="ff-collector")

    def _run(self) -> None:
        eos_seen = 0
        try:
            if self.node is not None and self.node.svc_init() < 0:
                raise RuntimeError("collector svc_init failed")
            while eos_seen < self.n_workers:
                item, _lane = self.mpsc.pop_any()
                if item is EOS:
                    eos_seen += 1
                    continue
                if self.node is None:
                    self.out(item)
                    continue
                self.node.svc_calls += 1
                res = self.node.svc(item)
                if res is EOS:
                    break
                if res is not GO_ON and res is not None:
                    self.out(res)
        except BaseException as e:  # noqa: BLE001
            self.error = e
            import traceback
            traceback.print_exc()
        finally:
            try:
                if self.node is not None:
                    self.node.svc_end()
            finally:
                self.out(EOS)
                # after closing the output stream, drain remaining worker
                # output until every EOS arrives so no worker wedges on this
                # collector's full lanes — whether it died or self-terminated
                if eos_seen < self.n_workers:
                    spawn_drainer(lambda: self.mpsc.pop_any()[0],
                                  self.n_workers - eos_seen)

    def start(self) -> None:
        self.thread.start()

    def join(self, timeout=None) -> None:
        self.thread.join(timeout)


class Farm(Skeleton):
    """Farm skeleton: optional emitter -> workers -> optional collector.

    - no collector: workers consolidate results in memory (paper Sec. 8.2);
    - ``set_scheduling_ondemand()``: auto-scheduling (Sec. 8.3.2);
    - pass a LoadBalancer subclass for custom policies (Sec. 8.3);
    - ``wrap_around()``: feedback for divide&conquer (Sec. 11);
    - accelerator usage via ``run_then_freeze``/``offload`` (Sec. 9).
    """

    def __init__(self, workers: Sequence = (), lb: Optional[LoadBalancer] = None,
                 capacity: int = 512):
        super().__init__()
        self._workers: List = [_as_runnable(w) for w in workers]
        self._emitter: Optional[FFNode] = None
        self._collector: Optional[FFNode] = None
        self._lb = lb or RoundRobinLB()
        self._cap = capacity
        self._col_runner: Optional[_CollectorRunner] = None

    # construction API (paper names) -----------------------------------------
    def add_workers(self, workers: Sequence) -> "Farm":
        self._workers.extend(_as_runnable(w) for w in workers)
        return self

    def add_emitter(self, node) -> "Farm":
        self._emitter = _as_runnable(node)
        return self

    def add_collector(self, node) -> "Farm":
        self._collector = _as_runnable(node)
        return self

    def set_scheduling_ondemand(self, threshold: int = 1) -> "Farm":
        self._lb = OnDemandLB(threshold)
        return self

    def getlb(self) -> LoadBalancer:
        return self._lb

    # runtime -----------------------------------------------------------------
    def _start(self, in_q: Optional[SPSCQueue]) -> None:
        if not self._workers:
            raise RuntimeError("farm with no workers")
        nw = len(self._workers)
        self._spmc = SPMCQueue(nw, self._cap)
        self._mpsc = MPSCQueue(nw, self._cap)
        self._lb._attach(self._spmc)
        out = self._out if self._out is not None else (lambda item: None)

        # collector side: always run a runner so EOS bookkeeping is uniform
        self._col_runner = _CollectorRunner(self._collector, self._mpsc, out, nw)
        self._col_runner.start()

        # workers: worker i reads lane i, writes mpsc lane i
        for i, w in enumerate(self._workers):
            lane_out = self._mpsc.lane(i)
            _start_runnable(w, self._spmc.lanes[i], lane_out.push, node_id=i)

        # emitter side
        def route(item: Any) -> None:
            if item is EOS:
                self._spmc.broadcast(EOS)
            else:
                self._lb.route(item)

        if self._emitter is not None:
            _start_runnable(self._emitter, in_q, route, node_id=-2)
        elif in_q is not None:
            # headless farm fed by an input stream: a tiny forwarder thread
            fwd = FnNode(lambda t: t)
            _start_runnable(fwd, in_q, route, node_id=-2)
            self._fwd = fwd
        else:
            raise RuntimeError("farm needs an emitter or an input stream")

    def _join(self, timeout: Optional[float] = None) -> None:
        if self._emitter is not None:
            self._emitter._join(timeout)
        for w in self._workers:
            w._join(timeout)
        if self._col_runner is not None:
            self._col_runner.join(timeout)

    def _error(self) -> Optional[BaseException]:
        nodes = [self._emitter, *self._workers]
        for n in nodes:
            if n is None:
                continue
            e = n.error if isinstance(n, FFNode) else n._error()
            if e is not None:
                return e
        if self._col_runner is not None and self._col_runner.error is not None:
            return self._col_runner.error
        if self._collector is not None and isinstance(self._collector, FFNode) \
                and self._collector.error is not None:
            return self._collector.error
        return None

    def _alive(self) -> bool:
        parts = [self._emitter, getattr(self, "_fwd", None), *self._workers]
        if any(p is not None and p._alive() for p in parts):
            return True
        return (self._col_runner is not None
                and self._col_runner.thread.is_alive())

    def ffStats(self) -> dict:
        return {
            "workers": len(self._workers),
            "svc_calls": [getattr(w, "svc_calls", None) for w in self._workers],
            "emitter_calls": getattr(self._emitter, "svc_calls", None),
            "collector_calls": getattr(self._collector, "svc_calls", None),
        }

    def stats(self) -> dict:
        out = {"type": "farm",
               "workers": [_stat_of(w) for w in self._workers]}
        if self._emitter is not None:
            out["emitter"] = _stat_of(self._emitter)
        if self._collector is not None:
            out["collector"] = _stat_of(self._collector)
        spmc = getattr(self, "_spmc", None)
        mpsc = getattr(self, "_mpsc", None)
        out["lane_max_depth"] = \
            [l.max_depth for l in spmc.lanes] if spmc else []
        out["result_lane_max_depth"] = \
            [l.max_depth for l in mpsc.lanes] if mpsc else []
        return out


# ---------------------------------------------------------------------------
# Map skeleton on the farm template (paper Sec. 12.1)
# ---------------------------------------------------------------------------
class FFMap(Skeleton):
    """map = farm(Split -> workers -> Compose): the splitter partitions each
    input collection; the composer rebuilds the result.  Mirrors the paper's
    ``ff_map`` class.  The device-side analogue is ``core.device.tensor_map``
    (shard_map over the model axis)."""

    def __init__(self, splitter: FFNode, workers: Sequence, composer: FFNode,
                 lb: Optional[LoadBalancer] = None, capacity: int = 512):
        super().__init__()
        self._exec = Farm(workers, lb=lb, capacity=capacity)
        self._exec.add_emitter(splitter)
        self._exec.add_collector(composer)

    def _bind(self, out_fn, node_id: int = -1) -> None:
        super()._bind(out_fn, node_id)
        self._exec._bind(out_fn, node_id)

    def _start(self, in_q):
        if self._exec._out is None and self._out is not None:
            self._exec._bind(self._out)
        self._exec._start(in_q)

    def _join(self, timeout=None):
        self._exec._join(timeout)

    def _error(self):
        return self._exec._error()

    def _alive(self) -> bool:
        return self._exec._alive()

    def _make_input(self, capacity: int = 512):
        q = super()._make_input(capacity)
        return q

    def run_then_freeze(self) -> int:
        q = self._make_input()
        self._results = SPSCQueue(4096)
        self._exec._bind(lambda item: self._results.push(item))
        self._exec._start(q)
        self._t0 = time.perf_counter()
        self._running = True
        return 0

    def offload(self, task):
        self._in_q.push(task)

    def wait(self, timeout=None) -> int:
        self._exec._join(timeout)
        self._t1 = time.perf_counter()
        return -1 if self._exec._error() is not None else 0

    def stats(self) -> dict:
        return {"type": "map", **{k: v for k, v in self._exec.stats().items()
                                  if k != "type"}}


# ---------------------------------------------------------------------------
# Thread-tier farm-as-one-node: the drainable/resizable engine behind the
# adaptive runtime (core/runtime.py)
# ---------------------------------------------------------------------------
class _WorkerFailure:
    """A worker-thread exception shipped through the result lanes (the
    thread-tier twin of ``shm.ShmError``)."""

    __slots__ = ("error",)

    def __init__(self, error: BaseException):
        self.error = error


class ThreadFarmNode(FFNode):
    """A farm stage embedded as ONE host node: worker *threads* over
    SPMC/MPSC lanes with a sequence-ordered collector — the thread-tier twin
    of :class:`~repro.core.process.ProcessFarmNode`, sharing its surface
    (``svc`` routes, a collector thread reorders by sequence number and
    forwards via ``ff_send_out``, ``svc_end`` drains to a quiescent
    boundary).

    The shared surface is what makes live tier migration possible: the
    adaptive runtime (``core/runtime.py``) hot-swaps one of these for a
    ``ProcessFarmNode`` (or back) behind the node's boundary queues.
    Output order follows *input* order — stricter than the arrival-ordered
    ``Farm`` collector, matching the process and device lowerings, so a
    migration can never reorder the stream.

    ``set_active(k)`` moves the round-robin routing boundary between 1 and
    the built width (the :class:`AutoscaleLB` mechanism, driven externally):
    an inactive worker parks on the blocking pop of its empty lane.  Workers
    measure both wall and CPU time per call (``time.thread_time``), so
    ``node_stats`` exposes a ``gil_ratio`` — CPU/wall, ~1 when calls truly
    run in parallel, ~1/width when they serialize on the GIL — the signal
    the supervisor's thread->process migration policy keys on."""

    def __init__(self, fns: List[Callable], pre: Optional[Callable] = None,
                 post: Optional[Callable] = None, capacity: int = 64,
                 label: str = "thread_farm",
                 active: Optional[int] = None):
        super().__init__()
        if not fns:
            raise ValueError("thread farm with no workers")
        self._fns = list(fns)
        self._pre = pre
        self._post = post
        self._n = len(self._fns)
        self._label = label
        self._active = min(active or self._n, self._n)
        self._spmc = SPMCQueue(self._n, capacity)
        self._mpsc = MPSCQueue(self._n, capacity)
        self._seq = 0
        self._delivered = 0
        self._routed = [0] * self._n
        self._fn_calls = 0
        self._wall_warm: List[float] = []
        self._cpu_warm: List[float] = []
        self._wall_ema = 0.0
        self._cpu_ema = 0.0
        self._hop_ema = 0.0
        self._gap_ema = 0.0
        self._last_delivery: Optional[float] = None
        self._threads: List[threading.Thread] = []
        self._collector: Optional[threading.Thread] = None
        self._started = False

    @property
    def width(self) -> int:
        return self._n

    @property
    def active_workers(self) -> int:
        return self._active

    def set_active(self, k: int) -> None:
        """Move the routing boundary: new items go to workers [0, k)."""
        self._active = max(1, min(int(k), self._n))

    # -- worker / collector threads -----------------------------------------
    def _record_fn_time(self, wall: float, cpu: float) -> None:
        with self._stats_lock:
            self._fn_calls += 1
            if len(self._wall_warm) < 5:
                self._wall_warm.append(wall)
                self._cpu_warm.append(cpu)
                self._wall_ema = \
                    sorted(self._wall_warm)[len(self._wall_warm) // 2]
                self._cpu_ema = \
                    sorted(self._cpu_warm)[len(self._cpu_warm) // 2]
            else:
                self._wall_ema = 0.8 * self._wall_ema + 0.2 * wall
                self._cpu_ema = 0.8 * self._cpu_ema + 0.2 * cpu

    def _worker_loop(self, i: int, fn: Callable) -> None:
        lane = self._spmc.lanes[i]
        out = self._mpsc.lane(i)
        early = False
        try:
            while True:
                got = lane.pop()
                if got is EOS:
                    break
                seq, item = got
                w0 = time.perf_counter()
                c0 = time.thread_time()
                try:
                    y = fn(item)
                except BaseException as e:     # noqa: BLE001 - to the parent
                    out.push((seq, _WorkerFailure(e)))
                    early = True
                    break
                self._record_fn_time(time.perf_counter() - w0,
                                     time.thread_time() - c0)
                out.push((seq, y))
        except QueueClosed:
            early = True
        finally:
            try:
                out.push(EOS)
            except QueueClosed:
                pass
            if early:
                # keep the input lane draining so the emitter can never
                # wedge on a dead worker's full lane
                spawn_drainer(lane.pop)

    def _collect(self) -> None:
        hold = {}
        nxt = 0
        eos_seen = 0
        try:
            while eos_seen < self._n:
                item, _lane = self._mpsc.pop_any()
                if item is EOS:
                    eos_seen += 1
                    continue
                seq, y = item
                if isinstance(y, _WorkerFailure):
                    if self.error is None:
                        self.error = y.error
                    continue
                hold[seq] = y
                while nxt in hold:
                    res = hold.pop(nxt)
                    nxt += 1
                    if self._post is not None:
                        res = self._post(res)
                    now = time.perf_counter()
                    with self._stats_lock:
                        if self._last_delivery is not None:
                            gap = now - self._last_delivery
                            self._gap_ema = gap if self._gap_ema == 0.0 \
                                else 0.8 * self._gap_ema + 0.2 * gap
                        self._last_delivery = now
                        self._delivered += 1
                    self.ff_send_out(res)
        except BaseException as e:             # noqa: BLE001
            if self.error is None:
                self.error = e

    # -- node protocol --------------------------------------------------------
    def svc_init(self) -> int:
        if self._started:
            return 0
        self._started = True
        self._collector = threading.Thread(
            target=self._collect, daemon=True, name=f"{self._label}-collector")
        self._collector.start()
        for i, fn in enumerate(self._fns):
            t = threading.Thread(target=self._worker_loop, args=(i, fn),
                                 daemon=True, name=f"{self._label}-{i}")
            t.start()
            self._threads.append(t)
        return 0

    def svc(self, item: Any) -> Any:
        if self.error is not None:
            raise self.error
        if self._pre is not None:
            item = self._pre(item)
        with self._stats_lock:
            seq = self._seq
            self._seq += 1
        idx = seq % max(1, min(self._active, self._n))
        t0 = time.perf_counter()
        if self._spmc.lanes[idx].try_push((seq, item)):
            # the hop EMA is the *channel* cost: only uncontended pushes
            # count (a wait on a full lane measures back-pressure instead)
            hop = time.perf_counter() - t0
            with self._stats_lock:
                self._routed[idx] += 1
                self._hop_ema = hop if self._hop_ema == 0.0 \
                    else 0.9 * self._hop_ema + 0.1 * hop
        else:
            self._spmc.lanes[idx].push((seq, item))
            with self._stats_lock:
                self._routed[idx] += 1
        return GO_ON

    def svc_end(self) -> None:
        """Drain to a quiescent boundary: EOS to every worker lane, join
        workers and the collector — every accepted item is delivered (or the
        error surfaced) before this returns, which is the barrier live
        migration relies on.  A worker that refuses to quiesce (fn wedged on
        a lock / IO past the join timeout) surfaces as an error rather than
        silently returning with the barrier broken — a migration must abort
        instead of letting a zombie worker's late output interleave with the
        replacement engine's stream."""
        try:
            self._spmc.broadcast(EOS)
        except QueueClosed:
            pass
        for t in self._threads:
            t.join(timeout=30.0)
        if self._collector is not None:
            self._collector.join(timeout=30.0)
        stuck = [t.name for t in self._threads if t.is_alive()]
        if self._collector is not None and self._collector.is_alive():
            stuck.append(self._collector.name)
        if stuck and self.error is None:
            self.error = RuntimeError(
                f"{self._label}: drain did not quiesce within 30s "
                f"(stuck: {', '.join(stuck)})")

    # -- stats ---------------------------------------------------------------
    def node_stats(self) -> dict:
        from .perf_model import fn_key
        depths = [len(l) for l in self._spmc.lanes]
        with self._stats_lock:
            wall, cpu = self._wall_ema, self._cpu_ema
            return {
                "node": self._label,
                "backend": "thread",
                "workers": self._n,
                "active": self._active,
                "items": self._seq,
                "delivered": self._delivered,
                "routed_per_worker": list(self._routed),
                "svc_time_ema_s": wall,
                "svc_wall_ema_s": wall,
                "svc_cpu_ema_s": cpu,
                "gil_ratio": (cpu / wall) if wall > 0.0 else None,
                "hop_ema_s": self._hop_ema,
                "delivery_gap_ema_s": self._gap_ema,
                "lane_depths": depths,
                "max_lane_depth": max(
                    (l.max_depth for l in self._spmc.lanes), default=0),
                "fn_key": fn_key(self._fns[0]),
            }
