"""L1/L2 — network lanes for the distributed tier: the shm slot protocol
over TCP, remote farms, and the loopback cluster harness.

``core/shm.py`` carries the process-backed host tier over fixed-slot
shared-memory rings; this module is the same FastFlow layer-1 structure
across the *node* boundary.  A :class:`NetLane` speaks the **same slot
protocol** as the shm rings — each frame is the shm slot header
(``<IB3xQ``: u32 payload length | u8 tag | 3B pad | u64 seq) followed by the
payload, so the raw-ndarray fast path (dtype/shape meta + buffer bytes), the
pickled-bytes fallback, and the EOS/ERR control marks ride TCP byte-for-byte
the way they ride a shared-memory slot.  Three net-only control tags ride
the same header: ``CREDIT`` (the bounded in-flight window for back-pressure
— the stream analogue of a full ring), ``HB`` (heartbeats, so a silent peer
is *detected* instead of wedging a blocking pop), and ``FN`` (the pickled
``svc`` callable a remote farm ships to its worker once at startup).

The pieces, mirroring the process tier one level up:

- :class:`NetLane` — one full-duplex framed TCP link with the lane surface
  the farm machinery and :class:`~repro.core.skeletons.AutoscaleLB` already
  consume (``push``/``try_push``/``pop_seq``/``push_eos``/``push_err``/
  ``close``/``__len__``).  Client half via :meth:`NetLane.connect` (retry +
  exponential backoff), server half by wrapping an accepted socket.  A dead
  peer (EOF/RST mid-stream, or heartbeat silence past ``hb_timeout``)
  surfaces as :class:`~repro.core.process.WorkerCrashed` on the next
  push/pop instead of blocking forever.

- :func:`worker_main` — the worker-pool entry point
  (``python -m repro.launch.worker --listen host:port``): accept a
  connection, receive the pickled ``svc`` callable (tag ``FN``), then serve
  the farm worker loop — pop an item, push ``fn(item)`` with the item's seq
  echoed, ship worker-side CPU-time records
  (:class:`~repro.core.shm.WorkerStats`) every few dozen items and at EOS.

- :class:`RemoteFarmNode` — the :class:`~repro.core.process.ProcessFarmNode`
  of the distributed tier: one host boundary node whose workers live on
  remote hosts.  ``svc`` routes items onto per-worker net lanes (failing
  over past dead peers); a collector thread drains results, restores exact
  input order from the echoed sequence numbers, folds worker CPU stats, and
  surfaces crashes.  ``set_active``/``active_workers`` move the routing
  boundary, so :class:`~repro.core.skeletons.AutoscaleLB` and the
  :class:`~repro.core.runtime.Supervisor` drive **cluster autoscaling** —
  growing or shrinking the active remote worker set from observed lane
  depth, exactly the policy that scales thread and process farms.

- :func:`spawn_loopback_pool` — the test/bench harness: fork local
  ``worker_main`` pools on 127.0.0.1 ephemeral ports, so a "cluster" run
  needs nothing but this machine.
"""

from __future__ import annotations

import collections
import pickle
import socket
import struct
import threading
import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .node import EOS, FFNode, GO_ON
from .queues import QueueClosed
from .shm import (_SLOT_FMT, _SLOT_HDR, TAG_ARR, TAG_EOS, TAG_ERR, TAG_PKL,
                  ShmError, WorkerStats)

# net-only control tags, riding the same slot header as the shm tags
TAG_CREDIT = 4          # seq field carries the grant count; empty payload
TAG_HB = 5              # heartbeat; empty payload
TAG_FN = 6              # pickled svc callable (farm handshake)

# refuse absurd frames before allocating for them: a corrupt/hostile length
# word must fail the decode, not the allocator
MAX_FRAME_BYTES = 1 << 26       # 64 MiB

_HB_FRAME = struct.pack(_SLOT_FMT, 0, TAG_HB, 0)
_EOS_FRAME = struct.pack(_SLOT_FMT, 0, TAG_EOS, 0)

_STATS_EVERY = 32       # ship a WorkerStats record every this many items


class FrameError(RuntimeError):
    """A malformed frame on a net lane: truncated mid-frame, oversized
    length word, or corrupt ndarray meta."""


def parse_addr(addr: Any) -> Tuple[str, int]:
    """``"host:port"`` / ``(host, port)`` -> ``(host, port)``."""
    if isinstance(addr, str):
        host, _, port = addr.rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(f"bad worker address {addr!r} "
                             "(expected host:port)")
        return host, int(port)
    host, port = addr
    return str(host), int(port)


# ---------------------------------------------------------------------------
# Frame codec: the shm slot encoding, length-prefixed onto a byte stream
# ---------------------------------------------------------------------------
def encode_frame(tag: int, obj: Any = None, seq: int = 0,
                 max_frame: int = MAX_FRAME_BYTES) -> bytes:
    """One wire frame: the shm slot header + payload, as bytes.

    Payload encodings match :meth:`~repro.core.shm.ShmSPSCQueue._encode`
    exactly: ``ARR`` is ``<BB`` (ndim, dtype-string length) + dtype string +
    ``<{ndim}q`` shape + the raw contiguous buffer; ``PKL``/``ERR``/``FN``
    are pickled bytes; control tags carry no payload."""
    if tag == TAG_ARR:
        dt = obj.dtype.str.encode("ascii")
        meta = struct.pack("<BB", obj.ndim, len(dt)) + dt \
            + struct.pack(f"<{obj.ndim}q", *obj.shape)
        payload = meta + memoryview(obj).cast("B").tobytes()
    elif tag in (TAG_PKL, TAG_ERR, TAG_FN):
        payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    else:                           # EOS / HB / CREDIT
        payload = b""
    if len(payload) > max_frame:
        raise FrameError(f"frame payload of {len(payload)}B exceeds the "
                         f"{max_frame}B lane limit")
    return struct.pack(_SLOT_FMT, len(payload), tag, seq) + payload


def encode_item(item: Any, seq: int = 0,
                max_frame: int = MAX_FRAME_BYTES) -> bytes:
    """Encode one stream item, choosing the tag the way the shm ring's
    ``try_push`` does: plain-dtype ndarrays ride the raw-slab ``ARR`` fast
    path (made contiguous first), everything else — structured/object
    dtypes, pytrees, scalars — the ``PKL`` fallback."""
    if isinstance(item, np.ndarray) and item.dtype.names is None \
            and item.dtype.kind != "O":
        # order="C", not ascontiguousarray: the latter promotes 0-d to 1-d,
        # and the wire must round-trip shapes exactly
        return encode_frame(TAG_ARR, np.asarray(item, order="C"), seq,
                            max_frame)
    return encode_frame(TAG_PKL, item, seq, max_frame)


def decode_payload(tag: int, payload: bytes) -> Any:
    """Payload bytes -> object (the shm ``_decode``, off a byte string).
    ``EOS`` decodes back to the module-wide sentinel so identity checks keep
    working across the wire."""
    if tag in (TAG_EOS, TAG_HB, TAG_CREDIT):
        return EOS if tag == TAG_EOS else None
    if tag == TAG_ARR:
        try:
            ndim, dlen = struct.unpack_from("<BB", payload, 0)
            off = 2
            dtype = np.dtype(payload[off:off + dlen].decode("ascii"))
            off += dlen
            shape = struct.unpack_from(f"<{ndim}q", payload, off)
            off += 8 * ndim
            nbytes = int(dtype.itemsize
                         * int(np.prod(shape, dtype=np.int64))) \
                if ndim else dtype.itemsize
            if off + nbytes != len(payload):
                raise FrameError(
                    f"corrupt ndarray frame: meta claims {nbytes}B of data, "
                    f"payload carries {len(payload) - off}B")
            return np.frombuffer(payload[off:off + nbytes],
                                 dtype=dtype).reshape(shape)
        except (struct.error, ValueError, UnicodeDecodeError) as e:
            raise FrameError(f"corrupt ndarray frame meta: {e}") from e
    return pickle.loads(payload)


def _recv_exact(sock: socket.socket, n: int,
                allow_eof: bool = False) -> Optional[bytes]:
    """Read exactly ``n`` bytes, riding out partial reads.  EOF at offset 0
    returns None when ``allow_eof`` (a clean close between frames); EOF
    mid-read always raises :class:`FrameError` (a truncated frame)."""
    chunks: List[bytes] = []
    got = 0
    while got < n:
        try:
            b = sock.recv(n - got)
        except OSError as e:
            raise FrameError(f"lane read failed: {e}") from e
        if not b:
            if got == 0 and allow_eof:
                return None
            raise FrameError(f"truncated frame: connection closed after "
                             f"{got} of {n} bytes")
        chunks.append(b)
        got += len(b)
    return b"".join(chunks)


def read_frame(sock: socket.socket,
               max_frame: int = MAX_FRAME_BYTES
               ) -> Optional[Tuple[int, bytes, int]]:
    """Read one frame: ``(tag, payload bytes, seq)``, or None on a clean
    EOF at a frame boundary.  Raises :class:`FrameError` on a truncated
    frame or an oversized length word (rejected before any allocation)."""
    hdr = _recv_exact(sock, _SLOT_HDR, allow_eof=True)
    if hdr is None:
        return None
    length, tag, seq = struct.unpack(_SLOT_FMT, hdr)
    if length > max_frame:
        raise FrameError(f"oversized frame: length word {length}B exceeds "
                         f"the {max_frame}B lane limit")
    payload = _recv_exact(sock, length) if length else b""
    return tag, payload, seq


def _worker_crashed(msg: str):
    from .process import WorkerCrashed
    return WorkerCrashed(msg)


# ---------------------------------------------------------------------------
# NetLane: one framed TCP link with the shm-lane surface
# ---------------------------------------------------------------------------
class _Handshake:
    """A received ``FN`` frame: the svc callable a remote farm shipped."""

    __slots__ = ("fn",)

    def __init__(self, fn: Callable):
        self.fn = fn


class NetLane:
    """A full-duplex framed TCP lane speaking the shm slot protocol.

    Same surface as :class:`~repro.core.shm.ShmSPSCQueue` (``push`` /
    ``try_push`` / ``pop_seq`` / ``push_eos`` / ``push_err`` / ``close`` /
    ``__len__``), crossing a host boundary.  Two extra disciplines the
    shared-memory ring gets for free from its fixed slots and liveness
    polling:

    - **credit window**: a data push consumes one credit from a bounded
      window (``credit=``); the receiver returns one credit per item its
      application actually pops.  In-flight items are therefore bounded —
      the stream back-pressures exactly like a full ring — and the lane's
      ``len()`` (outstanding + locally queued) is the depth signal
      ``AutoscaleLB`` scales on.  Control frames (EOS/ERR/HB/CREDIT/FN)
      never consume credit, so termination and errors cannot wedge behind
      back-pressure.
    - **heartbeat**: each side sends ``HB`` every ``hb_interval`` and marks
      the peer dead after ``hb_timeout`` without *any* traffic (EOF/RST
      marks it immediately).  A dead peer surfaces as
      :class:`~repro.core.process.WorkerCrashed` on the next push, or on a
      pop that would otherwise wait forever — never a silent wedge.
    """

    def __init__(self, sock: socket.socket, *, credit: int = 32,
                 hb_interval: float = 0.5,
                 hb_timeout: Optional[float] = None,
                 max_frame: int = MAX_FRAME_BYTES, label: str = "netlane"):
        if credit < 1:
            raise ValueError("credit window must be >= 1")
        self._sock = sock
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:             # not TCP (e.g. a unix socketpair in tests)
            pass
        self._window = credit
        self._credits = credit
        self._credit_cv = threading.Condition()
        self._hb_interval = hb_interval
        self._hb_timeout = hb_timeout if hb_timeout is not None \
            else 6.0 * hb_interval
        self._max_frame = max_frame
        self._label = label
        self._send_lock = threading.Lock()
        self._rq: collections.deque = collections.deque()
        self._dead: Optional[str] = None
        self._closed = False
        self._saw_eos = False
        self._shutdown = False
        self._last_recv = time.monotonic()
        self.max_depth = 0
        self._stop = threading.Event()
        self._reader = threading.Thread(target=self._read_loop, daemon=True,
                                        name=f"{label}-reader")
        self._hb = threading.Thread(target=self._hb_loop, daemon=True,
                                    name=f"{label}-hb")
        self._reader.start()
        self._hb.start()

    # -- construction --------------------------------------------------------
    @classmethod
    def connect(cls, host: str, port: int, *, timeout: float = 15.0,
                backoff: float = 0.05, max_backoff: float = 1.0,
                **kw) -> "NetLane":
        """Client half: dial ``host:port``, retrying with exponential
        backoff until ``timeout`` (workers and parents race to start — a
        refused connect means the listener is not up *yet*)."""
        deadline = time.monotonic() + timeout
        delay = backoff
        while True:
            try:
                sock = socket.create_connection((host, port), timeout=5.0)
                sock.settimeout(None)
                return cls(sock, label=f"netlane[{host}:{port}]", **kw)
            except OSError as e:
                if time.monotonic() + delay > deadline:
                    raise _worker_crashed(
                        f"cannot connect to worker {host}:{port} within "
                        f"{timeout:.0f}s: {e}") from e
                time.sleep(delay)
                delay = min(delay * 2.0, max_backoff)

    # -- peer liveness -------------------------------------------------------
    @property
    def peer_dead(self) -> Optional[str]:
        """The reason the peer is considered gone, or None while healthy."""
        return self._dead

    @property
    def saw_eos(self) -> bool:
        return self._saw_eos

    def _mark_dead(self, reason: str) -> None:
        if self._dead is None and not self._shutdown:
            self._dead = f"{self._label}: {reason}"
        with self._credit_cv:       # wake pushers blocked on the window
            self._credit_cv.notify_all()

    # -- background threads --------------------------------------------------
    def _read_loop(self) -> None:
        try:
            while True:
                fr = read_frame(self._sock, self._max_frame)
                if fr is None:
                    if not self._saw_eos:
                        self._mark_dead("peer closed the connection "
                                        "mid-stream")
                    return
                tag, payload, seq = fr
                self._last_recv = time.monotonic()
                if tag == TAG_HB:
                    continue
                if tag == TAG_CREDIT:
                    with self._credit_cv:
                        self._credits += int(seq) or 1
                        self._credit_cv.notify_all()
                    continue
                if tag == TAG_EOS:
                    self._saw_eos = True
                    self._rq.append((EOS, seq))
                    continue
                if tag == TAG_FN:
                    self._rq.append((_Handshake(pickle.loads(payload)), seq))
                    continue
                self._rq.append((decode_payload(tag, payload), seq))
        except FrameError as e:
            self._mark_dead(str(e))
        except Exception as e:      # noqa: BLE001 - reader must never wedge
            self._mark_dead(f"lane reader failed: {e!r}")

    def _hb_loop(self) -> None:
        while not self._stop.wait(self._hb_interval):
            if self._dead is not None or self._shutdown:
                return
            try:
                with self._send_lock:
                    self._sock.sendall(_HB_FRAME)
            except OSError as e:
                self._mark_dead(f"heartbeat send failed: {e}")
                return
            if time.monotonic() - self._last_recv > self._hb_timeout:
                self._mark_dead(
                    f"heartbeat timeout ({self._hb_timeout:.1f}s without "
                    "traffic from the peer)")
                return

    # -- send primitives -----------------------------------------------------
    def _send_raw(self, buf: bytes) -> None:
        try:
            with self._send_lock:
                self._sock.sendall(buf)
        except OSError as e:
            self._mark_dead(f"send failed: {e}")
            raise _worker_crashed(self._dead) from e

    def try_push(self, item: Any, seq: int = 0) -> bool:
        """Non-blocking data push: False when the credit window is
        exhausted (back-pressure), :class:`WorkerCrashed` when the peer is
        dead — a full window on a dead peer never drains."""
        if self._dead is not None:
            raise _worker_crashed(self._dead)
        with self._credit_cv:
            if self._credits <= 0:
                return False
            self._credits -= 1
            depth = self._window - self._credits
            if depth > self.max_depth:
                self.max_depth = depth
        try:
            self._send_raw(encode_item(item, seq, self._max_frame))
        except BaseException:
            with self._credit_cv:   # un-spend the credit of a failed send
                self._credits += 1
            raise
        return True

    def push(self, item: Any, timeout: Optional[float] = None,
             seq: int = 0) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            if self._closed:
                raise QueueClosed("push to closed net lane")
            if self.try_push(item, seq):
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(f"{self._label}: push timed out waiting "
                                   "for credit")
            with self._credit_cv:
                if self._credits <= 0 and self._dead is None:
                    self._credit_cv.wait(delay)
            delay = min(delay * 2, 1e-3)

    def push_eos(self, timeout: Optional[float] = None) -> None:
        if self._closed:
            raise QueueClosed("push_eos to closed net lane")
        self._send_raw(_EOS_FRAME)

    def push_err(self, err: ShmError, timeout: Optional[float] = None) -> None:
        if self._closed:
            raise QueueClosed("push_err to closed net lane")
        self._send_raw(encode_frame(TAG_ERR, err, 0, self._max_frame))

    def push_fn(self, fn: Callable) -> None:
        """Ship the farm worker's ``svc`` callable (the ``FN`` handshake)."""
        self._send_raw(encode_frame(TAG_FN, fn, 0, self._max_frame))

    # -- receive primitives --------------------------------------------------
    def _grant(self) -> None:
        # one credit back per item the application consumed; best-effort —
        # a dead peer has no use for credits
        try:
            self._send_raw(struct.pack(_SLOT_FMT, 0, TAG_CREDIT, 1))
        except BaseException:       # noqa: BLE001 - peer gone
            pass

    def try_pop_seq(self) -> Tuple[bool, Any, int]:
        if not self._rq:
            return False, None, 0
        item, seq = self._rq.popleft()
        if item is not EOS and not isinstance(item, (ShmError, _Handshake)):
            self._grant()
        return True, item, seq

    def pop_seq(self, timeout: Optional[float] = None) -> Tuple[Any, int]:
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = 1e-6
        while True:
            ok, item, seq = self.try_pop_seq()
            if ok:
                return item, seq
            if self._dead is not None:
                raise _worker_crashed(self._dead)
            if self._closed:
                raise QueueClosed("pop from closed empty net lane")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(f"{self._label}: pop timed out")
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def pop(self, timeout: Optional[float] = None) -> Any:
        return self.pop_seq(timeout)[0]

    # -- lane surface shared with the shm/thread tiers -----------------------
    def __len__(self) -> int:
        """Depth signal: data in flight toward the peer (sent, not yet
        consumed there) plus data locally received and not yet popped."""
        outstanding = max(0, self._window - self._credits)
        return outstanding + len(self._rq)

    def empty(self) -> bool:
        return len(self) == 0

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Local close: further pushes raise ``QueueClosed`` (the unwind
        discipline of the shm rings).  The socket stays up so in-flight
        results still drain; :meth:`shutdown` tears it down."""
        self._closed = True

    def drained(self) -> bool:
        return self._closed and self.empty()

    def shutdown(self) -> None:
        """Tear the link down: close the socket and stop the lane threads."""
        self._shutdown = True
        self._closed = True
        self._stop.set()
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        for t in (self._reader, self._hb):
            if t is not threading.current_thread():
                t.join(timeout=2.0)


# ---------------------------------------------------------------------------
# Worker side: the pool entry point (python -m repro.launch.worker)
# ---------------------------------------------------------------------------
def _serve_conn(sock: socket.socket, idx: int, *, credit: int,
                hb_interval: float, hb_timeout: Optional[float],
                max_frame: int) -> None:
    """Serve one farm-parent connection: receive the ``FN`` handshake, then
    loop pop item -> push ``fn(item)`` with the item's seq echoed.  Ships a
    :class:`~repro.core.shm.WorkerStats` CPU-time record every
    ``_STATS_EVERY`` items and at EOS; an exception in ``fn`` ships an
    error record; the parent dying just ends the loop."""
    lane = NetLane(sock, credit=credit, hb_interval=hb_interval,
                   hb_timeout=hb_timeout, max_frame=max_frame,
                   label=f"worker{idx}")
    fn: Optional[Callable] = None
    cpu_ema = 0.0
    done = 0
    try:
        while True:
            try:
                item, seq = lane.pop_seq()
            except Exception:       # noqa: BLE001 - parent gone/closed lane
                return
            if item is EOS:
                return
            if isinstance(item, _Handshake):
                fn = item.fn
                continue
            if fn is None:
                lane.push_err(ShmError(
                    idx, "ProtocolError('item before FN handshake')", ""))
                return
            try:
                c0 = time.thread_time()
                out = fn(item)
                c = time.thread_time() - c0
            except BaseException as e:  # noqa: BLE001 - shipped to the parent
                try:
                    lane.push_err(ShmError(idx, repr(e),
                                           traceback.format_exc()))
                except BaseException:   # noqa: BLE001 - parent may be gone
                    pass
                return
            done += 1
            cpu_ema = c if cpu_ema == 0.0 else 0.9 * cpu_ema + 0.1 * c
            try:
                lane.push(out, seq=seq)
                if done % _STATS_EVERY == 0:
                    lane.push(WorkerStats(idx, done, cpu_ema), seq=0)
            except BaseException:       # noqa: BLE001 - parent gone
                return
    finally:
        try:
            if done:
                lane.push(WorkerStats(idx, done, cpu_ema), seq=0)
            lane.push_eos()
        except BaseException:           # noqa: BLE001 - parent may be gone
            pass
        lane.shutdown()


def worker_main(host: str = "127.0.0.1", port: int = 0, *, slots: int = 4,
                credit: int = 32, hb_interval: float = 0.5,
                hb_timeout: Optional[float] = None,
                max_frame: int = MAX_FRAME_BYTES,
                max_conns: Optional[int] = None,
                announce: Optional[Callable[[str, int], None]] = None,
                quiet: bool = False) -> None:
    """Serve a farm worker pool on ``host:port`` until killed.

    Each accepted connection is one farm lane, served on its own thread (up
    to ``slots`` concurrently); the first data frame must be the ``FN``
    handshake carrying the pickled ``svc`` callable.  ``port=0`` binds an
    ephemeral port — ``announce(host, actual_port)`` reports it (the
    loopback pool harness listens on a queue; the CLI prints it)."""
    ls = socket.create_server((host, port), backlog=max(slots, 4))
    actual = ls.getsockname()[1]
    if announce is not None:
        announce(host, actual)
    if not quiet:
        print(f"repro worker: listening on {host}:{actual} "
              f"(slots={slots})", flush=True)
    gate = threading.BoundedSemaphore(max(1, slots))
    served = 0
    try:
        while max_conns is None or served < max_conns:
            conn, _peer = ls.accept()
            gate.acquire()
            idx = served
            served += 1

            def _run(c=conn, i=idx):
                try:
                    _serve_conn(c, i, credit=credit,
                                hb_interval=hb_interval,
                                hb_timeout=hb_timeout, max_frame=max_frame)
                finally:
                    gate.release()

            threading.Thread(target=_run, daemon=True,
                             name=f"ff-net-worker-{idx}").start()
    finally:
        ls.close()


def _pool_entry(q, host: str, kw: dict) -> None:
    import os

    def announce(h: str, p: int) -> None:
        q.put((h, p, os.getpid()))

    worker_main(host, 0, announce=announce, quiet=True, **kw)


def spawn_loopback_pool(n: int, *, host: str = "127.0.0.1",
                        start_timeout: float = 15.0,
                        **kw) -> Tuple[List[Tuple[str, int]], List[Any]]:
    """The loopback-cluster harness: fork ``n`` local :func:`worker_main`
    pools on ephemeral 127.0.0.1 ports.  Returns ``(addrs, procs)`` with
    ``addrs[i]`` served by ``procs[i]`` (so a test can kill a *specific*
    worker); the caller owns the processes and must ``terminate()`` them."""
    from .process import _mp_context, _quiet_fork
    ctx = _mp_context()
    q = ctx.Queue()
    procs = [ctx.Process(target=_pool_entry, args=(q, host, kw),
                         daemon=True, name=f"ff-net-pool-{i}")
             for i in range(n)]
    with _quiet_fork():
        for p in procs:
            p.start()
    by_pid: Dict[int, Tuple[str, int]] = {}
    deadline = time.monotonic() + start_timeout
    while len(by_pid) < n:
        try:
            h, prt, pid = q.get(timeout=max(0.1, deadline - time.monotonic()))
        except Exception as e:      # noqa: BLE001 - queue.Empty
            for p in procs:
                p.terminate()
            raise _worker_crashed(
                f"loopback pool: only {len(by_pid)} of {n} workers came up "
                f"within {start_timeout:.0f}s") from e
        by_pid[pid] = (h, prt)
    addrs = [by_pid[p.pid] for p in procs]
    return addrs, procs


# ---------------------------------------------------------------------------
# Parent side: the remote farm boundary node
# ---------------------------------------------------------------------------
class _LaneBundle:
    """The ``lanes``-list surface :class:`AutoscaleLB` attaches to."""

    def __init__(self, lanes: List[NetLane]):
        self.lanes = lanes


class RemoteFarmNode(FFNode):
    """A farm stage whose workers live on remote hosts, embedded as one
    host node — the :class:`~repro.core.process.ProcessFarmNode` of the
    distributed tier.

    ``fns`` is one picklable per-item callable per worker; ``addrs`` the
    matching ``(host, port)`` worker-pool addresses (connected with retry +
    backoff at build time; each lane then ships its callable once, tag
    ``FN``).  ``pre``/``post`` run in the parent around the network hop.
    Results carry the item's sequence number back, so output order is
    exactly *input* order through a reorder buffer — past any credit-window
    depth — matching the process and device lowerings.

    Crash surfacing: a worker exception ships an error record; a killed
    worker is an EOF/RST (or heartbeat silence) on its lane — either way the
    farm sets :class:`~repro.core.process.WorkerCrashed`, refuses new input,
    and unwinds instead of wedging.  ``set_active``/``active_workers`` move
    the round-robin routing boundary across the connected pool, so
    ``autoscale=True`` (an :class:`AutoscaleLB` over the net lanes) and the
    runtime :class:`~repro.core.runtime.Supervisor` (through the node's
    resizable stage handle) both drive cluster autoscaling from observed
    lane depth — growing never dials a new connection, it starts routing to
    an idle one."""

    def __init__(self, fns: Sequence[Callable],
                 addrs: Sequence[Any], pre: Optional[Callable] = None,
                 post: Optional[Callable] = None, credit: int = 32,
                 label: str = "remote_farm", autoscale: bool = False,
                 min_workers: int = 1, connect_timeout: float = 15.0,
                 hb_interval: float = 0.5, hb_timeout: Optional[float] = None,
                 max_frame: int = MAX_FRAME_BYTES):
        super().__init__()
        if not fns:
            raise ValueError("remote farm with no workers")
        if len(addrs) < len(fns):
            raise ValueError(f"remote farm needs one worker address per "
                             f"callable ({len(fns)} fns, {len(addrs)} addrs)")
        self._fns = list(fns)
        self._pre = pre
        self._post = post
        self._label = label
        self._n = len(self._fns)
        self._addrs = [parse_addr(a) for a in addrs[:self._n]]
        self._lanes: List[NetLane] = []
        try:
            for host, port in self._addrs:
                self._lanes.append(NetLane.connect(
                    host, port, timeout=connect_timeout, credit=credit,
                    hb_interval=hb_interval, hb_timeout=hb_timeout,
                    max_frame=max_frame))
            for lane, fn in zip(self._lanes, self._fns):
                lane.push_fn(fn)
        except BaseException:
            for lane in self._lanes:
                lane.shutdown()
            raise
        self._lb = None
        if autoscale:
            from .skeletons import AutoscaleLB
            self._lb = AutoscaleLB(min_workers=min_workers,
                                   max_workers=self._n)
            self._lb._attach(_LaneBundle(self._lanes))
        self._seq = 0
        self._delivered = 0
        self._routed = [0] * self._n
        self._active = self._n
        self._hop_ema = 0.0         # parent-side per-item lane push cost
        self._gap_ema = 0.0
        self._last_delivery: Optional[float] = None
        self._worker_cpu: Dict[int, Tuple[int, float]] = {}
        self._eos_seen = [False] * self._n
        self._collector: Optional[threading.Thread] = None
        self._destroyed = False

    @property
    def width(self) -> int:
        return self._n

    @property
    def active_workers(self) -> int:
        return self._lb.cur if self._lb is not None else self._active

    def set_active(self, k: int) -> None:
        """Move the routing boundary: new items go to workers [0, k).  The
        full pool connected at build time; an inactive remote worker just
        idles on its lane, so growing the active set never dials — it
        resumes routing.  This is the cluster-autoscaling mechanism the
        AutoscaleLB and the runtime Supervisor drive."""
        k = max(1, min(int(k), self._n))
        if self._lb is not None:
            self._lb.cur = min(max(k, self._lb.min_workers),
                               self._lb.max_workers or self._n)
        self._active = k

    def make_handle(self, desc: Optional[str] = None) -> "RemoteStageHandle":
        return RemoteStageHandle(desc or self._label, self)

    # -- parent-side emitter -------------------------------------------------
    def _push_alive(self, idx: int, item: Any, seq: int) -> bool:
        """Blocking push to worker ``idx`` that fails over instead of
        wedging when the peer has died (or the collector flagged the farm
        as failed)."""
        from .process import WorkerCrashed
        lane = self._lanes[idx]
        delay = 1e-6
        self._push_waited = False
        while True:
            if self.error is not None:
                return False
            try:
                if lane.try_push(item, seq):
                    return True
            except WorkerCrashed:   # dead peer: fail over to the next worker
                return False
            # anything else (unpicklable item, oversized frame) is the
            # item's fault, not the worker's — surface it like the shm
            # tier does instead of misreporting a cluster death
            self._push_waited = True
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def svc(self, item: Any) -> Any:
        if self.error is not None:      # collector flagged a failed farm
            raise self.error
        if self._pre is not None:
            item = self._pre(item)
        with self._stats_lock:
            seq = self._seq
            self._seq += 1
        start = self._lb.selectworker(item) if self._lb is not None \
            else seq % max(1, min(self._active, self._n))
        t0 = time.perf_counter()
        for off in range(self._n):
            idx = (start + off) % self._n
            if self._push_alive(idx, item, seq):
                hop = time.perf_counter() - t0
                with self._stats_lock:
                    self._routed[idx] += 1
                    if not self._push_waited:
                        self._hop_ema = hop if self._hop_ema == 0.0 \
                            else 0.9 * self._hop_ema + 0.1 * hop
                return GO_ON
        if self.error is None:
            self.error = _worker_crashed(
                f"{self._label}: all {self._n} remote workers are gone")
        raise self.error

    # -- parent-side collector ----------------------------------------------
    def _collect(self) -> None:
        hold: Dict[int, Any] = {}       # out-of-order results by sequence
        nxt = 0
        scan = 0
        delay = 1e-6
        last_liveness = time.monotonic()
        while not all(self._eos_seen):
            got = None
            for off in range(self._n):
                i = (scan + off) % self._n
                if self._eos_seen[i]:
                    continue
                ok, item, seq = self._lanes[i].try_pop_seq()
                if ok:
                    scan = (i + 1) % self._n
                    got = (item, seq, i)
                    break
            if got is None:
                now = time.monotonic()
                if now - last_liveness > 0.05:
                    last_liveness = now
                    if self._check_crashed():
                        self._fail()
                        return
                time.sleep(delay)
                delay = min(delay * 2, 1e-3)
                continue
            delay = 1e-6
            item, seq, lane = got
            if item is EOS:
                self._eos_seen[lane] = True
                continue
            if isinstance(item, ShmError):
                self.error = _worker_crashed(
                    f"{self._label}: worker {lane} ({self._addrs[lane][0]}:"
                    f"{self._addrs[lane][1]}) raised {item.exc}\n{item.tb}")
                self._fail()
                return
            if isinstance(item, WorkerStats):
                with self._stats_lock:
                    self._worker_cpu[lane] = (item.items, item.cpu_ema_s)
                continue
            hold[seq] = item
            while nxt in hold:
                out = hold.pop(nxt)
                nxt += 1
                if self._post is not None:
                    out = self._post(out)
                now = time.perf_counter()
                with self._stats_lock:
                    if self._last_delivery is not None:
                        gap = now - self._last_delivery
                        self._gap_ema = gap if self._gap_ema == 0.0 \
                            else 0.8 * self._gap_ema + 0.2 * gap
                    self._last_delivery = now
                    self._delivered += 1
                self.ff_send_out(out)
        # completeness invariant (mirrors ProcessA2ANode): a clean end of
        # stream must have delivered every accepted item — a gap means a
        # worker vanished with items in flight and its death evaded the
        # liveness scan; surface it, never return a truncated stream
        if self.error is None and self._delivered < self._seq:
            self.error = _worker_crashed(
                f"{self._label}: stream truncated — only {self._delivered} "
                f"of {self._seq} items delivered")

    def _check_crashed(self) -> bool:
        for i, lane in enumerate(self._lanes):
            if not self._eos_seen[i] and lane.peer_dead is not None \
                    and not lane._rq:
                self.error = _worker_crashed(
                    f"{self._label}: worker {i} "
                    f"({self._addrs[i][0]}:{self._addrs[i][1]}) died before "
                    f"end of stream — {lane.peer_dead}")
                return True
        return False

    def _fail(self) -> None:
        """Unwind a failed farm without wedging: refuse new input (``svc``
        raises once ``self.error`` is set), tell surviving workers to stop
        (EOS is credit-free, so it cannot block behind back-pressure), and
        drain their EOS acknowledgements briefly so sockets close clean."""
        for i, lane in enumerate(self._lanes):
            if lane.peer_dead is None and not self._eos_seen[i]:
                try:
                    lane.push_eos()
                except BaseException:   # noqa: BLE001 - racing a dying peer
                    pass
        deadline = time.monotonic() + 5.0
        while not all(self._eos_seen) and time.monotonic() < deadline:
            moved = False
            for i, lane in enumerate(self._lanes):
                if self._eos_seen[i]:
                    continue
                if lane.peer_dead is not None and not lane._rq:
                    self._eos_seen[i] = True
                    continue
                ok, item, _seq = lane.try_pop_seq()
                if ok:
                    moved = True
                    if item is EOS:
                        self._eos_seen[i] = True
            if not moved:
                time.sleep(1e-4)

    # -- lifecycle -----------------------------------------------------------
    def svc_init(self) -> int:
        self._collector = threading.Thread(target=self._collect, daemon=True,
                                           name=f"{self._label}-collector")
        self._collector.start()
        return 0

    def svc_end(self) -> None:
        if self._destroyed:
            return
        try:
            for i, lane in enumerate(self._lanes):
                if lane.peer_dead is None:
                    try:
                        lane.push_eos()
                    except BaseException:   # noqa: BLE001 - racing a crash
                        pass
            if self._collector is not None:
                self._collector.join(timeout=30.0)
        finally:
            self._destroy()

    def _destroy(self) -> None:
        if not self._destroyed:
            self._destroyed = True
            for lane in self._lanes:
                lane.shutdown()

    def __del__(self):
        # a compiled-but-never-run or abandoned node must still release its
        # sockets and lane threads (same contract as ProcessFarmNode)
        try:
            if not self._destroyed:
                self._destroy()
        except Exception:   # noqa: BLE001 - interpreter teardown
            pass

    # -- stats ---------------------------------------------------------------
    def node_stats(self) -> dict:
        from .perf_model import fn_key
        depths = [0] * self._n if self._destroyed \
            else [len(l) for l in self._lanes]
        with self._stats_lock:
            cpu_recs = list(self._worker_cpu.values())
            total = sum(i for i, _ in cpu_recs)
            svc_cpu = (sum(i * c for i, c in cpu_recs) / total
                       if total else 0.0)
            s = {
                "node": self._label,
                "backend": "remote",
                "tier": "host_remote",
                "workers": self._n,
                "active": self.active_workers,
                "items": self._seq,
                "delivered": self._delivered,
                "routed_per_worker": list(self._routed),
                "svc_time_ema_s": self.svc_time_ema,
                "svc_cpu_ema_s": svc_cpu,
                "hop_ema_s": self._hop_ema,
                "delivery_gap_ema_s": self._gap_ema,
                "lane_depths": depths,
                "max_lane_depth": max(
                    (l.max_depth for l in self._lanes), default=0),
                "fn_key": fn_key(self._fns[0]),
            }
        if self._lb is not None:
            s["autoscale"] = {"active": self._lb.cur,
                              "grown": self._lb.grown,
                              "shrunk": self._lb.shrunk}
        return s


class RemoteStageHandle:
    """Resizable stage handle over a :class:`RemoteFarmNode`: the runtime
    Supervisor's width policy moves the active remote worker set (cluster
    autoscaling); tier migration does not apply across the wire."""

    reconfigurable = True

    def __init__(self, desc: str, node: RemoteFarmNode):
        self.desc = desc
        self.node = node

    @property
    def tier(self) -> str:
        return "host_remote"

    @property
    def max_width(self) -> int:
        return self.node.width

    def stats(self) -> dict:
        return self.node.node_stats()

    def can_migrate(self, target: str) -> bool:
        return False

    def resize(self, width: int) -> bool:
        self.node.set_active(width)
        return True

    def migrate(self, target: str) -> bool:
        from .graph import GraphError
        raise GraphError(f"stage {self.desc!r} runs on remote hosts; "
                         "tier migration does not apply")
