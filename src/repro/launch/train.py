"""Training launcher: the whole-stack driver behind ``--arch``.

    PYTHONPATH=src python -m repro.launch.train --arch ff-tiny --steps 100
    PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --reduced

On a real TPU fleet this process runs per-host under jax.distributed; on
this container it drives the single CPU device through the same code path.
"""

from __future__ import annotations

import argparse
import json

import jax

from ..configs import get
from ..core.plan import ShardingPlan, single_device_plan
from ..data import SyntheticLMSource, make_pipeline
from ..optim.schedules import cosine_warmup
from ..runtime.driver import DriverConfig, TrainDriver
from ..runtime.steps import init_state, make_train_step
from .mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ff-tiny")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized reduction of the arch")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--adaptive", action="store_true",
                    help="adaptive data pipeline: a runtime Supervisor "
                         "re-places eligible farm stages live and feeds "
                         "observed costs back into the calibration cache")
    ap.add_argument("--tuned", action="store_true",
                    help="tuned host runtime: tcmalloc LD_PRELOAD when "
                         "installed + single-threaded XLA:CPU Eigen "
                         "(re-execs once; see repro.launch.tuned)")
    args = ap.parse_args()
    if args.tuned:
        from .tuned import apply_tuned
        apply_tuned()

    cfg = get(args.arch)
    if args.reduced or args.arch != "ff-tiny":
        cfg = cfg.reduced()
    n_dev = len(jax.devices())
    plan = ShardingPlan(mesh=make_host_mesh(data=n_dev)) if n_dev > 1 \
        else single_device_plan()

    state = init_state(cfg, plan, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M devices={n_dev}")

    src = SyntheticLMSource(cfg.vocab, args.seq, args.batch, seed=0)
    pipe = make_pipeline(src, plan, n_batches=args.steps + 8,
                         adaptive=args.adaptive)
    print(f"data graph: {pipe.graph.describe()}")
    for desc, p in pipe.placements:
        print(f"  [{p.target:6s}] {desc}")
    step = jax.jit(make_train_step(
        cfg, plan, cosine_warmup(args.lr, 20, args.steps)), donate_argnums=0)
    driver = TrainDriver(step, state, pipe,
                         DriverConfig(total_steps=args.steps,
                                      ckpt_every=args.ckpt_every,
                                      ckpt_dir=args.ckpt_dir, log_every=10))
    out = driver.run()
    losses = [h["loss"] for h in out["history"]]
    print(f"final step {out['final_step']}: loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}; restarts={out['restarts']} "
          f"stragglers={out['stragglers']}")
    print("data graph stats (svc-time EMA / items / lane depths):")
    stats = pipe.stats()
    print("  " + json.dumps(stats, default=str))
    # boundary stall report: where the host<->device hop is stall-bound
    # (submit = stack+put+dispatch, drain = compute remainder + d2h copy,
    # stall = drain paid while the in-flight window was full)
    def _boundaries(x, out):
        if isinstance(x, dict):
            if "boundary" in x:
                out.append((x.get("node", "device"), x["boundary"]))
            for v in x.values():
                _boundaries(v, out)
        elif isinstance(x, (list, tuple)):
            for v in x:
                _boundaries(v, out)
    bnds = []
    _boundaries(stats, bnds)
    for node, b in bnds:
        print(f"  boundary[{node}] {b.get('mode')}: "
              f"microbatch={b.get('microbatch')} inflight={b.get('inflight')}"
              f" submit={b.get('submit_s', 0.0):.4f}s "
              f"drain={b.get('drain_s', 0.0):.4f}s "
              f"stall={b.get('stall_frac', 0.0):.0%} of drain")
    if args.adaptive:
        pipe.stop()                 # joins the supervisor, persists observe()
        events = pipe.replacement_events()
        print(f"re-placement events: {len(events)}")
        for e in events:
            print(f"  {e}")


if __name__ == "__main__":
    main()
