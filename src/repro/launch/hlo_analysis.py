"""Post-SPMD HLO analysis: collective inventory + ring-model link bytes.

Shapes in post-partitioning HLO are per-device, so each collective op's
operand size is the per-chip buffer; core.perf_model.collective_link_bytes
turns (kind, operand_bytes, group_size) into per-chip link traffic.

Loop correction: XLA cost analysis (and a flat text scan) counts a ``while``
body once.  Step functions keep layer scans as the only loops; the dry-run
combines the full program's raw counts with per-layer probe programs:

    corrected = full_raw + sum_kind (trips_kind - instances_kind) * probe_kind
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

import numpy as np

from ..core.perf_model import collective_link_bytes

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", )

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # [num_groups, group_size]<=[N] (iota format)
        return int(m.group(2))
    return total_devices


def _group_stride(line: str) -> int:
    """Smallest id distance within the first replica group (explicit format
    only) — used to classify pod-axis (DCI) collectives."""
    m = _GROUPS_BRACE_RE.search(line)
    if not m:
        return 1
    ids = [int(x) for x in m.group(1).split(",") if x.strip() != ""]
    if len(ids) < 2:
        return 1
    return min(abs(b - a) for a, b in zip(ids, ids[1:]))


def _max_component_bytes(type_str: str) -> int:
    best = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        best = max(best, n * _DTYPE_BYTES[dt])
    return best


def parse_collectives(hlo_text: str, total_devices: int,
                      pod_stride: int = 0) -> List[dict]:
    """One record per collective op occurrence (while bodies counted once —
    corrected by the caller).  Operand sizes are derived from the *result*
    type (operand refs in post-opt HLO text carry no types):
      all-gather: operand = result/gs;  reduce-scatter: operand = result*gs;
      all-reduce / all-to-all / permute: operand = result.
    Async (-start) tuples: use the largest array component."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        is_async = m.group(4) is not None
        rtype = m.group(1) if m.group(1) is not None else m.group(2)
        # async -start results are (operand, result) pairs -> take the max
        # component; sync results may be tuples of COMBINED collectives ->
        # sum the components.
        rbytes = (_max_component_bytes(rtype) if is_async
                  else _shape_bytes(rtype))
        gs = _group_size(line, total_devices)
        if kind == "all-gather":
            operand = rbytes / max(gs, 1)
        elif kind == "reduce-scatter":
            operand = rbytes * gs
        else:  # all-reduce, all-to-all, collective-permute
            operand = rbytes
        link = collective_link_bytes(kind, operand, gs)
        stride = _group_stride(line)
        is_dci = bool(pod_stride) and stride >= pod_stride
        out.append({"kind": kind, "operand_bytes": operand,
                    "group_size": gs, "link_bytes": link, "dci": is_dci})
    return out


def total_link_bytes(colls: List[dict]) -> Tuple[float, float]:
    ici = sum(c["link_bytes"] for c in colls if not c["dci"])
    dci = sum(c["link_bytes"] for c in colls if c["dci"])
    return ici, dci


def count_kinds(colls: List[dict]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for c in colls:
        out[c["kind"]] = out.get(c["kind"], 0) + 1
    return out
