"""Serving launcher: the continuous-batching engine behind the typed
client API (``submit`` -> ``RequestHandle``, ``results()``, context-manager
lifecycle) — the paper's accelerator surface remains available on the
engine for compat.

    PYTHONPATH=src python -m repro.launch.serve --arch ff-tiny --requests 8
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import get
from ..core.plan import single_device_plan
from ..runtime.steps import init_state
from ..serving import InferenceEngine, Overloaded, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ff-tiny")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request SLO deadline in seconds: past it a "
                         "request finishes truncated (or is shed before "
                         "admission)")
    ap.add_argument("--exit-threshold", type=float, default=None,
                    help="FastBERT-style early exit: stop decoding a "
                         "request once next-token confidence (max softmax "
                         "prob) reaches this")
    ap.add_argument("--adaptive", action="store_true",
                    help="attach the runtime Supervisor: live stage stats "
                         "sampling, SLO pressure-level control, cost-model "
                         "observation (events land in the report)")
    ap.add_argument("--tuned", action="store_true",
                    help="tuned host runtime: tcmalloc LD_PRELOAD when "
                         "installed + single-threaded XLA:CPU Eigen "
                         "(re-execs once; see repro.launch.tuned)")
    args = ap.parse_args()
    if args.tuned:
        from .tuned import apply_tuned
        apply_tuned()

    cfg = get(args.arch)
    if args.arch != "ff-tiny":
        cfg = cfg.reduced()
    plan = single_device_plan()
    params = init_state(cfg, plan, jax.random.PRNGKey(0))["params"]

    eng = InferenceEngine(cfg, plan, params, max_batch=args.max_batch,
                          cache_len=args.cache_len, adaptive=args.adaptive,
                          exit_threshold=args.exit_threshold)
    print(f"engine graph: {eng.graph.describe()}")
    for desc, p in eng.placements:
        print(f"  [{p.target:6s}] {desc}")
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    total_toks = shed = 0
    with eng:
        for i in range(args.requests):
            eng.submit(Request(
                prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                    dtype=np.int32),
                max_new_tokens=args.max_new, deadline_s=args.deadline))
    for out in eng.results():
        if isinstance(out, Overloaded):
            shed += 1
            print(f"req {out.request.id}: SHED ({out.reason})")
            continue
        total_toks += len(out.tokens)
        print(f"req {out.id}: {len(out.tokens)} tokens "
              f"[{out.finish_reason}] in "
              f"{(out.finish_t - out.submit_t)*1e3:.0f} ms")
    dt = time.perf_counter() - t0
    print(f"served {args.requests - shed}/{args.requests} requests, "
          f"{total_toks} tokens in {dt:.2f}s ({total_toks/dt:.1f} tok/s); "
          f"decode steps={eng.steps}, early exits={eng.early_exits}, "
          f"shed={eng.shed_count}")
    print("engine graph stats (svc-time EMA / cache occupancy / SLO):")
    print("  " + json.dumps(eng.stats(), default=str))
    if args.adaptive:
        events = eng.replacement_events()
        print(f"re-placement events: {len(events)}"
              + (f" (supervisor {eng.supervisor.stats()})"
                 if eng.supervisor else ""))
        for e in events:
            print(f"  {e}")


if __name__ == "__main__":
    main()
