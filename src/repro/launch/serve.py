"""Serving launcher: continuous-batching engine behind the paper's
accelerator API.

    PYTHONPATH=src python -m repro.launch.serve --arch ff-tiny --requests 8
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import get
from ..core import FF_EOS
from ..core.plan import single_device_plan
from ..runtime.steps import init_state
from ..serving import InferenceEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ff-tiny")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--adaptive", action="store_true",
                    help="attach the runtime Supervisor: live stage stats "
                         "sampling + cost-model observation (re-placement "
                         "events land in the placement report)")
    ap.add_argument("--tuned", action="store_true",
                    help="tuned host runtime: tcmalloc LD_PRELOAD when "
                         "installed + single-threaded XLA:CPU Eigen "
                         "(re-execs once; see repro.launch.tuned)")
    args = ap.parse_args()
    if args.tuned:
        from .tuned import apply_tuned
        apply_tuned()

    cfg = get(args.arch)
    if args.arch != "ff-tiny":
        cfg = cfg.reduced()
    plan = single_device_plan()
    params = init_state(cfg, plan, jax.random.PRNGKey(0))["params"]

    eng = InferenceEngine(cfg, plan, params, max_batch=args.max_batch,
                          cache_len=args.cache_len, adaptive=args.adaptive)
    print(f"engine graph: {eng.graph.describe()}")
    for desc, p in eng.placements:
        print(f"  [{p.target:6s}] {desc}")
    eng.run_then_freeze()
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.offload(Request(
            prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                dtype=np.int32),
            max_new_tokens=args.max_new, id=i))
    eng.offload(FF_EOS)
    total_toks = 0
    while True:
        ok, req = eng.load_result()
        if not ok:
            break
        total_toks += len(req.tokens)
        print(f"req {req.id}: {len(req.tokens)} tokens in "
              f"{(req.finish_t - req.submit_t)*1e3:.0f} ms")
    eng.wait()
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests, {total_toks} tokens in "
          f"{dt:.2f}s ({total_toks/dt:.1f} tok/s); decode steps={eng.steps}")
    print("engine graph stats (svc-time EMA / items / lane depths):")
    print("  " + json.dumps(eng.stats(), default=str))
    if args.adaptive:
        events = eng.replacement_events()
        print(f"re-placement events: {len(events)}"
              + (f" (supervisor {eng.supervisor.stats()})"
                 if eng.supervisor else ""))
        for e in events:
            print(f"  {e}")


if __name__ == "__main__":
    main()
