"""Production mesh construction.

A FUNCTION (not module-level state) so importing this module never touches
jax device initialization.  The dry-run entrypoint (dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any import.
"""

from __future__ import annotations

import jax


def _mesh_kwargs(axes):
    # jax < 0.5 has no sharding.AxisType; Auto is its only behaviour anyway
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * len(axes)}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(axes))


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes), **_mesh_kwargs(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) devices exist — used by
    tests and CPU examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return make_mesh((data, model), ("data", "model"))
