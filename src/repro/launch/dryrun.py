import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture x input shape x mesh) cell:
  jax.jit(step).lower(**ShapeDtypeStruct stand-ins).compile()
must succeed on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh.
We record memory_analysis() (fits 16 GiB/chip?), cost_analysis() FLOPs/bytes,
and the collective schedule parsed from the compiled HLO.

Loop-corrected costs: the only ``while`` loops in any step are the layer
scans; per-layer probe programs (same shardings, same remat) are compiled
separately and combined as
    corrected = full_raw + sum_kind (trips - instances) * probe_kind
(see DESIGN.md §7 and launch/hlo_analysis.py).

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  python -m repro.launch.dryrun --all            # resumable sweep
  python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import gc
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ASSIGNED, SHAPES, batch_specs, cache_specs, get
from ..core.perf_model import TPU_V5E, roofline
from ..core.plan import ShardingPlan
from ..models import params as pp
from ..models.lm import LM, apply_block, block_defs, _cache_struct
from ..optim.schedules import cosine_warmup
from ..runtime.steps import (make_decode_step, make_prefill_step,
                             make_train_step, state_structs)
from .hlo_analysis import count_kinds, parse_collectives, total_link_bytes
from .mesh import make_production_mesh

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


# ---------------------------------------------------------------------------
def _sds(shape, dtype, plan, axes):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=plan.sharding_for(axes, shape))


def _compile_and_analyze(fn, args, n_dev, pod_stride, loop_corr=None,
                         donate=()):
    t0 = time.time()
    lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
    compiled = lowered.compile()
    compile_s = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    colls = parse_collectives(txt, n_dev, pod_stride)
    rec = {
        "compile_s": round(compile_s, 2),
        "mem": {
            "argument_gib": ma.argument_size_in_bytes / 2**30,
            "output_gib": ma.output_size_in_bytes / 2**30,
            "temp_gib": ma.temp_size_in_bytes / 2**30,
            "peak_gib": (ma.argument_size_in_bytes
                         + ma.temp_size_in_bytes) / 2**30,
        },
        "flops_raw": float(ca.get("flops", 0.0)),
        "bytes_raw": float(ca.get("bytes accessed", 0.0)),
        "collectives_raw": count_kinds(colls),
        "coll_ici_raw": total_link_bytes(colls)[0],
        "coll_dci_raw": total_link_bytes(colls)[1],
        "top_collectives": sorted(
            colls, key=lambda c: -c["link_bytes"])[:8],
    }
    if os.environ.get("REPRO_SAVE_HLO"):
        rec["_hlo_text"] = txt
    return rec, compiled


def _probe(cfg, plan, kind, mode, B, S):
    """Compile a single-block probe with production shardings; returns raw
    per-layer (flops, bytes, ici, dci)."""
    n_dev = plan.mesh.devices.size
    pod_stride = 256 if "pod" in plan.mesh.axis_names else 0
    pdefs = block_defs(kind, cfg, None)
    pl_structs = pp.shape_structs(pdefs, plan)
    Sx = 1 if mode == "decode" else S
    x = _sds((B, Sx, cfg.d_model), jnp.bfloat16, plan,
             ("batch", "sp" if mode != "decode" else None, None))

    extra = {}
    if kind == "dec":
        enc_len = cfg.enc_len if mode == "decode" else max(32, S)
        extra["enc_out"] = _sds((B, cfg.enc_len if mode == "decode" else S,
                                 cfg.d_model), jnp.bfloat16, plan,
                                ("batch", "sp", None))

    cache_arg = None
    if mode == "decode":
        cs = _cache_struct(kind, cfg, B, cfg.cache_len or S, 1)
        def leaf(t):
            shape, dtype, axes = t
            return _sds(tuple(shape[1:]), dtype, plan, tuple(axes[1:]))
        cache_arg = jax.tree.map(
            leaf, cs, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3
            and isinstance(t[0], tuple))

    mp = None
    if cfg.mrope:
        mp = _sds((3, B, Sx), jnp.int32, plan, (None, "batch", None))

    def positions(S_):
        return jnp.broadcast_to(jnp.arange(S_)[None], (B, S_))

    if mode == "train":
        def probe(x, pl, mrope=None, enc_out=None):
            def f(x, pl):
                y, _, aux = apply_block(kind, x, pl, cfg, plan, mode="train",
                                        positions=positions(Sx),
                                        mrope_positions=mrope,
                                        enc_out=enc_out)
                s = jnp.sum(y.astype(jnp.float32))
                for v in (aux or {}).values():
                    s = s + jnp.sum(v)
                return s
            g = jax.grad(jax.checkpoint(f), argnums=(0, 1))(x, pl)
            return g
        args = [x, pl_structs]
        if cfg.mrope:
            probe_fn = lambda x, pl, mp: probe(x, pl, mrope=mp)
            args.append(mp)
        elif kind == "dec":
            probe_fn = lambda x, pl, eo: probe(x, pl, enc_out=eo)
            args.append(extra["enc_out"])
        else:
            probe_fn = lambda x, pl: probe(x, pl)
    elif mode == "prefill":
        def probe_fn(x, pl, *rest):
            mrope = rest[0] if cfg.mrope else None
            enc_out = rest[0] if (kind == "dec" and not cfg.mrope) else None
            return apply_block(kind, x, pl, cfg, plan, mode="prefill",
                               cache="init", positions=positions(Sx),
                               mrope_positions=mrope, enc_out=enc_out)[:2]
        args = [x, pl_structs]
        if cfg.mrope:
            args.append(mp)
        elif kind == "dec":
            args.append(extra["enc_out"])
    else:
        pos = _sds((B, 1), jnp.int32, plan, ("batch", None))
        def probe_fn(x, pl, cache, pos_, *rest):
            mrope = rest[0] if cfg.mrope else None
            return apply_block(kind, x, pl, cfg, plan, mode="decode",
                               cache=cache, positions=pos_, pos_offset=0,
                               mrope_positions=mrope)[:2]
        args = [x, pl_structs, cache_arg, pos]
        if cfg.mrope:
            args.append(mp)

    rec, _ = _compile_and_analyze(probe_fn, args, n_dev, pod_stride)
    return rec


def _probe_micro(cfg, plan, shape, B_micro):
    """Compile one microbatch's value_and_grad(loss) with production
    shardings — the grad-accumulation body for two-level loop correction."""
    from ..models.lm import LM
    n_dev = plan.mesh.devices.size
    pod_stride = 256 if "pod" in plan.mesh.axis_names else 0
    model = LM(cfg)
    pstructs = pp.shape_structs(model.param_defs(), plan)
    batch = batch_specs(cfg, shape, plan, batch=B_micro)

    def micro(params, b):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(p, b, plan), has_aux=True)(params)
        return loss, grads

    rec, _ = _compile_and_analyze(micro, (pstructs, batch), n_dev,
                                  pod_stride)
    return rec


# ---------------------------------------------------------------------------
def run_cell(arch: str, shape: str, multi_pod: bool = False,
             plan_overrides=None, tag: str = "", verbose: bool = True,
             cfg_overrides=None):
    import dataclasses
    cfg = get(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    sh = SHAPES[shape]
    mode = sh["mode"]
    if not cfg.supports(shape):
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "skipped": True, "reason": cfg.skip_reason(shape)}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    pod_stride = 256 if multi_pod else 0
    plan = ShardingPlan(mesh=mesh)
    if plan_overrides:
        for k, v in plan_overrides.items():
            setattr(plan, k, v)

    B, S = sh["batch"], sh["seq"]
    # keep the unrolled attention q/kv block loops bounded (compile time
    # scales with unrolled block count; VMEM-sized tiles stay the kernel's
    # job — see kernels/flash_attention.py)
    if mode != "decode" and S >= 32768:
        cfg.q_block = max(cfg.q_block, S // 8)
        cfg.kv_block = max(cfg.kv_block, S // 8)
    result = {"arch": arch, "shape": shape, "mesh": "2x16x16" if multi_pod
              else "16x16", "multi_pod": multi_pod, "mode": mode, "tag": tag,
              "batch": B, "seq": S, "chips": n_dev}
    t_start = time.time()
    try:
        if mode == "train":
            step = make_train_step(cfg, plan, cosine_warmup(3e-4, 100, 10000))
            state = state_structs(cfg, plan)
            batch = batch_specs(cfg, shape, plan)
            rec, compiled = _compile_and_analyze(
                step, (state, batch), n_dev, pod_stride, donate=(0,))
        elif mode == "prefill":
            model = LM(cfg)
            pstructs = pp.shape_structs(model.param_defs(), plan)
            step = make_prefill_step(cfg, plan, cache_len=S)
            batch = batch_specs(cfg, shape, plan)
            rec, compiled = _compile_and_analyze(
                step, (pstructs, batch), n_dev, pod_stride)
        else:
            model = LM(cfg)
            pstructs = pp.shape_structs(model.param_defs(), plan)
            caches = cache_specs(cfg, B, S, plan)
            step = make_decode_step(cfg, plan, cache_len=S)
            batch = batch_specs(cfg, shape, plan)
            rec, compiled = _compile_and_analyze(
                step, (pstructs, caches, batch), n_dev, pod_stride,
                donate=(1,))
        if "_hlo_text" in rec:
            try:
                import zstandard as zstd
                hdir = RESULTS_DIR / "hlo"
                hdir.mkdir(parents=True, exist_ok=True)
                hp = hdir / (f"{arch}__{shape}__"
                             f"{'mp' if multi_pod else 'sp'}"
                             f"{('__' + tag) if tag else ''}.hlo.zst")
                hp.write_bytes(zstd.ZstdCompressor(level=9).compress(
                    rec.pop("_hlo_text").encode()))
                result["hlo_path"] = str(hp)
            except Exception:   # noqa: BLE001
                rec.pop("_hlo_text", None)
        result.update(rec)
        del compiled
    except Exception as e:  # noqa: BLE001
        result["ok"] = False
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-3000:]
        return result

    # --- loop-corrected totals -------------------------------------------------
    model = LM(cfg)
    if mode in ("prefill", "decode"):
        cfg.cache_len = (min(S, cfg.window) if cfg.attn_kind == "swa" else S)
    loop_specs = model.loop_specs("decode" if mode == "decode" else mode)
    n_micro = cfg.n_microbatches if mode == "train" else 1
    B_micro = B // n_micro
    flops = result["flops_raw"]
    byts = result["bytes_raw"]
    ici = result["coll_ici_raw"]
    dci = result["coll_dci_raw"]
    probes = {}
    dec_len = max(32, S // 8) if cfg.family == "encdec" else S

    def layer_corrections(base):
        """sum over kinds of (trips - instances) * per-layer probe costs."""
        f = b = i = d = 0.0
        for kind, trips, instances in loop_specs:
            if trips <= instances:
                continue
            try:
                S_probe = dec_len if kind == "dec" else S
                prec = _probe(cfg, plan, kind, mode, B_micro, S_probe)
            except Exception as e:  # noqa: BLE001
                result["probe_error_" + kind] = f"{type(e).__name__}: {e}"
                continue
            probes[kind] = prec
            k = trips - instances
            f += k * prec["flops_raw"]
            b += k * prec["bytes_raw"]
            i += k * prec["coll_ici_raw"]
            d += k * prec["coll_dci_raw"]
        return f, b, i, d

    cf, cb, ci_, cd = layer_corrections(result)
    if n_micro > 1:
        # full = outside + 1x micro_body(raw); true = outside + n*micro_true
        # -> probe one microbatch's value_and_grad with identical shardings
        try:
            mp_rec = _probe_micro(cfg, plan, shape, B_micro)
            probes["_micro"] = mp_rec
            micro_true = {
                "flops": mp_rec["flops_raw"] + cf,
                "bytes": mp_rec["bytes_raw"] + cb,
                "ici": mp_rec["coll_ici_raw"] + ci_,
                "dci": mp_rec["coll_dci_raw"] + cd,
            }
            flops = flops - mp_rec["flops_raw"] + n_micro * micro_true["flops"]
            byts = byts - mp_rec["bytes_raw"] + n_micro * micro_true["bytes"]
            ici = ici - mp_rec["coll_ici_raw"] + n_micro * micro_true["ici"]
            dci = dci - mp_rec["coll_dci_raw"] + n_micro * micro_true["dci"]
        except Exception as e:  # noqa: BLE001
            result["probe_error_micro"] = f"{type(e).__name__}: {e}"
            flops += n_micro * cf
            byts += n_micro * cb
            ici += n_micro * ci_
            dci += n_micro * cd
    else:
        flops += cf
        byts += cb
        ici += ci_
        dci += cd
    result["probes"] = probes
    result["loop_specs"] = loop_specs
    result["n_micro"] = n_micro
    result["flops_per_dev"] = flops
    result["bytes_per_dev"] = byts
    result["coll_ici_per_dev"] = ici
    result["coll_dci_per_dev"] = dci

    # --- roofline ---------------------------------------------------------------
    mf = cfg.model_flops(shape)
    terms = roofline(flops * n_dev, byts * n_dev, ici, n_dev,
                     coll_bytes_dci_per_chip=dci, model_flops=mf)
    result["roofline"] = {
        "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "collective_s": terms.collective_s, "dominant": terms.dominant,
        "step_time_s": terms.step_time_s,
        "model_flops": mf,
        "model_flops_s": terms.model_flops_s,
        "useful_flops_ratio": mf / max(flops * n_dev, 1.0),
        "roofline_fraction": terms.roofline_fraction,
    }
    result["ok"] = result["mem"]["peak_gib"] <= TPU_V5E.hbm_bytes / 2**30
    result["fits_hbm"] = result["ok"]
    result["ok"] = True   # compile success is the dry-run gate; HBM noted
    result["wall_s"] = round(time.time() - t_start, 1)
    if verbose:
        r = result["roofline"]
        print(f"[{arch} x {shape} x {result['mesh']}{tag}] ok "
              f"compile={result['compile_s']}s peak={result['mem']['peak_gib']:.2f}GiB "
              f"terms(c/m/n)={r['compute_s']:.4f}/{r['memory_s']:.4f}/"
              f"{r['collective_s']:.4f}s dom={r['dominant']} "
              f"frac={r['roofline_fraction']:.3f}", flush=True)
    return result


def cell_path(arch, shape, multi_pod, tag=""):
    m = "mp" if multi_pod else "sp"
    t = f"__{tag}" if tag else ""
    return RESULTS_DIR / f"{arch}__{shape}__{m}{t}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--seq-parallel", dest="sp", default=None,
                    choices=["on", "off"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    metavar="key=value",
                    help="Config override, e.g. --set n_microbatches=8")
    args = ap.parse_args()
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)

    overrides = {}
    if args.sp == "off":
        overrides["sequence_parallel"] = False
    if args.no_fsdp:
        overrides["fsdp_params"] = False
    cfg_overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        cfg_overrides[k] = v

    if args.all:
        cells = [(a, s, mp) for a in ASSIGNED for s in SHAPES
                 for mp in ((False, True) if args.multi_pod in (False,)
                            else (True,))]
        # single-pod first (roofline table), then multi-pod
        cells.sort(key=lambda c: (c[2], c[0], c[1]))
        for a, s, mp in cells:
            p = cell_path(a, s, mp, args.tag)
            if p.exists() and not args.force:
                continue
            res = run_cell(a, s, mp, plan_overrides=overrides, tag=args.tag,
                           cfg_overrides=cfg_overrides)
            p.write_text(json.dumps(res, indent=1, default=str))
            gc.collect()
        return

    res = run_cell(args.arch, args.shape, args.multi_pod,
                   plan_overrides=overrides, tag=args.tag,
                   cfg_overrides=cfg_overrides)
    p = cell_path(args.arch, args.shape, args.multi_pod, args.tag)
    p.write_text(json.dumps(res, indent=1, default=str))
    if not res.get("ok", False) and not res.get("skipped"):
        print(res.get("error"))
        print(res.get("traceback", "")[-2000:])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
