"""Tuned host-runtime preset for the launchers (``--tuned``).

Two environment-level wins for host-tier streaming workers, applied by
re-exec so they land *before* the interpreter loads numpy/jax:

* **tcmalloc** — ``LD_PRELOAD`` a thread-caching malloc when one is
  installed.  The process-tier farm workers allocate per-item (pickle
  buffers, ndarray copies out of the shm rings); glibc malloc's central
  arena lock serializes exactly the hot path the transport just
  parallelized.  Detection only — no tcmalloc on the box means no preload,
  never a failure.
* **single-threaded Eigen** — ``XLA_FLAGS`` pins XLA:CPU to one intra-op
  thread (``--xla_cpu_multi_thread_eigen=false intra_op_parallelism_
  threads=1``).  Farm workers already occupy every core; letting each
  worker's XLA spin up its own Eigen pool oversubscribes the machine and
  destroys the placement math.

``apply_tuned()`` is idempotent across the re-exec (an env guard breaks
the loop) and a no-op when the environment is already tuned.
"""

from __future__ import annotations

import glob
import os
import sys
from typing import Dict, List, Optional

# set in the re-exec'd child so the second pass through apply_tuned()
# knows the environment is already in place
_GUARD = "REPRO_FF_TUNED"

# one intra-op thread per worker process: the farm supplies the parallelism
_XLA_TUNED = ("--xla_cpu_multi_thread_eigen=false "
              "intra_op_parallelism_threads=1")

# silence tcmalloc's large-alloc reports for big ndarray slabs
_TCMALLOC_THRESHOLD = "60000000000"

_TCMALLOC_CANDIDATES = [
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc_minimal.so.4",
]


def find_tcmalloc() -> Optional[str]:
    """Path of an installed tcmalloc shared object, or None."""
    for path in _TCMALLOC_CANDIDATES:
        if os.path.exists(path):
            return path
    for pat in ("/usr/lib/*/libtcmalloc*.so*", "/usr/lib/libtcmalloc*.so*"):
        hits = sorted(glob.glob(pat))
        if hits:
            return hits[0]
    return None


def tuned_env(base: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """The environment deltas the tuned preset wants on top of ``base``
    (default: ``os.environ``).  Pure — computes, never mutates."""
    env = dict(os.environ if base is None else base)
    delta: Dict[str, str] = {}
    tc = find_tcmalloc()
    if tc is not None and tc not in env.get("LD_PRELOAD", ""):
        preload = env.get("LD_PRELOAD", "")
        delta["LD_PRELOAD"] = f"{preload}:{tc}".lstrip(":")
        delta.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                         _TCMALLOC_THRESHOLD)
    if "--xla_cpu_multi_thread_eigen" not in env.get("XLA_FLAGS", ""):
        flags = env.get("XLA_FLAGS", "")
        delta["XLA_FLAGS"] = f"{flags} {_XLA_TUNED}".strip()
    return delta


def apply_tuned(argv: Optional[List[str]] = None) -> bool:
    """Apply the tuned preset, re-exec'ing the current program once so
    ``LD_PRELOAD``/``XLA_FLAGS`` precede every library load.  Returns False
    when the environment is already tuned (including the post-re-exec pass)
    — the caller just continues; does not return otherwise."""
    if os.environ.get(_GUARD) == "1":
        return False
    delta = tuned_env()
    if not delta:
        return False
    os.environ.update(delta)
    os.environ[_GUARD] = "1"
    args = sys.argv if argv is None else argv
    mod = _main_module()
    cmd = ([sys.executable, "-m", mod] + args[1:] if mod
           else [sys.executable] + args)
    sys.stdout.flush()
    sys.stderr.flush()
    os.execv(sys.executable, cmd)


def _main_module() -> Optional[str]:
    """``python -m repro.launch.X`` spelling of the running launcher, so the
    re-exec preserves the module entry point (sys.argv[0] is the script
    path, which ``-m`` launches don't want back)."""
    main = sys.modules.get("__main__")
    spec = getattr(main, "__spec__", None)
    name = getattr(spec, "name", None)
    return name if name else None
