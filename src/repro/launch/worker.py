"""Remote worker-pool entry point for the distributed (``host_remote``) tier.

    PYTHONPATH=src python -m repro.launch.worker --listen 0.0.0.0:7001

Starts a ``core.net.worker_main`` pool: a TCP listener whose connections
each speak the shm slot protocol (length-prefixed frames, u64 sequence
numbers, EOS/ERR control, credit-window back-pressure, heartbeats).  The
worker has no code of its own — the first frame on every connection is a
pickled service callable (``TAG_FN`` handshake) shipped by the compiling
side, so one pool serves any ``compile(remote_workers=[...])`` program.

Two-"host" loopback run (both "hosts" on one machine, distinct ports):

    # terminal 1 — "host" A
    PYTHONPATH=src python -m repro.launch.worker --listen 127.0.0.1:7001

    # terminal 2 — "host" B
    PYTHONPATH=src python -m repro.launch.worker --listen 127.0.0.1:7002

    # terminal 3 — the program: farm workers live in the two pools
    PYTHONPATH=src python - <<'EOF'
    import numpy as np
    from repro.core import CompileConfig, FFGraph, farm, pipeline, seq

    def heavy(x):                      # GIL-bound: remote tier pays off
        return np.tanh(x @ x.T).sum()

    g = FFGraph(pipeline(
        seq(iter(np.random.default_rng(0)
                   .standard_normal((64, 32, 32), dtype=np.float32))),
        farm(heavy, n=2),
        seq(print),
    ))
    g.compile(config=CompileConfig(
        mode="remote",
        remote_workers=["127.0.0.1:7001", "127.0.0.1:7002"])).run()
    EOF

``--listen host:0`` binds an ephemeral port and prints the bound address
on stdout (``listening <host>:<port> pid=<pid>``) so a launcher script can
scrape it.
"""

from __future__ import annotations

import argparse
import os

import numpy as np

from ..core import net


def demo_fn(x):
    """Default service used by ``--demo`` smoke runs and the CLI test:
    square numerics elementwise, echo anything else back."""
    if isinstance(x, np.ndarray) or isinstance(x, (int, float)):
        return x * x
    return x


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="repro.launch.worker",
        description="remote worker pool for host_remote farm stages")
    ap.add_argument("--listen", required=True, metavar="HOST:PORT",
                    help="bind address; PORT 0 picks an ephemeral port "
                         "(printed on stdout)")
    ap.add_argument("--slots", type=int, default=4,
                    help="accept backlog / expected concurrent lanes")
    ap.add_argument("--credit", type=int, default=32,
                    help="in-flight credit window granted per lane")
    ap.add_argument("--hb-interval", type=float, default=0.5,
                    help="heartbeat period in seconds")
    ap.add_argument("--max-conns", type=int, default=None,
                    help="serve this many connections then exit "
                         "(default: forever)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the 'listening' line")
    args = ap.parse_args(argv)

    host, port = net.parse_addr(args.listen)

    def announce(h, p):
        if not args.quiet:
            print(f"listening {h}:{p} pid={os.getpid()}", flush=True)

    net.worker_main(host, port,
                    slots=args.slots,
                    credit=args.credit,
                    hb_interval=args.hb_interval,
                    max_conns=args.max_conns,
                    announce=announce,
                    quiet=True)


if __name__ == "__main__":
    main()
