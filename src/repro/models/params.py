"""Parameter definition trees.

A model builds a pytree of :class:`ParamDef` leaves; from it we derive
(1) real initialized parameters (tests/examples), (2) ShapeDtypeStruct
stand-ins (multi-pod dry-run — never allocated), and (3) NamedShardings via
the logical axes recorded on every def (consumed by core.plan.ShardingPlan).

Logical dim names used by models:
  'layers'  stacked scan dim (never sharded)
  'fsdp'    ZeRO-3 shard dim (-> data axis)
  'tp'      tensor-parallel dim (-> model axis): heads / ffn / vocab
  'expert'  expert-parallel dim (-> model axis)
  None      replicated
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"          # normal | zeros | ones | embed
    scale: float = 1.0            # fan-in style scale applied by _init_leaf

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(d: ParamDef, key) -> jnp.ndarray:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    # fan-in scaled truncated normal
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    std = d.scale / math.sqrt(max(fan_in, 1))
    if d.init == "embed":
        std = d.scale
    x = jax.random.truncated_normal(key, -2.0, 2.0, d.shape, jnp.float32) * std
    return x.astype(d.dtype)


def init_params(defs, key):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def shape_structs(defs, plan=None):
    """ShapeDtypeStructs (with shardings when a plan is given): the dry-run
    stand-ins — no device allocation ever happens."""
    def leaf(d: ParamDef):
        if plan is None:
            return jax.ShapeDtypeStruct(d.shape, d.dtype)
        return jax.ShapeDtypeStruct(d.shape, d.dtype,
                                    sharding=plan.sharding_for(d.axes, d.shape))
    return jax.tree.map(leaf, defs, is_leaf=is_def)


def shardings(defs, plan):
    return jax.tree.map(
        lambda d: jax.sharding.NamedSharding(
            plan.mesh, plan.param_spec(d.axes, d.shape)),
        defs, is_leaf=is_def)


def pspecs(defs, plan):
    return jax.tree.map(lambda d: plan.param_spec(d.axes, d.shape),
                        defs, is_leaf=is_def)


def count_params(defs) -> int:
    return sum(math.prod(d.shape) for d in
               jax.tree.leaves(defs, is_leaf=is_def))


def bytes_params(defs) -> int:
    return sum(math.prod(d.shape) * jnp.dtype(d.dtype).itemsize
               for d in jax.tree.leaves(defs, is_leaf=is_def))
