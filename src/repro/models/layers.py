"""Common layers: norms, GLU MLPs, embeddings, RoPE / M-RoPE.

All matmuls run in bf16 with fp32 normalization/softmax statistics.
Sharding is expressed only through logical axes (models/params.py) and
``plan.constrain`` — never mesh axes directly.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .params import ParamDef


# -- norms -------------------------------------------------------------------
def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    m = jnp.mean(x, -1, keepdims=True)
    v = jnp.mean((x - m) ** 2, -1, keepdims=True)
    x = (x - m) * jax.lax.rsqrt(v + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_defs(d_model: int, kind: str = "rms", layers: Optional[int] = None):
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    if kind == "rms":
        return {"w": ParamDef(lead + (d_model,), lax_ + (None,), init="zeros")}
    return {"w": ParamDef(lead + (d_model,), lax_ + (None,), init="ones"),
            "b": ParamDef(lead + (d_model,), lax_ + (None,), init="zeros")}


def apply_norm(x, p, kind: str = "rms"):
    if kind == "rms":
        return rms_norm(x, p["w"])
    return layer_norm(x, p["w"], p["b"])


# -- GLU MLP (SwiGLU / GeGLU) --------------------------------------------------
def mlp_defs(d_model: int, d_ff: int, layers: Optional[int] = None):
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "wi": ParamDef(lead + (d_model, d_ff), la + ("fsdp", "tp")),
        "wg": ParamDef(lead + (d_model, d_ff), la + ("fsdp", "tp")),
        "wo": ParamDef(lead + (d_ff, d_model), la + ("tp", "fsdp")),
    }


def mlp(x, p, act: str = "silu", plan=None):
    if plan is not None:
        # SP boundary: gather the (bf16) norm output over the seq shards
        # here, not at some f32 intermediate GSPMD picks
        x = plan.constrain(x, "batch", None, None)
    wi = plan.gather_fsdp(p["wi"], ("fsdp", "tp")) if plan else p["wi"]
    wg = plan.gather_fsdp(p["wg"], ("fsdp", "tp")) if plan else p["wg"]
    wo = plan.gather_fsdp(p["wo"], ("tp", "fsdp")) if plan else p["wo"]
    a = jnp.einsum("bsd,df->bsf", x, wi)
    g = jnp.einsum("bsd,df->bsf", x, wg)
    g = jax.nn.gelu(g) if act == "gelu" else jax.nn.silu(g)
    # bf16 partials + immediate sp constraint: the cross-shard reduction
    # lowers to a bf16 reduce-scatter instead of an f32 all-reduce
    o = jnp.einsum("bsf,fd->bsd", a * g, wo,
                   preferred_element_type=jnp.bfloat16)
    if plan is not None:
        o = plan.constrain(o, "batch", "sp", None)
    return o


# -- embeddings ----------------------------------------------------------------
def embed_defs(vocab: int, d_model: int, tie: bool = False):
    d = {"emb": ParamDef((vocab, d_model), ("tp", "fsdp"), init="embed",
                         scale=1.0)}
    if not tie:
        d["unemb"] = ParamDef((d_model, vocab), ("fsdp", "tp"))
    return d


def embed(tokens, p, d_model: int):
    # gather; vocab-sharded -> XLA turns this into a sharded one-hot matmul
    return p["emb"][tokens].astype(jnp.bfloat16)


def unembed(x, p):
    w = p.get("unemb")
    if w is None:
        w = p["emb"].T
    return jnp.einsum("bsd,dv->bsv", x, w)


# -- rotary position embeddings -------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B,S,d/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def apply_mrope(x, positions_thw, theta: float = 1e4, sections=(16, 24, 24)):
    """Qwen2-VL M-RoPE: head_dim/2 split into (t, h, w) frequency sections,
    each rotated by its own position id.  positions_thw: (3, B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = rope_freqs(d, theta)                       # (half,)
    # build per-frequency position: section s of the spectrum uses pos[s]
    sec = jnp.zeros((half,), jnp.int32)
    start = 0
    tot = sum(sections)
    scaled = [int(round(s / tot * half)) for s in sections]
    scaled[-1] = half - sum(scaled[:-1])
    for i, n in enumerate(scaled):
        sec = sec.at[start:start + n].set(i)
        start += n
    # (B,S,half): select the right (t/h/w) position stream per frequency
    p = jnp.moveaxis(positions_thw, 0, -1).astype(jnp.float32)   # (B,S,3)
    psel = p[..., sec]                                           # (B,S,half)
    ang = psel * freqs[None, None, :]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)
