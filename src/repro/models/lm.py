"""Unified language-model backbone covering all assigned architectures.

A model is a sequence of *segments*, each a homogeneous stack of blocks run
under ``lax.scan`` (stacked params, full remat).  Block kinds:

  dense        GQA attention + GLU MLP           (gemma/yi/mistral/llama/...)
  moe          GQA attention + MoE farm          (kimi, mixtral)
  mamba2       SSD state-space block             (zamba2 backbone)
  mlstm/slstm  xLSTM blocks                      (xlstm-125m)
  shared_attn  zamba2's shared transformer block (same params every call —
               the broadcast/MISD farm: one task stream, one worker reused)

Families 'encdec' (whisper) and 'vlm' (qwen2-vl) reuse the same machinery
with stub frontends (precomputed frame/patch embeddings per the assignment).

Sharding: only logical axes (core/plan.py).  Embedding and cross-entropy are
vocab-parallel (Megatron-style shard_map) so full logits are never
materialized.  The layer scans are the *only* ``while`` loops in any step
function — launch/dryrun.py relies on this for exact loop-corrected cost
accounting (see DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
try:
    from jax import shard_map as _shard_map_fn
    def shard_map(f, mesh, in_specs, out_specs, check_rep=False):
        return _shard_map_fn(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_rep)
except ImportError:  # older jax
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .attention import attention, attn_defs, cross_attention, cross_kv
from .layers import apply_norm, mlp, mlp_defs, norm_defs
from .moe import moe_block, moe_defs
from .params import ParamDef, init_params, shape_structs
from .ssm import mamba2_block, mamba2_defs, mamba2_state_defs
from .xlstm import (mlstm_block, mlstm_defs, mlstm_state_defs, slstm_block,
                    slstm_defs, slstm_state_defs)


# ---------------------------------------------------------------------------
# vocab-parallel embedding / cross entropy
# ---------------------------------------------------------------------------
def vocab_parallel_embed(tokens, emb, plan):
    mesh = plan.mesh
    b_ax, m_ax = plan.axes("batch"), plan.axes("tp")
    if m_ax is None:
        return emb[tokens].astype(jnp.bfloat16)
    b_ax = plan._fit_dim(tokens.shape[0], "batch")
    tp = plan.tp
    V = emb.shape[0]
    Vl = V // tp
    S = tokens.shape[1]
    seq_scatter = (S % tp == 0) and plan.sequence_parallel

    def body(tok, emb_l):
        idx = lax.axis_index(m_ax)
        loc = tok - idx * Vl
        ok = (loc >= 0) & (loc < Vl)
        e = emb_l[jnp.clip(loc, 0, Vl - 1)] * ok[..., None].astype(emb_l.dtype)
        e = e.astype(jnp.bfloat16)
        if seq_scatter:
            return lax.psum_scatter(e, m_ax, scatter_dimension=1, tiled=True)
        return lax.psum(e, m_ax)

    out_spec = P(b_ax, m_ax if seq_scatter else None, None)
    return shard_map(body, mesh=mesh,
                     in_specs=(P(b_ax, None), P("model", None)),
                     out_specs=out_spec, check_rep=False)(tokens, emb)


def vocab_parallel_ce(x, unemb, labels, mask, plan, chunks: int = 1):
    """Mean CE over masked tokens; logits never materialized beyond a
    (B_loc, S/chunks, V/tp) fp32 tile.  x: (B,S,d) seq-sharded; labels (B,S)."""
    mesh = plan.mesh
    b_ax, m_ax = plan.axes("batch"), plan.axes("tp")
    if m_ax is None:
        logits = jnp.einsum("bsd,dv->bsv", x, unemb).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        lab = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        nll = (lse - lab) * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    tp = plan.tp
    b_ax = plan._fit_dim(x.shape[0], "batch")
    V = unemb.shape[1]
    Vl = V // tp

    def body(xl, w_l, lab, msk):
        # xl: (B_loc, S or S/tp, d) — gather seq if sp-sharded
        if xl.shape[1] != lab.shape[1]:
            xl = lax.all_gather(xl, m_ax, axis=1, tiled=True)
        idx = lax.axis_index(m_ax)
        lo = idx * Vl
        S = xl.shape[1]
        cs = max(1, S // max(chunks, 1))
        nll_parts = []
        for c0 in range(0, S, cs):
            xc = xl[:, c0:c0 + cs]
            lc = lab[:, c0:c0 + cs]
            lg = jnp.einsum("bsd,dv->bsv", xc, w_l).astype(jnp.float32)
            # stop-grad on the max: exact (lse is shift-invariant) and pmax
            # has no transpose rule
            mx = lax.pmax(jax.lax.stop_gradient(jnp.max(lg, -1)), m_ax)
            ssum = lax.psum(jnp.sum(jnp.exp(lg - mx[..., None]), -1), m_ax)
            lse = jnp.log(ssum) + mx
            loc = lc - lo
            ok = (loc >= 0) & (loc < Vl)
            ll = jnp.take_along_axis(lg, jnp.clip(loc, 0, Vl - 1)[..., None],
                                     -1)[..., 0]
            ll = lax.psum(ll * ok.astype(jnp.float32), m_ax)
            nll_parts.append(lse - ll)
        nll = jnp.concatenate(nll_parts, axis=1) if len(nll_parts) > 1 \
            else nll_parts[0]
        loss = jnp.sum(nll * msk)
        cnt = jnp.sum(msk)
        return lax.pmean(loss, b_ax), lax.pmean(cnt, b_ax)

    x_seq_ax = m_ax if (plan.sequence_parallel
                        and x.shape[1] % tp == 0) else None
    loss, cnt = shard_map(
        body, mesh=mesh,
        in_specs=(P(b_ax, x_seq_ax, None), P(None, "model"),
                  P(b_ax, None), P(b_ax, None)),
        out_specs=(P(), P()), check_rep=False)(x, unemb, labels, mask)
    return loss / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _residual(x, delta, plan):
    return plan.constrain(x + delta, "batch", "sp", None)


def dense_block(x, p, cfg, plan, *, mode, cache=None, positions=None,
                pos_offset=0, mrope_positions=None, causal=True,
                window=0, moe=False):
    aux = {}
    xn = apply_norm(x, p["ln1"], cfg.norm)
    a, new_cache = attention(
        xn, p["attn"], cfg, plan, positions=positions, causal=causal,
        window=window, cache=cache, cache_pos=pos_offset,
        mrope_positions=mrope_positions,
        q_block=cfg.q_block, kv_block=cfg.kv_block)
    x = _residual(x, a, plan)
    xn = apply_norm(x, p["ln2"], cfg.norm)
    if moe:
        m, aux = moe_block(xn, p["moe"], cfg, plan)
    else:
        m = mlp(xn, p["mlp"], cfg.act, plan)
    x = _residual(x, m, plan)
    return x, new_cache, aux


def dense_defs(cfg, layers, moe=False, kind_cfg=None):
    d = {
        "ln1": norm_defs(cfg.d_model, cfg.norm, layers),
        "ln2": norm_defs(cfg.d_model, cfg.norm, layers),
        "attn": attn_defs(cfg, layers),
    }
    if moe:
        d["moe"] = moe_defs(cfg, layers)
    else:
        d["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff, layers)
    return d


def apply_block(kind, x, p, cfg, plan, *, mode, cache=None, positions=None,
                pos_offset=0, mrope_positions=None, enc_out=None):
    """Uniform block dispatch. Returns (x, new_cache, aux)."""
    window = cfg.window if cfg.attn_kind == "swa" else 0
    if kind in ("dense", "moe"):
        return dense_block(x, p, cfg, plan, mode=mode, cache=cache,
                           positions=positions, pos_offset=pos_offset,
                           mrope_positions=mrope_positions,
                           causal=True, window=window, moe=(kind == "moe"))
    if kind == "shared_attn":
        return dense_block(x, p, cfg, plan, mode=mode, cache=cache,
                           positions=positions, pos_offset=pos_offset,
                           causal=True, window=cfg.shared_attn_window)
    if kind == "enc":
        return dense_block(x, p, cfg, plan, mode=mode, cache=None,
                           positions=positions, causal=False, window=0)
    if kind == "dec":
        aux = {}
        xn = apply_norm(x, p["ln1"], cfg.norm)
        a, new_self = attention(xn, p["attn"], cfg, plan, positions=positions,
                                causal=True, window=0, cache=(
                                    cache["self"] if isinstance(cache, dict)
                                    else cache),
                                cache_pos=pos_offset,
                                q_block=cfg.q_block, kv_block=cfg.kv_block)
        x = _residual(x, a, plan)
        xn = apply_norm(x, p["ln_x"], cfg.norm)
        if isinstance(cache, dict):         # decode: cached cross-kv
            ckv = cache["cross"]
        else:
            ckv = cross_kv(enc_out, p["xattn"], cfg, plan)
        ca = cross_attention(xn, p["xattn"], ckv, cfg, plan)
        x = _residual(x, ca, plan)
        xn = apply_norm(x, p["ln2"], cfg.norm)
        x = _residual(x, mlp(xn, p["mlp"], cfg.act, plan), plan)
        new_cache = None
        if cache is not None:
            new_cache = {"self": new_self, "cross": ckv}
        return x, new_cache, aux
    if kind == "mamba2":
        x, st = mamba2_block(x, p, cfg, plan, state=cache, chunk=cfg.gla_chunk)
        return x, st, {}
    if kind == "mlstm":
        x, st = mlstm_block(x, p, cfg, plan, state=cache, chunk=cfg.gla_chunk)
        return x, st, {}
    if kind == "slstm":
        x, st = slstm_block(x, p, cfg, plan, state=cache)
        return x, st, {}
    raise ValueError(kind)


def block_defs(kind, cfg, layers):
    if kind == "dense":
        return dense_defs(cfg, layers)
    if kind == "moe":
        return dense_defs(cfg, layers, moe=True)
    if kind in ("shared_attn", "enc"):
        return dense_defs(cfg, layers)
    if kind == "dec":
        return {
            "ln1": norm_defs(cfg.d_model, cfg.norm, layers),
            "ln_x": norm_defs(cfg.d_model, cfg.norm, layers),
            "ln2": norm_defs(cfg.d_model, cfg.norm, layers),
            "attn": attn_defs(cfg, layers),
            "xattn": attn_defs(cfg, layers),
            "mlp": mlp_defs(cfg.d_model, cfg.d_ff, layers),
        }
    if kind == "mamba2":
        return mamba2_defs(cfg, layers)
    if kind == "mlstm":
        return mlstm_defs(cfg, layers)
    if kind == "slstm":
        return slstm_defs(cfg, layers)
    raise ValueError(kind)


def _cache_struct(kind, cfg, B, S_max, layers):
    """(shape, dtype, axes) templates for one stack's decode cache."""
    if kind in ("dense", "moe", "shared_attn", "enc", "dec"):
        from .attention import _cache_axes
        ca = ("layers",) + _cache_axes(cfg)
        def kvd(S):
            return {"k": ((layers, B, S, cfg.n_kv_heads, cfg.head_dim),
                          jnp.bfloat16, ca),
                    "v": ((layers, B, S, cfg.n_kv_heads, cfg.head_dim),
                          jnp.bfloat16, ca)}
        if kind == "dec":
            return {"self": kvd(S_max), "cross": kvd(cfg.enc_len)}
        return kvd(S_max)
    if kind == "mamba2":
        return mamba2_state_defs(cfg, B, layers)
    if kind == "mlstm":
        return mlstm_state_defs(cfg, B, layers)
    if kind == "slstm":
        return slstm_state_defs(cfg, B, layers)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------
class LM:
    def __init__(self, cfg):
        self.cfg = cfg

    # -- parameters -----------------------------------------------------------
    def param_defs(self):
        cfg = self.cfg
        d: Dict[str, Any] = {
            "embed": {"emb": ParamDef((cfg.vocab, cfg.d_model),
                                      ("tp", "fsdp"), init="embed",
                                      scale=0.02)},
            "final_norm": norm_defs(cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            d["embed"]["unemb"] = ParamDef((cfg.d_model, cfg.vocab),
                                           ("fsdp", "tp"))
        stacks = {}
        for kind, total in cfg.stack_sizes().items():
            if kind == "shared_attn":
                d["shared"] = block_defs("shared_attn", cfg, None)
            else:
                stacks[kind] = block_defs(kind, cfg, total)
        d["stacks"] = stacks
        if cfg.family == "encdec":
            d["enc_norm"] = norm_defs(cfg.d_model, cfg.norm)
        return d

    def init(self, key):
        return init_params(self.param_defs(), key)

    # -- segment runner ---------------------------------------------------------
    def _run_segments(self, params, x, *, mode, caches=None, positions=None,
                      pos_offset=0, mrope_positions=None, enc_out=None,
                      segments=None):
        """Run the segment list; returns (x, new_caches, aux_sum)."""
        cfg = self.cfg
        segments = segments if segments is not None else cfg.segments
        offsets = {k: 0 for k, _ in segments}
        shared_i = 0
        aux_tot = {}
        new_caches: Dict[str, Any] = {}

        def add_aux(a):
            for k, v in a.items():
                aux_tot[k] = aux_tot.get(k, 0.0) + v

        for kind, count in segments:
            if kind == "shared_attn":
                p = params["shared"]
                if mode == "train":
                    cache_l = None
                elif mode == "prefill":
                    cache_l = "init"
                else:
                    cache_l = jax.tree.map(lambda t: t[shared_i],
                                           caches["shared_attn"])
                if mode == "train":
                    blk = jax.checkpoint(
                        lambda xx, pp: apply_block(
                            "shared_attn", xx, pp, cfg, self._plan, mode=mode,
                            cache=None, positions=positions,
                            pos_offset=pos_offset)[0])
                    x = blk(x, p)
                    nc = None
                else:
                    x, nc, aux = apply_block(
                        "shared_attn", x, p, cfg, self._plan, mode=mode,
                        cache=cache_l, positions=positions,
                        pos_offset=pos_offset)
                if nc is not None:
                    new_caches.setdefault("shared_attn", []).append(nc)
                shared_i += 1
                continue

            start = offsets[kind]
            offsets[kind] = start + count
            stack = jax.tree.map(lambda t: t[start:start + count],
                                 params["stacks"][kind])
            plan = self._plan

            if mode == "train":
                def body(carry, pl, _kind=kind):
                    xx, aux_c = carry
                    def blk(xx, pl):
                        y, _, aux = apply_block(
                            _kind, xx, pl, cfg, plan, mode="train",
                            positions=positions,
                            mrope_positions=mrope_positions, enc_out=enc_out)
                        return y, aux
                    y, aux = jax.checkpoint(blk)(xx, pl)
                    aux_c = {k: aux_c.get(k, 0.0) + v for k, v in aux.items()} \
                        if aux else aux_c
                    return (y, aux_c), None
                aux0 = {"moe_lb": jnp.zeros((), jnp.float32),
                        "moe_z": jnp.zeros((), jnp.float32)} \
                    if kind == "moe" else {}
                (x, aux_c), _ = lax.scan(body, (x, aux0), stack)
                add_aux(aux_c)
            elif mode == "prefill":
                def body(xx, pl, _kind=kind):
                    y, nc, _ = apply_block(
                        _kind, xx, pl, cfg, plan, mode="prefill",
                        cache="init", positions=positions,
                        mrope_positions=mrope_positions, enc_out=enc_out)
                    return y, nc
                x, ncs = lax.scan(body, x, stack)
                new_caches.setdefault(kind, []).append(ncs)
            else:  # decode
                cache_stack = jax.tree.map(
                    lambda t: t[start:start + count], caches[kind])
                def body(xx, pc, _kind=kind):
                    pl, cl = pc
                    y, nc, _ = apply_block(
                        _kind, xx, pl, cfg, plan, mode="decode",
                        cache=cl, positions=positions, pos_offset=pos_offset,
                        mrope_positions=mrope_positions, enc_out=enc_out)
                    return y, nc
                x, ncs = lax.scan(body, x, (stack, cache_stack))
                new_caches.setdefault(kind, []).append(ncs)

        # concatenate per-kind cache pieces back into full stacks
        out_caches = {}
        for kind, pieces in new_caches.items():
            if kind == "shared_attn":
                out_caches[kind] = jax.tree.map(
                    lambda *ts: jnp.stack(ts, 0), *pieces) \
                    if len(pieces) > 1 else jax.tree.map(
                        lambda t: t[None], pieces[0])
            else:
                out_caches[kind] = jax.tree.map(
                    lambda *ts: jnp.concatenate(ts, 0), *pieces) \
                    if len(pieces) > 1 else pieces[0]
        return x, out_caches, aux_tot

    # -- entry points -------------------------------------------------------------
    def _embed_in(self, params, batch, plan):
        cfg = self.cfg
        if cfg.family == "vlm" and "embeds" in batch:
            x = batch["embeds"].astype(jnp.bfloat16)
            x = plan.constrain(x, "batch", "sp", None)
        else:
            x = vocab_parallel_embed(batch["tokens"], params["embed"]["emb"],
                                     plan)
            x = plan.constrain(x, "batch", "sp", None)
        return x

    def loss(self, params, batch, plan):
        cfg = self.cfg
        self._plan = plan
        if cfg.family == "encdec":
            return self._encdec_loss(params, batch, plan)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed_in(params, batch, plan)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        mrope = batch.get("mrope_positions") if cfg.mrope else None
        x, _, aux = self._run_segments(params, x, mode="train",
                                       positions=positions,
                                       mrope_positions=mrope)
        x = apply_norm(x, params["final_norm"], cfg.norm)
        labels = jnp.roll(tokens, -1, axis=1)
        mask = jnp.ones((B, S), jnp.float32).at[:, -1].set(0.0)
        unemb = params["embed"].get("unemb")
        if unemb is None:
            unemb = params["embed"]["emb"].T
        loss = vocab_parallel_ce(x, unemb, labels, mask, plan,
                                 chunks=cfg.loss_chunks)
        metrics = {"ce": loss}
        if aux:
            loss = loss + 0.01 * aux.get("moe_lb", 0.0) \
                + 0.001 * aux.get("moe_z", 0.0)
            metrics.update(aux)
        return loss, metrics

    def _encdec_loss(self, params, batch, plan):
        cfg = self.cfg
        frames = batch["frames"].astype(jnp.bfloat16)   # (B, S_enc, d) stub
        tokens = batch["tokens"]                        # (B, S_dec)
        B, S_dec = tokens.shape
        enc_x = plan.constrain(frames, "batch", "sp", None)
        pos_e = jnp.broadcast_to(jnp.arange(enc_x.shape[1])[None],
                                 (B, enc_x.shape[1]))
        enc_x, _, _ = self._run_segments(
            params, enc_x, mode="train", positions=pos_e,
            segments=[("enc", cfg.enc_layers)])
        enc_out = apply_norm(enc_x, params["enc_norm"], cfg.norm)
        x = vocab_parallel_embed(tokens, params["embed"]["emb"], plan)
        x = plan.constrain(x, "batch", "sp", None)
        pos_d = jnp.broadcast_to(jnp.arange(S_dec)[None], (B, S_dec))
        x, _, _ = self._run_segments(
            params, x, mode="train", positions=pos_d, enc_out=enc_out,
            segments=[("dec", cfg.dec_layers)])
        x = apply_norm(x, params["final_norm"], cfg.norm)
        labels = jnp.roll(tokens, -1, axis=1)
        mask = jnp.ones((B, S_dec), jnp.float32).at[:, -1].set(0.0)
        unemb = params["embed"].get("unemb")
        if unemb is None:
            unemb = params["embed"]["emb"].T
        loss = vocab_parallel_ce(x, unemb, labels, mask, plan,
                                 chunks=cfg.loss_chunks)
        return loss, {"ce": loss}

    # -- serving -----------------------------------------------------------------
    def prefill(self, params, batch, plan, cache_len: Optional[int] = None):
        """Process the prompt; returns (last-position logits (B,1,V) vocab-
        sharded, caches padded to ``cache_len``)."""
        cfg = self.cfg
        self._plan = plan
        if cache_len is not None:
            cfg.cache_len = (min(cache_len, cfg.window)
                             if cfg.attn_kind == "swa" else cache_len)
        if cfg.family == "encdec":
            return self._encdec_prefill(params, batch, plan)
        tokens = batch.get("tokens")
        if cfg.family == "vlm" and "embeds" in batch:
            x = batch["embeds"].astype(jnp.bfloat16)
            B, S = x.shape[:2]
            x = plan.constrain(x, "batch", "sp", None)
        else:
            B, S = tokens.shape
            x = self._embed_in(params, batch, plan)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        mrope = batch.get("mrope_positions") if cfg.mrope else None
        x, caches, _ = self._run_segments(params, x, mode="prefill",
                                          positions=positions,
                                          mrope_positions=mrope)
        x = apply_norm(x, params["final_norm"], cfg.norm)
        x_last = x[:, -1:]
        unemb = params["embed"].get("unemb")
        if unemb is None:
            unemb = params["embed"]["emb"].T
        logits = jnp.einsum("bsd,dv->bsv", x_last, unemb)
        logits = plan.constrain(logits, "batch", None, "tp")
        return logits, caches

    def _encdec_prefill(self, params, batch, plan):
        cfg = self.cfg
        frames = batch["frames"].astype(jnp.bfloat16)
        tokens = batch["tokens"]
        B, S_dec = tokens.shape
        enc_x = plan.constrain(frames, "batch", "sp", None)
        pos_e = jnp.broadcast_to(jnp.arange(enc_x.shape[1])[None],
                                 (B, enc_x.shape[1]))
        enc_x, _, _ = self._run_segments(
            params, enc_x, mode="prefill", positions=pos_e,
            segments=[("enc", cfg.enc_layers)])
        enc_out = apply_norm(enc_x, params["enc_norm"], cfg.norm)
        x = vocab_parallel_embed(tokens, params["embed"]["emb"], plan)
        x = plan.constrain(x, "batch", "sp", None)
        pos_d = jnp.broadcast_to(jnp.arange(S_dec)[None], (B, S_dec))
        x, caches, _ = self._run_segments(
            params, x, mode="prefill", positions=pos_d, enc_out=enc_out,
            segments=[("dec", cfg.dec_layers)])
        x = apply_norm(x, params["final_norm"], cfg.norm)
        unemb = params["embed"].get("unemb")
        if unemb is None:
            unemb = params["embed"]["emb"].T
        logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], unemb)
        return plan.constrain(logits, "batch", None, "tp"), caches

    def decode_step(self, params, caches, batch, plan):
        """One token for every sequence.  batch: {'token': (B,1), 'pos': ()}.
        Returns (logits (B,1,V) vocab-sharded, new caches)."""
        cfg = self.cfg
        self._plan = plan
        tok = batch["token"]
        B = tok.shape[0]
        pos = batch["pos"]
        if getattr(pos, "ndim", 0) == 1:      # per-sequence positions
            positions = pos[:, None].astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(pos[None, None],
                                         (B, 1)).astype(jnp.int32)
        if cfg.family == "vlm" and "embeds" in batch:
            x = batch["embeds"].astype(jnp.bfloat16)
        else:
            x = vocab_parallel_embed(tok, params["embed"]["emb"], plan)
        mrope = batch.get("mrope_positions") if cfg.mrope else None
        cache_pos = self._cache_write_pos(pos)
        segs = [("dec", cfg.dec_layers)] if cfg.family == "encdec" else None
        x, new_caches, _ = self._run_segments(
            params, x, mode="decode", caches=caches, positions=positions,
            pos_offset=cache_pos, mrope_positions=mrope, segments=segs)
        x = apply_norm(x, params["final_norm"], cfg.norm)
        unemb = params["embed"].get("unemb")
        if unemb is None:
            unemb = params["embed"]["emb"].T
        logits = jnp.einsum("bsd,dv->bsv", x, unemb)
        logits = plan.constrain(logits, "batch", None, "tp")
        return logits, new_caches

    def _cache_write_pos(self, pos):
        cfg = self.cfg
        if cfg.attn_kind == "swa" and cfg.cache_len == cfg.window:
            return jnp.mod(pos, cfg.window)
        return pos

    def cache_defs(self, B: int, S_max: int):
        """Tree of (shape, dtype, axes) for the decode caches."""
        cfg = self.cfg
        S_eff = min(S_max, cfg.window) if cfg.attn_kind == "swa" else S_max
        cfg.cache_len = S_eff
        out = {}
        for kind, total in cfg.stack_sizes().items():
            L = total
            if cfg.family == "encdec" and kind == "enc":
                continue
            out[kind] = _cache_struct(kind, cfg, B,
                                      S_eff if kind != "dec" else S_eff, L)
        return out

    # -- dry-run metadata -----------------------------------------------------------
    def loop_specs(self, mode: str):
        """[(kind, trips, scan_instances)] for cost correction."""
        cfg = self.cfg
        segs = cfg.segments
        if cfg.family == "encdec" and mode == "decode":
            segs = [("dec", cfg.dec_layers)]
        agg: Dict[str, list] = {}
        for kind, count in segs:
            if kind == "shared_attn":
                continue  # unrolled, counted raw
            agg.setdefault(kind, [0, 0])
            agg[kind][0] += count
            agg[kind][1] += 1
        return [(k, v[0], v[1]) for k, v in agg.items()]
