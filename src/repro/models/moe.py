"""MoE block — the farm skeleton with a *learned* load balancer.

Paper mapping (Sec. 8.3): the router is an ``ff_loadbalancer`` whose
``selectworker`` is a trained top-k policy; capacity-bounded dispatch is the
bounded SPSC lane (tasks beyond capacity are dropped instead of blocking —
an SPMD program cannot block); the all-to-all is the MPMC network moving
tasks from token shards (producers) to expert shards (consumers); the
combine is the collector weighting worker results; the aux load-balance loss
is the *on-demand scheduling* pressure pushing the emitter towards uniform
lane occupancy.

Two lowerings, chosen per architecture:
  mode='ep'  (E % tp == 0, e.g. kimi-k2 384e/16): experts sharded over the
             model axis; tokens stay sequence-sharded; all-to-all dispatch.
  mode='tp'  (E < tp, e.g. mixtral 8e): experts replicated, expert FFN
             tensor-parallel over the model axis; tokens gathered over the
             model axis for dispatch, outputs reduce-scattered back.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
try:
    from jax import shard_map as _shard_map_fn
    def shard_map(f, mesh, in_specs, out_specs, check_rep=False):
        return _shard_map_fn(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_rep)
except ImportError:  # older jax
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.device import expert_capacity
from .params import ParamDef


def moe_defs(cfg, layers: Optional[int] = None):
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    E, dff = cfg.n_experts, cfg.moe_d_ff
    ex_ax = "expert" if cfg.moe_mode == "ep" else None
    ff_ax = None if cfg.moe_mode == "ep" else "tp"
    d = {
        "router": ParamDef(lead + (cfg.d_model, E), la + ("fsdp", None),
                           dtype=jnp.float32),
        "wi": ParamDef(lead + (E, cfg.d_model, dff), la + (ex_ax, "fsdp", ff_ax)),
        "wg": ParamDef(lead + (E, cfg.d_model, dff), la + (ex_ax, "fsdp", ff_ax)),
        "wo": ParamDef(lead + (E, dff, cfg.d_model), la + (ex_ax, ff_ax, "fsdp")),
    }
    if cfg.n_shared_experts:
        sff = cfg.moe_d_ff * cfg.n_shared_experts
        d["shared"] = {
            "wi": ParamDef(lead + (cfg.d_model, sff), la + ("fsdp", "tp")),
            "wg": ParamDef(lead + (cfg.d_model, sff), la + ("fsdp", "tp")),
            "wo": ParamDef(lead + (sff, cfg.d_model), la + ("tp", "fsdp")),
        }
    return d


def _route(x2d, wr, top_k: int):
    """Router: returns (probs(T,E) f32, topk_w(T,K), topk_idx(T,K), aux)."""
    logits = x2d.astype(jnp.float32) @ wr.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = lax.top_k(probs, top_k)
    topk_w = topk_w / jnp.maximum(topk_w.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balance loss + router z-loss
    E = probs.shape[-1]
    me = probs.mean(0)                                     # (E,)
    ce_frac = jnp.zeros((E,), jnp.float32).at[topk_idx.reshape(-1)].add(1.0)
    ce_frac = ce_frac / jnp.maximum(topk_idx.size, 1)
    lb = E * jnp.sum(me * ce_frac)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return probs, topk_w, topk_idx, {"lb": lb, "z": z}


def _dispatch_local(x2d, topk_idx, topk_w, E: int, C: int):
    """Capacity-bounded scatter into (E, C, d) + bookkeeping for combine."""
    T, K = topk_idx.shape
    flat_e = topk_idx.reshape(T * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (TK, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot
    pos = pos.sum(-1) - 1                                      # (TK,)
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)            # overflow slot
    buf = jnp.zeros((E * C + 1, x2d.shape[1]), x2d.dtype)
    xrep = jnp.repeat(x2d, K, axis=0)                          # (TK, d)
    buf = buf.at[slot].add(xrep)
    return buf[:-1].reshape(E, C, -1), slot, keep


def _combine_local(ybuf, slot, keep, topk_w, T: int, K: int):
    yflat = ybuf.reshape(-1, ybuf.shape[-1])
    yflat = jnp.concatenate([yflat, jnp.zeros_like(yflat[:1])], axis=0)
    got = yflat[slot] * keep[:, None]                          # (TK, d)
    got = got.reshape(T, K, -1)
    return jnp.einsum("tkd,tk->td", got.astype(jnp.float32),
                      topk_w.astype(jnp.float32))


def _glu(h, wi, wg, wo):
    a = jnp.einsum("ecd,edf->ecf", h, wi)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, wg))
    return jnp.einsum("ecf,efd->ecd", a * g, wo)


def moe_block(x, p, cfg, plan):
    """x: (B, S, d) sharded (batch, sp, -). Returns (out, aux_losses)."""
    mesh = plan.mesh
    B, S, _d = x.shape
    batch_ax = plan._fit_dim(B, "batch")
    model_ax = plan.axes("tp")
    E, K = cfg.n_experts, cfg.top_k
    tp = plan.tp
    seq_sharded = (S % tp == 0 and S > 1 and plan.sequence_parallel
                   and model_ax is not None)

    xspec = P(batch_ax, model_ax if seq_sharded else None, None)
    rspec = P(None, None)

    def _pmean(v):
        for ax in (model_ax, batch_ax):
            if ax is not None:
                v = lax.pmean(v, ax)
        return v
    if cfg.moe_mode == "ep":
        wspec = P("model", None, None)
    else:
        wspec = (P(None, None, "model"), P(None, None, "model"),
                 P(None, "model", None))
        wspec_i, wspec_g, wspec_o = wspec

    def ep_body(xl, wr, wi, wg, wo):
        Bl, Sl, d = xl.shape
        x2 = xl.reshape(Bl * Sl, d)
        probs, tw, ti, aux = _route(x2, wr, K)
        C = expert_capacity(Bl * Sl, E, K, cfg.capacity_factor)
        buf, slot, keep = _dispatch_local(x2, ti, tw, E, C)
        # MPMC: token shards -> expert shards
        buf = lax.all_to_all(buf, model_ax, split_axis=0, concat_axis=1,
                             tiled=True)                  # (E/tp, C*tp, d)
        y = _glu(buf, wi, wg, wo)
        y = lax.all_to_all(y, model_ax, split_axis=1, concat_axis=0,
                           tiled=True)                    # (E, C, d)
        out = _combine_local(y, slot, keep, tw, Bl * Sl, K)
        out = out.reshape(Bl, Sl, d).astype(xl.dtype)
        aux = {k: _pmean(v) for k, v in aux.items()}
        return out, aux["lb"], aux["z"]

    def tp_body(xl, wr, wi, wg, wo):
        # tokens gathered over model axis; expert FFN is ff-sharded
        Bl, Sl, d = xl.shape
        xg = lax.all_gather(xl, model_ax, axis=1, tiled=True) \
            if seq_sharded else xl                              # (B, S, d)
        Sg = xg.shape[1]
        x2 = xg.reshape(Bl * Sg, d)
        probs, tw, ti, aux = _route(x2, wr, K)
        C = expert_capacity(Bl * Sg, E, K, cfg.capacity_factor)
        buf, slot, keep = _dispatch_local(x2, ti, tw, E, C)
        y = _glu(buf, wi, wg, wo)                               # partial (ff shard)
        out = _combine_local(y, slot, keep, tw, Bl * Sg, K)     # partial sums
        out = out.reshape(Bl, Sg, d).astype(xl.dtype)
        # Compose: reduce-scatter partials back to seq shards (or psum)
        if seq_sharded:
            out = lax.psum_scatter(out, model_ax, scatter_dimension=1,
                                   tiled=True)
        elif model_ax is not None:
            out = lax.psum(out, model_ax)
        aux = {k: _pmean(v) for k, v in aux.items()}
        return out, aux["lb"], aux["z"]

    body = ep_body if cfg.moe_mode == "ep" else tp_body
    if cfg.moe_mode == "ep":
        in_specs = (xspec, rspec, wspec, wspec, wspec)
        wax = ("expert", "fsdp", None)
        oax = ("expert", None, "fsdp")
    else:
        in_specs = (xspec, rspec, wspec_i, wspec_g, wspec_o)
        wax = (None, "fsdp", "tp")
        oax = (None, "tp", "fsdp")
    # gather the bf16 expert weights over the fsdp axis *before* the
    # shard_map boundary (otherwise GSPMD hoists an f32 convert first and
    # all-gathers 2x the bytes)
    wi = plan.gather_fsdp(p["wi"], wax)
    wg = plan.gather_fsdp(p["wg"], wax)
    wo = plan.gather_fsdp(p["wo"], oax)
    router = plan.gather_fsdp(p["router"], ("fsdp", None))
    fn = shard_map(body, mesh=mesh,
                   in_specs=in_specs,
                   out_specs=(xspec, P(), P()), check_rep=False)
    out, lb, z = fn(x, router, wi, wg, wo)

    if cfg.n_shared_experts:
        sp_ = p["shared"]
        a = jnp.einsum("bsd,df->bsf", x, sp_["wi"])
        g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sp_["wg"]))
        out = out + jnp.einsum("bsf,fd->bsd", a * g, sp_["wo"],
                                preferred_element_type=jnp.bfloat16)

    aux = {"moe_lb": lb, "moe_z": z}
    return out, aux
