"""State-space / linear-recurrence blocks: Mamba2 (SSD) and the shared
chunked gated-linear-attention primitive.

The recurrence  h_t = a_t * h_{t-1} + k_t (x_t)^T ,  y_t = q_t . h_t
is computed chunkwise: dense intra-chunk matmuls (MXU work) + an associative
scan over per-chunk state transforms (log-depth, statically unrolled — no
``while`` loop, keeping the dry-run cost analysis exact).  This is the
feedback skeleton (wrap_around) pushed down to the tensor level; the Pallas
version is kernels/ssd_scan.py.

Decode uses the plain single-step recurrence on a carried state.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import rms_norm
from .params import ParamDef


def chunked_gla(q, k, v, log_a, chunk: int = 256, plan=None):
    """y_t = sum_{s<=t} exp(sum_{u=s+1..t} log_a_u) (q_t . k_s) v_s.

    q, k: (B, S, H, N); v: (B, S, H, P); log_a: (B, S, H) (<= 0).
    Returns y: (B, S, H, P) and final state (B, H, N, P).

    H-major intermediate layout + explicit sharding constraints keep every
    (Q, Q) score tile head-sharded under GSPMD (no involuntary
    rematerialization of (B,NC,Q,Q,H) tensors).
    """
    B, S, H, N = q.shape
    P = v.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    NC = S // Q

    def con(t, *axes):
        return plan.constrain(t, *axes) if plan is not None else t

    # (B, NC, H, Q, feat)
    qc = jnp.moveaxis(q.reshape(B, NC, Q, H, N), 3, 2).astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(B, NC, Q, H, N), 3, 2).astype(jnp.float32)
    vc = jnp.moveaxis(v.reshape(B, NC, Q, H, P), 3, 2).astype(jnp.float32)
    la = jnp.moveaxis(log_a.reshape(B, NC, Q, H), 3, 2).astype(jnp.float32)
    qc = con(qc, "batch", None, "tp", None, None)
    kc = con(kc, "batch", None, "tp", None, None)
    vc = con(vc, "batch", None, "tp", None, None)
    la = con(la, "batch", None, "tp", None)

    cum = jnp.cumsum(la, axis=3)                      # (B,NC,H,Q) inclusive
    tot = cum[:, :, :, -1]                            # (B,NC,H)

    # intra-chunk: scores[t,s] = q_t.k_s * exp(cum_t - cum_s) for s<=t
    scores = jnp.einsum("bchtn,bchsn->bchts", qc, kc)
    decay = jnp.exp(jnp.clip(cum[:, :, :, :, None] - cum[:, :, :, None, :],
                             -60.0, 0.0))             # (B,NC,H,t,s)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    w = scores * decay * mask[None, None, None]
    w = con(w, "batch", None, "tp", None, None)
    y_intra = jnp.einsum("bchts,bchsp->bchtp", w, vc)

    # per-chunk state increment: I_c = sum_s exp(tot - cum_s) k_s v_s^T
    dk = jnp.exp(jnp.clip(tot[..., None] - cum, -60.0, 0.0))     # (B,NC,H,Q)
    inc = jnp.einsum("bchsn,bchs,bchsp->bchnp", kc, dk, vc)      # (B,NC,H,N,P)
    a_tot = jnp.exp(jnp.clip(tot, -60.0, 0.0))                   # (B,NC,H)

    # associative scan of transforms S -> a S + I  (composition law)
    def combine(x, y):
        a1, i1 = x
        a2, i2 = y
        return a1 * a2, a2[..., None, None] * i1 + i2

    a_sc, i_sc = jax.lax.associative_scan(combine, (a_tot, inc), axis=1)
    # state BEFORE chunk c: shift right
    zero = jnp.zeros_like(inc[:, :1])
    s_before = jnp.concatenate([zero, i_sc[:, :-1]], axis=1)     # (B,NC,H,N,P)
    s_final = i_sc[:, -1]                                        # (B,H,N,P)

    # inter-chunk contribution: y_t += exp(cum_t) q_t . S_before
    y_inter = jnp.einsum("bchtn,bcht,bchnp->bchtp", qc,
                         jnp.exp(jnp.clip(cum, -60.0, 0.0)), s_before)
    y = jnp.moveaxis(y_intra + y_inter, 2, 3).reshape(B, S, H, P)
    return y, s_final


def gla_step(state, q, k, v, log_a):
    """Single decode step: state (B,H,N,P); q/k (B,1,H,N); v (B,1,H,P)."""
    a = jnp.exp(log_a.astype(jnp.float32))[:, 0, :, None, None]  # (B,H,1,1)
    kv = jnp.einsum("bhn,bhp->bhnp", k[:, 0].astype(jnp.float32),
                    v[:, 0].astype(jnp.float32))
    state = state * a + kv
    y = jnp.einsum("bhn,bhnp->bhp", q[:, 0].astype(jnp.float32), state)
    return state, y[:, None]


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------
def mamba2_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    return d_inner, n_heads


def mamba2_defs(cfg, layers: Optional[int] = None):
    d_inner, H = mamba2_dims(cfg)
    N = cfg.ssm_state
    G = cfg.ssm_groups
    K = cfg.ssm_conv
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "norm": {"w": ParamDef(lead + (cfg.d_model,), la + (None,), init="zeros")},
        "wz": ParamDef(lead + (cfg.d_model, d_inner), la + ("fsdp", "tp")),
        "wx": ParamDef(lead + (cfg.d_model, d_inner), la + ("fsdp", "tp")),
        "wB": ParamDef(lead + (cfg.d_model, G, N), la + ("fsdp", None, None)),
        "wC": ParamDef(lead + (cfg.d_model, G, N), la + ("fsdp", None, None)),
        "wdt": ParamDef(lead + (cfg.d_model, H), la + ("fsdp", "tp")),
        "dt_bias": ParamDef(lead + (H,), la + ("tp",), init="zeros"),
        "A_log": ParamDef(lead + (H,), la + ("tp",), init="zeros"),
        "D": ParamDef(lead + (H,), la + ("tp",), init="zeros"),
        "conv": ParamDef(lead + (K, d_inner), la + (None, "tp")),
        "wo": ParamDef(lead + (d_inner, cfg.d_model), la + ("tp", "fsdp")),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv along seq: x (B,S,C), w (K,C).
    With ``state`` (B,K-1,C) this is the decode step (S==1)."""
    K = w.shape[0]
    if state is not None:
        buf = jnp.concatenate([state, x], axis=1)          # (B,K,C)
        y = jnp.einsum("bkc,kc->bc", buf.astype(jnp.float32),
                       w.astype(jnp.float32))[:, None]
        return y.astype(x.dtype), buf[:, 1:]
    pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # (B,S+K-1,C)
    y = sum(xp[:, i:i + x.shape[1]].astype(jnp.float32)
            * w[i].astype(jnp.float32) for i in range(K))
    return y.astype(x.dtype), xp[:, -(K - 1):] if K > 1 else None


def mamba2_block(x, p, cfg, plan, *, state=None, chunk: int = 256):
    """state: None (train) | 'init' (prefill: return final state) |
    dict {ssm, conv} (decode step)."""
    B, S, _ = x.shape
    d_inner, H = mamba2_dims(cfg)
    N, G, P = cfg.ssm_state, cfg.ssm_groups, cfg.ssm_headdim
    decode = isinstance(state, dict)

    xn = rms_norm(x, p["norm"]["w"])
    if S > 1:
        xn = plan.constrain(xn, "batch", None, None)   # SP gather (bf16)
    wz = plan.gather_fsdp(p["wz"], ("fsdp", "tp"))
    wx = plan.gather_fsdp(p["wx"], ("fsdp", "tp"))
    z = jnp.einsum("bsd,de->bse", xn, wz)
    xi = jnp.einsum("bsd,de->bse", xn, wx)
    Bm = jnp.einsum("bsd,dgn->bsgn", xn, p["wB"])
    Cm = jnp.einsum("bsd,dgn->bsgn", xn, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", xn, p["wdt"]) + p["dt_bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))            # (B,S,H)

    xi = plan.constrain(xi, "batch", None, "tp")
    conv_state = state.get("conv") if decode else None
    xi, new_conv = _causal_conv(xi, p["conv"], conv_state)
    xi = jax.nn.silu(xi)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,) negative
    log_a = dt * A[None, None, :]                           # (B,S,H)
    xh = xi.reshape(B, S, H, P)
    dtx = xh.astype(jnp.float32) * dt[..., None]
    # expand groups to heads
    rep = H // G
    k = jnp.repeat(Bm, rep, axis=2)                         # (B,S,H,N)
    q = jnp.repeat(Cm, rep, axis=2)

    if decode:
        new_ssm, y = gla_step(state["ssm"], q, k, dtx, log_a)
        new_state = {"ssm": new_ssm, "conv": new_conv}
    else:
        y, s_final = chunked_gla(q, k, dtx, log_a, chunk=chunk, plan=plan)
        new_state = None
        if state == "init":
            new_state = {"ssm": s_final,
                         "conv": new_conv if new_conv is not None else
                         jnp.zeros((B, cfg.ssm_conv - 1, d_inner), x.dtype)}

    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    wo = plan.gather_fsdp(p["wo"], ("tp", "fsdp"))
    out = jnp.einsum("bse,ed->bsd", y, wo,
                     preferred_element_type=jnp.bfloat16)
    out = plan.constrain(out, "batch", "sp", None)
    return x + out, new_state


def mamba2_state_defs(cfg, B: int, layers: int):
    """ShapeDtype templates for the decode state (used by input_specs)."""
    d_inner, H = mamba2_dims(cfg)
    return {
        "ssm": ((layers, B, H, cfg.ssm_state, cfg.ssm_headdim), jnp.float32,
                ("layers", "batch", "tp", None, None)),
        "conv": ((layers, B, cfg.ssm_conv - 1, d_inner), jnp.bfloat16,
                 ("layers", "batch", None, "tp")),
    }
