"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Both reuse the chunked gated-linear-attention primitive of models/ssm.py:
 - mLSTM:  C_t = f_t C_{t-1} + i_t v_t k_t^T ;  h = o( q.C / max(|q.n|,1) )
   (the normalizer n_t uses the same recurrence with v == 1).
 - sLSTM:  per-unit scalar recurrence  c_t = f_t c_{t-1} + i_t z_t,
   n_t = f_t n_{t-1} + i_t, h = o * c/n — computed with a log-depth
   associative scan.  NOTE (hardware adaptation, see DESIGN.md): the
   hidden-to-hidden recurrence matrix R of the paper's sLSTM serializes the
   whole sequence and has no parallel form; we drop R (gates depend on the
   input only), which is the standard parallelizable variant.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import rms_norm
from .params import ParamDef
from .ssm import chunked_gla, gla_step, _causal_conv


def mlstm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.n_heads
    P = d_inner // H
    return d_inner, H, P


def mlstm_defs(cfg, layers: Optional[int] = None):
    d_inner, H, P = mlstm_dims(cfg)
    K = cfg.ssm_conv
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "norm": {"w": ParamDef(lead + (cfg.d_model,), la + (None,), init="zeros")},
        "wup": ParamDef(lead + (cfg.d_model, d_inner), la + ("fsdp", "tp")),
        "wgate": ParamDef(lead + (cfg.d_model, d_inner), la + ("fsdp", "tp")),
        "conv": ParamDef(lead + (K, d_inner), la + (None, "tp")),
        "wq": ParamDef(lead + (d_inner, d_inner), la + ("fsdp", "tp")),
        "wk": ParamDef(lead + (d_inner, d_inner), la + ("fsdp", "tp")),
        "wv": ParamDef(lead + (d_inner, d_inner), la + ("fsdp", "tp")),
        "wi": ParamDef(lead + (d_inner, H), la + ("fsdp", "tp")),
        "wf": ParamDef(lead + (d_inner, H), la + ("fsdp", "tp")),
        "wo": ParamDef(lead + (d_inner, cfg.d_model), la + ("tp", "fsdp")),
    }


def mlstm_block(x, p, cfg, plan, *, state=None, chunk: int = 256):
    B, S, _ = x.shape
    d_inner, H, P = mlstm_dims(cfg)
    decode = isinstance(state, dict)

    xn = rms_norm(x, p["norm"]["w"])
    if S > 1:
        xn = plan.constrain(xn, "batch", None, None)
    wup = plan.gather_fsdp(p["wup"], ("fsdp", "tp"))
    wgate = plan.gather_fsdp(p["wgate"], ("fsdp", "tp"))
    up = jnp.einsum("bsd,de->bse", xn, wup)
    gate = jax.nn.silu(jnp.einsum("bsd,de->bse", xn, wgate))
    up = plan.constrain(up, "batch", None, "tp")

    conv_state = state.get("conv") if decode else None
    c, new_conv = _causal_conv(up, p["conv"], conv_state)
    c = jax.nn.silu(c)

    q = jnp.einsum("bse,ef->bsf", c, p["wq"]).reshape(B, S, H, P)
    k = jnp.einsum("bse,ef->bsf", c, p["wk"]).reshape(B, S, H, P) / (P ** 0.5)
    v = jnp.einsum("bse,ef->bsf", up, p["wv"]).reshape(B, S, H, P)
    i_gate = jnp.einsum("bse,eh->bsh", c, p["wi"]).astype(jnp.float32)
    f_gate = jnp.einsum("bse,eh->bsh", c, p["wf"]).astype(jnp.float32)
    # log decay: log sigmoid(f); input scale: exp-normalized i (stabilized
    # variant: fold exp(i) into v and the normalizer symmetrically)
    log_a = jax.nn.log_sigmoid(f_gate)
    i_scl = jnp.exp(jnp.clip(i_gate, -20.0, 2.0))[..., None]
    vi = v.astype(jnp.float32) * i_scl
    ones = jnp.ones(v.shape[:-1] + (1,), jnp.float32) * i_scl

    if decode:
        new_C, num = gla_step(state["C"], q, k, vi, log_a)
        new_n, den = gla_step(state["n"], q, k, ones, log_a)
        new_state = {"C": new_C, "n": new_n, "conv": new_conv}
    else:
        num, C_fin = chunked_gla(q, k, vi, log_a, chunk=min(chunk, S), plan=plan)
        den, n_fin = chunked_gla(q, k, ones, log_a, chunk=min(chunk, S), plan=plan)
        new_state = None
        if state == "init":
            new_state = {"C": C_fin, "n": n_fin, "conv": new_conv}

    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h = h.reshape(B, S, d_inner).astype(x.dtype) * gate
    wo = plan.gather_fsdp(p["wo"], ("tp", "fsdp"))
    out = jnp.einsum("bse,ed->bsd", h, wo,
                     preferred_element_type=jnp.bfloat16)
    out = plan.constrain(out, "batch", "sp", None)
    return x + out, new_state


def mlstm_state_defs(cfg, B: int, layers: int):
    d_inner, H, P = mlstm_dims(cfg)
    return {
        "C": ((layers, B, H, P, P), jnp.float32,
              ("layers", "batch", "tp", None, None)),
        "n": ((layers, B, H, P, 1), jnp.float32,
              ("layers", "batch", "tp", None, None)),
        "conv": ((layers, B, cfg.ssm_conv - 1, d_inner), jnp.bfloat16,
                 ("layers", "batch", None, "tp")),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_defs(cfg, layers: Optional[int] = None):
    d = cfg.d_model
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "norm": {"w": ParamDef(lead + (d,), la + (None,), init="zeros")},
        "wz": ParamDef(lead + (d, d), la + ("fsdp", "tp")),
        "wi": ParamDef(lead + (d, d), la + ("fsdp", "tp")),
        "wf": ParamDef(lead + (d, d), la + ("fsdp", "tp")),
        "wo_gate": ParamDef(lead + (d, d), la + ("fsdp", "tp")),
        "wo": ParamDef(lead + (d, d), la + ("tp", "fsdp")),
    }


def slstm_block(x, p, cfg, plan, *, state=None):
    B, S, d = x.shape
    decode = isinstance(state, dict)
    xn = rms_norm(x, p["norm"]["w"])
    z = jnp.tanh(jnp.einsum("bsd,de->bse", xn, p["wz"]).astype(jnp.float32))
    i = jnp.exp(jnp.clip(jnp.einsum("bsd,de->bse", xn, p["wi"])
                         .astype(jnp.float32), -20.0, 2.0))
    f = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xn, p["wf"])
                       .astype(jnp.float32))
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xn, p["wo_gate"])
                       .astype(jnp.float32))

    if decode:
        c = f[:, 0] * state["c"] + i[:, 0] * z[:, 0]
        n = f[:, 0] * state["n"] + i[:, 0]
        h = (o[:, 0] * c / jnp.maximum(n, 1e-6))[:, None]
        new_state = {"c": c, "n": n}
    else:
        def combine(a, b):
            (f1, c1), (f2, c2) = a, b
            return f1 * f2, f2 * c1 + c2
        _, c = jax.lax.associative_scan(combine, (f, i * z), axis=1)
        _, n = jax.lax.associative_scan(combine, (f, i), axis=1)
        h = o * c / jnp.maximum(n, 1e-6)
        new_state = {"c": c[:, -1], "n": n[:, -1]} if state == "init" else None

    out = jnp.einsum("bse,ed->bsd", h.astype(x.dtype), p["wo"],
                     preferred_element_type=jnp.bfloat16)
    out = plan.constrain(out, "batch", "sp", None)
    return x + out, new_state


def slstm_state_defs(cfg, B: int, layers: int):
    d = cfg.d_model
    return {
        "c": ((layers, B, d), jnp.float32, ("layers", "batch", "tp")),
        "n": ((layers, B, d), jnp.float32, ("layers", "batch", "tp")),
    }
