"""GQA attention with two device-skeleton lowerings.

``parallel='heads'``  map skeleton over heads (Megatron TP): q heads sharded
    over the model axis; KV heads sharded when n_kv % tp == 0, otherwise KV
    projections replicate (they are small) and the KV *cache* shards on
    head_dim.  GQA math is grouped (q reshaped to (kv, group)) — KV is never
    materialized repeated.

``parallel='cp'``     map skeleton over *sequence* (context parallelism) for
    archs whose head count does not divide the TP degree (yi-34b 56H,
    llama3.2 24H, qwen2-vl 12H): queries stay sequence-sharded, KV is
    gathered for the streaming loop.  Decode shards the KV cache on head_dim
    (score/value contractions become partial-sum collectives — the
    farm-with-collector skeleton, flash-decoding).

Both paths use a blocked streaming softmax (never materializing (S, S)),
mirroring the Pallas kernel (kernels/flash_attention.py) the TPU build uses;
this XLA path is the dry-run / CPU fallback (``config.use_pallas=False``).
The q-block x kv-block loops are *unrolled*, so blocks above the causal
diagonal / outside the SWA window are skipped at trace time — both the
FLOPs and the HLO cost analysis reflect kernel-like work.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import apply_mrope, apply_rope
from .params import ParamDef

NEG_INF = -2.0e38


def attn_defs(cfg, layers: Optional[int] = None):
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    hd = cfg.head_dim
    head_ax = "tp" if cfg.attn_parallel == "heads" else None
    kv_ax = head_ax if cfg.n_kv_heads % 16 == 0 else None
    n_q = cfg.padded_heads or cfg.n_heads   # TP-friendly head padding
    return {
        "wq": ParamDef(lead + (cfg.d_model, n_q, hd),
                       la + ("fsdp", head_ax, None)),
        "wk": ParamDef(lead + (cfg.d_model, cfg.n_kv_heads, hd),
                       la + ("fsdp", kv_ax, None)),
        "wv": ParamDef(lead + (cfg.d_model, cfg.n_kv_heads, hd),
                       la + ("fsdp", kv_ax, None)),
        "wo": ParamDef(lead + (n_q, hd, cfg.d_model),
                       la + (head_ax, None, "fsdp")),
    }


def _group(q, n_kv: int):
    """(B, S, H, D) -> (B, S, kv, group, D)."""
    B, S, H, D = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, D)


def _block_mask(qlo, qhi, klo, khi, causal, window):
    qp = jnp.arange(qlo, qhi)[:, None]
    kp = jnp.arange(klo, khi)[None, :]
    m = jnp.zeros((qhi - qlo, khi - klo), jnp.float32)
    if causal:
        m = jnp.where(kp <= qp, m, NEG_INF)
    if window and window > 0:
        m = jnp.where(kp > qp - window, m, NEG_INF)
    return m


def sdpa_streaming(q, k, v, *, causal: bool, window: int = 0,
                   q_block: Optional[int] = 2048, kv_block: int = 2048,
                   q_offset: int = 0):
    """Blocked streaming-softmax grouped attention.

    q: (B, Sq, kv, g, D); k/v: (B, Sk, kv, D).
    ``q_block=None`` disables query blocking (context-parallel mode)."""
    B, Sq, KV, G, D = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32) * scale
    qb = Sq if q_block is None else min(q_block, Sq)
    skip = q_block is not None
    outs = []
    for qlo in range(0, Sq, qb):
        qhi = min(qlo + qb, Sq)
        gqlo, gqhi = qlo + q_offset, qhi + q_offset
        klo, khi = 0, Sk
        if skip:
            if causal:
                khi = min(Sk, gqhi)
            if window and window > 0:
                klo = max(0, gqlo - window + 1)
                klo = (klo // kv_block) * kv_block
        qc = qf[:, qlo:qhi]
        acc = jnp.zeros((B, qhi - qlo, KV, G, D), jnp.float32)
        m = jnp.full((B, qhi - qlo, KV, G), NEG_INF, jnp.float32)
        l = jnp.zeros((B, qhi - qlo, KV, G), jnp.float32)
        for blo in range(klo, khi, kv_block):
            bhi = min(blo + kv_block, khi)
            kb = k[:, blo:bhi].astype(jnp.float32)
            vb = v[:, blo:bhi].astype(jnp.float32)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qc, kb)
            mask = _block_mask(gqlo, gqhi, blo, bhi, causal, window)
            s = s + mask[None, :, None, None, :]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bqhgk,bkhd->bqhgd",
                                                     p, vb)
            m = m_new
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
    return out.reshape(B, Sq, KV * G, D).astype(q.dtype)


def _cache_axes(cfg):
    """Logical axes of the KV cache (B, S_max, n_kv, hd): shard kv heads
    when they divide the TP degree, else shard head_dim."""
    if cfg.attn_parallel == "heads" and cfg.n_kv_heads % 16 == 0:
        return ("batch", None, "tp", None)
    return ("batch", None, None, "tp")


def attention(x, p, cfg, plan, *, positions, causal=True, window=0,
              cache=None, cache_pos=None, mrope_positions=None,
              q_block: int = 2048, kv_block: int = 2048):
    """Attention block: projections + blocked grouped SDPA + output proj.

    train:    cache=None          -> (out, None)
    prefill:  cache='init'        -> (out, {k, v} padded to cfg.cache_len)
    decode:   cache={k, v} dict   -> (out, updated cache); x is (B, 1, d),
              ``cache_pos`` the write slot (scalar or (B,)), ``positions``
              (B, 1) global positions.
    """
    B, S, _ = x.shape
    n_kv = cfg.n_kv_heads
    decode = isinstance(cache, dict)

    if cfg.attn_parallel == "heads" and not decode:
        # SP boundary: gather bf16 activations over seq shards here
        x = plan.constrain(x, "batch", None, None)
    head_ax = "tp" if cfg.attn_parallel == "heads" else None
    kv_ax = head_ax if cfg.n_kv_heads % 16 == 0 else None
    wq = plan.gather_fsdp(p["wq"], ("fsdp", head_ax, None))
    wk = plan.gather_fsdp(p["wk"], ("fsdp", kv_ax, None))
    wv = plan.gather_fsdp(p["wv"], ("fsdp", kv_ax, None))
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)

    if mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta)
    elif cfg.use_rope:
        pos2d = positions if positions.ndim == 2 else \
            jnp.broadcast_to(positions[None, :], (B, S))
        q = apply_rope(q, pos2d, cfg.rope_theta)
        k = apply_rope(k, pos2d, cfg.rope_theta)

    if cfg.attn_parallel == "heads":
        q = plan.constrain(q, "batch", None, "tp", None)
        k = plan.constrain(k, "batch", None, "tp", None)
        v = plan.constrain(v, "batch", None, "tp", None)
    else:
        q = plan.constrain(q, "batch", "cp", None, None)

    if decode:
        ca = _cache_axes(cfg)
        if hasattr(cache_pos, "ndim") and getattr(cache_pos, "ndim", 0) == 1:
            def upd(c, u, pp):
                return jax.lax.dynamic_update_slice(c, u, (pp, 0, 0))
            ck = jax.vmap(upd)(cache["k"], k.astype(cache["k"].dtype),
                               cache_pos)
            cv = jax.vmap(upd)(cache["v"], v.astype(cache["v"].dtype),
                               cache_pos)
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        ck = plan.constrain(ck, *ca)
        cv = plan.constrain(cv, *ca)
        new_cache = {"k": ck, "v": cv}
        Sk = ck.shape[1]
        k_pos = jnp.arange(Sk)
        scale = 1.0 / math.sqrt(cfg.head_dim)
        qg = _group(q, n_kv).astype(jnp.float32) * scale   # (B,1,kv,g,D)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, ck.astype(jnp.float32))
        ring = window > 0 and Sk == window
        valid = k_pos[None, None, :] <= positions[:, :, None]
        if window and window > 0 and not ring:
            valid &= k_pos[None, None, :] > (positions[:, :, None] - window)
        if ring:
            # warm ring buffer: every slot holds an in-window entry; the
            # k_pos<=pos test is only exact during warmup (pos < window)
            valid = valid | (positions[:, :, None] >= window)
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        w = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        out = jnp.einsum("bqhgk,bkhd->bqhgd", w, cv.astype(jnp.float32))
        out = out.reshape(B, S, q.shape[2], cfg.head_dim).astype(x.dtype)
    else:
        if cfg.attn_parallel == "cp":
            k = plan.constrain(k, "batch", None, None, None)
            v = plan.constrain(v, "batch", None, None, None)
            qb = None
        else:
            qb = q_block
        out = sdpa_streaming(_group(q, n_kv), k, v, causal=causal,
                             window=window, q_block=qb, kv_block=kv_block)
        new_cache = None
        if cache == "init":
            ca = _cache_axes(cfg)
            ck, cv = k, v
            tgt = getattr(cfg, "cache_len", None) or S
            if cfg.attn_kind == "swa" and tgt == window and S > window:
                shift = S % window
                ck = jnp.roll(ck[:, -window:], shift, axis=1)
                cv = jnp.roll(cv[:, -window:], shift, axis=1)
            elif tgt > S:
                pad = [(0, 0), (0, tgt - S), (0, 0), (0, 0)]
                ck = jnp.pad(ck, pad)
                cv = jnp.pad(cv, pad)
            new_cache = {"k": plan.constrain(ck, *ca),
                         "v": plan.constrain(cv, *ca)}

    head_ax2 = "tp" if cfg.attn_parallel == "heads" else None
    wo = plan.gather_fsdp(p["wo"], (head_ax2, None, "fsdp"))
    o = jnp.einsum("bshk,hkd->bsd", out, wo,
                   preferred_element_type=jnp.bfloat16)
    if not decode:
        o = plan.constrain(o, "batch", "sp", None)
    return o, new_cache


def cross_attention(x, p, enc_kv, cfg, plan, kv_block: int = 2048):
    """Encoder-decoder cross attention (whisper): q from decoder x, kv
    precomputed from the encoder output (cached at prefill)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.attn_parallel == "heads":
        q = plan.constrain(q, "batch", None, "tp", None)
    out = sdpa_streaming(_group(q, cfg.n_kv_heads), enc_kv["k"], enc_kv["v"],
                         causal=False, window=0, q_block=2048,
                         kv_block=kv_block)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_kv(enc_out, p, cfg, plan):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if cfg.attn_parallel == "heads":
        k = plan.constrain(k, "batch", None, "tp", None)
        v = plan.constrain(v, "batch", None, "tp", None)
    return {"k": k, "v": v}
