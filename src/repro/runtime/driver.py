"""Fault-tolerant training driver.

The supervision loop a 1000-node deployment needs, runnable (and tested) on
one host:

  * checkpoint/restart: periodic async checkpoints (+ data-iterator and RNG
    state in ``extras``); on ANY step exception the driver restores the last
    checkpoint and resumes with bounded retries/backoff — preemption or a
    flaky worker costs at most ``ckpt_every`` steps.
  * straggler watchdog: per-step wall-time EMA + k*sigma threshold; slow
    steps are logged and counted.  On a real fleet this signal feeds
    re-slicing / hot-spare swap; here it drives tests and metrics.
  * elastic restart: restore onto a different mesh via checkpoint/reshard
    (exercised in tests/test_fault_tolerance.py).

This is the paper's farm with a *supervising emitter*: the stream items are
steps, workers are the mesh, the collector is the metrics sink, and the
feedback loop re-offloads failed work.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from .monitor import Monitor, StragglerWatchdog


@dataclasses.dataclass
class DriverConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_retries: int = 3
    retry_backoff_s: float = 0.5
    log_every: int = 10
    watchdog_k: float = 4.0


class TrainDriver:
    def __init__(self, train_step: Callable, state, pipeline,
                 config: DriverConfig, monitor: Optional[Monitor] = None,
                 fault_hook: Optional[Callable[[int], None]] = None):
        self.step_fn = train_step
        self.state = state
        self.pipeline = pipeline
        self.cfg = config
        self.ckpt = CheckpointManager(config.ckpt_dir, keep=config.keep)
        self.monitor = monitor or Monitor()
        self.watchdog = StragglerWatchdog(k=config.watchdog_k)
        self.fault_hook = fault_hook        # test hook: raise at step N
        self.restarts = 0

    # -- main loop -------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        step = int(np.asarray(jax.device_get(self.state["step"])))
        retries = 0
        while step < self.cfg.total_steps:
            batch = self.pipeline.get()
            if batch is None:
                break
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                t0 = time.perf_counter()
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                retries = 0
            except Exception as e:  # noqa: BLE001 - supervised retry
                retries += 1
                self.monitor.event("step_failure", step=step,
                                   error=f"{type(e).__name__}: {e}",
                                   retry=retries)
                if retries > self.cfg.max_retries:
                    raise
                time.sleep(self.cfg.retry_backoff_s * retries)
                self._restore()
                step = int(np.asarray(jax.device_get(self.state["step"])))
                continue

            if self.watchdog.observe(dt):
                self.monitor.event("straggler", step=step, step_time_s=dt,
                                   mean_s=self.watchdog.mean)
            self.monitor.log_step(step, metrics, dt)
            step += 1
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save_async(step, self.state,
                                     extras={"data": self.pipeline.state()})
        # final synchronous checkpoint
        self.ckpt.wait()
        self.ckpt.save(step, self.state,
                       extras={"data": self.pipeline.state()})
        return {"final_step": step, "restarts": self.restarts,
                "stragglers": self.watchdog.count,
                "history": self.monitor.history}

    def _restore(self) -> None:
        self.ckpt.wait()
        latest = self.ckpt.latest()
        if latest is None:
            return                      # nothing saved yet: retry in place
        self.state, extras = self.ckpt.restore(self.state)
        if extras.get("data"):
            self.pipeline.source.restore(extras["data"])
        self.restarts += 1
        self.monitor.event("restart", from_step=latest)
