"""Step functions: train_step / prefill_step / decode_step factories.

The train step is the device lowering of the outer farm skeleton:
  emitter   = batch sharding over (pod, data)
  workers   = SPMD model replicas (each internally a map/pipeline skeleton)
  collector = gradient reduction (reduce-scatter over data via FSDP
              shardings; all-reduce over pod, optionally int8-EF-compressed)
  feedback  = the optimizer update + grad-accumulation loop (wrap_around)

Only the layer scans (and the optional grad-accumulation scan) introduce
``while`` loops — launch/dryrun.py depends on this (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import Config
from ..core.plan import ShardingPlan
from ..models import params as pp
from ..models.lm import LM
from ..optim import clip_by_global_norm, ef_compress_grads, make_optimizer


# ---------------------------------------------------------------------------
def make_model(cfg: Config) -> LM:
    return LM(cfg)


def state_defs(cfg: Config, plan: ShardingPlan):
    """ParamDef trees for params and optimizer state (for dry-run structs
    and checkpoint layouts)."""
    model = LM(cfg)
    pdefs = model.param_defs()
    return pdefs


def init_state(cfg: Config, plan: ShardingPlan, key, optimizer=None):
    model = LM(cfg)
    opt = optimizer or make_optimizer(cfg.optimizer)
    params = model.init(key)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_shardings(cfg: Config, plan: ShardingPlan, optimizer=None):
    """NamedShardings for the full train state (params + opt + step)."""
    model = LM(cfg)
    opt = optimizer or make_optimizer(cfg.optimizer)
    pdefs = model.param_defs()
    p_sh = pp.shardings(pdefs, plan)
    ax_tree = opt.state_axes(pdefs)
    rep = NamedSharding(plan.mesh, P())

    def ax_to_sh(ax):
        if ax == () or ax is None:
            return rep
        return NamedSharding(plan.mesh, plan.param_spec(ax))  # shapes match params
    o_sh = jax.tree.map(ax_to_sh, ax_tree,
                        is_leaf=lambda x: isinstance(x, tuple))
    return {"params": p_sh, "opt": o_sh, "step": rep}


def state_structs(cfg: Config, plan: ShardingPlan, optimizer=None):
    """ShapeDtypeStructs for the train state — dry-run stand-ins."""
    model = LM(cfg)
    opt = optimizer or make_optimizer(cfg.optimizer)
    pdefs = model.param_defs()
    p_st = pp.shape_structs(pdefs, plan)

    ax_tree = opt.state_axes(pdefs)
    flat_defs = jax.tree.leaves(pdefs, is_leaf=pp.is_def)

    # build opt-state structs by pairing each param def with its state axes
    def build(defs, axes):
        if isinstance(axes, tuple):   # leaf: logical axes of a state tensor
            raise AssertionError
        return None

    def opt_struct(adef_ax, shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32,
                                    sharding=plan.sharding_for(adef_ax, shape))

    if cfg.optimizer == "adamw":
        mk = lambda d: jax.ShapeDtypeStruct(
            d.shape, jnp.float32, sharding=plan.sharding_for(d.axes, d.shape))
        o_st = {"m": jax.tree.map(mk, pdefs, is_leaf=pp.is_def),
                "v": jax.tree.map(mk, pdefs, is_leaf=pp.is_def),
                "count": jax.ShapeDtypeStruct((), jnp.int32,
                                              sharding=plan.sharding_for(()))}
    else:
        def mk(d):
            sh, ax = d.shape, tuple(d.axes)
            if len(sh) >= 2 and sh[-1] >= 128 and sh[-2] >= 128:
                return {"vr": opt_struct(ax[:-1], sh[:-1]),
                        "vc": opt_struct(ax[:-2] + ax[-1:], sh[:-2] + sh[-1:])}
            return {"v": opt_struct(ax, sh)}
        o_st = {"s": jax.tree.map(mk, pdefs, is_leaf=pp.is_def),
                "count": jax.ShapeDtypeStruct((), jnp.int32,
                                              sharding=plan.sharding_for(()))}
    step = jax.ShapeDtypeStruct((), jnp.int32, sharding=plan.sharding_for(()))
    return {"params": p_st, "opt": o_st, "step": step}


# ---------------------------------------------------------------------------
def make_train_step(cfg: Config, plan: ShardingPlan, lr_fn: Callable,
                    optimizer=None, n_micro: Optional[int] = None,
                    max_grad_norm: float = 1.0,
                    compress_pod_grads: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""
    model = LM(cfg)
    opt = optimizer or make_optimizer(cfg.optimizer)
    n_micro = n_micro or cfg.n_microbatches

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, plan)
        return loss, metrics

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state, batch):
        params = state["params"]
        if n_micro > 1:
            micro = jax.tree.map(
                lambda t: t.reshape((n_micro, t.shape[0] // n_micro)
                                    + t.shape[1:]), batch)

            def body(carry, mb):
                acc, loss_acc = carry
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = lax.scan(body, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = loss_sum / n_micro
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = lr_fn(state["step"])
        new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics or {})
        metrics.update({"loss": loss, "grad_norm": gnorm, "lr": lr})
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: Config, plan: ShardingPlan, cache_len: int):
    model = LM(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch, plan, cache_len=cache_len)

    return prefill_step


def make_decode_step(cfg: Config, plan: ShardingPlan, cache_len: int):
    model = LM(cfg)
    cfg.cache_len = (min(cache_len, cfg.window) if cfg.attn_kind == "swa"
                     else cache_len)

    def decode_step(params, caches, batch):
        logits, new_caches = model.decode_step(params, caches, batch, plan)
        # greedy token for the feedback loop (argmax over vocab-sharded dim)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, new_caches

    return decode_step
