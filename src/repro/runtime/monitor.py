"""Metrics sink + straggler detection (the collector of the supervising
farm).  Plain-python, dependency-free; a fleet deployment would point
``emit`` at its telemetry bus."""

from __future__ import annotations

import math
import time
from typing import Any, Dict, List, Optional

import numpy as np


class Monitor:
    def __init__(self, log_fn=print, log_every: int = 10):
        self.history: List[Dict[str, Any]] = []
        self.events: List[Dict[str, Any]] = []
        self.log_fn = log_fn
        self.log_every = log_every

    def log_step(self, step: int, metrics: Dict[str, Any], dt: float) -> None:
        rec = {"step": step, "dt": dt}
        for k, v in metrics.items():
            try:
                rec[k] = float(np.asarray(v))
            except Exception:   # noqa: BLE001
                pass
        self.history.append(rec)
        if self.log_fn and step % self.log_every == 0:
            loss = rec.get("loss", float("nan"))
            self.log_fn(f"step {step:6d} loss {loss:.4f} "
                        f"({dt*1e3:.0f} ms/step)")

    def event(self, kind: str, **kw) -> None:
        rec = {"kind": kind, "time": time.time(), **kw}
        self.events.append(rec)
        if self.log_fn:
            self.log_fn(f"[{kind}] {kw}")


class StragglerWatchdog:
    """EMA mean/var of step time; observe() -> True when a step exceeds
    mean + k*std (the signal that would trigger re-slicing on a fleet)."""

    def __init__(self, k: float = 4.0, alpha: float = 0.1,
                 warmup: int = 5, min_threshold_s: float = 1e-4):
        self.k = k
        self.alpha = alpha
        self.warmup = warmup
        self.min_threshold_s = min_threshold_s
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.count = 0

    def observe(self, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            # prime the EMA
            self.mean = dt if self.n == 1 else \
                (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        is_straggler = dt > max(self.mean + self.k * math.sqrt(self.var),
                                self.mean * 1.5, self.min_threshold_s)
        if is_straggler:
            self.count += 1
        else:
            self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = (1 - self.alpha) * self.var \
                + self.alpha * (dt - self.mean) ** 2
        return is_straggler
