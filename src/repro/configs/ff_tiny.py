"""Config for --arch ff-tiny (see assignment table; source tier noted)."""

from .base import Config
from .registry import register

CONFIG = register(Config(
    name="ff-tiny", family="dense", source="demo",
    n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=1024, vocab=4096, act="silu", attn_parallel="heads", n_kv_eff=2,
    q_block=2048, kv_block=2048))
