"""Config for --arch xlstm-125m (see assignment table; source tier noted)."""

from .base import Config
from .registry import register

CONFIG = register(Config(
    name="xlstm-125m", family="ssm", source="arXiv:2405.04517; unverified",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab=50304, act="gelu", attn_parallel="heads",
    ssm_expand=2, ssm_conv=4, gla_chunk=256, tie_embeddings=True,
    use_rope=False,
    segments_spec=[("mlstm", 3), ("slstm", 1)] * 3))
