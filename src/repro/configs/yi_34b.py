"""Config for --arch yi-34b (see assignment table; source tier noted)."""

from .base import Config
from .registry import register

CONFIG = register(Config(
    name="yi-34b", family="dense", source="arXiv:2403.04652; hf",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000, act="silu", attn_parallel="cp",
    rope_theta=5e6, loss_chunks=2, kv_block=512))
