"""Config for --arch whisper-medium (see assignment table; source tier noted)."""

from .base import Config
from .registry import register

CONFIG = register(Config(
    name="whisper-medium", family="encdec",
    source="arXiv:2212.04356; unverified",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51872,            # padded from 51865 to %16
    act="gelu", norm="ln", use_rope=False, attn_parallel="heads",
    enc_layers=24, dec_layers=24, enc_len=4096, tie_embeddings=True))
