"""Config for --arch llama3.2-3b (see assignment table; source tier noted)."""

from .base import Config
from .registry import register

CONFIG = register(Config(
    name="llama3.2-3b", family="dense",
    source="hf:meta-llama/Llama-3.2-3B; unverified",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=128256, act="silu", attn_parallel="cp",
    rope_theta=5e5, loss_chunks=4, kv_block=512))
