"""Config for --arch gemma-7b (see assignment table; source tier noted)."""

from .base import Config
from .registry import register

CONFIG = register(Config(
    name="gemma-7b", family="dense", source="arXiv:2403.08295; hf",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000, act="gelu", attn_parallel="heads",
    rope_theta=1e4, tie_embeddings=True, loss_chunks=8))
