"""Config for --arch qwen2-vl-2b (see assignment table; source tier noted)."""

from .base import Config
from .registry import register

CONFIG = register(Config(
    name="qwen2-vl-2b", family="vlm", source="arXiv:2409.12191; hf",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936, act="silu", attn_parallel="cp",
    mrope=True, rope_theta=1e6, loss_chunks=4, kv_block=512))
