"""Architecture config schema + assigned input shapes.

Every assigned architecture is a ``Config`` in its own module
(``configs/<id>.py``) selectable via ``--arch <id>`` (configs/registry.py).
``input_specs`` builds ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no allocation) for every (arch x shape) dry-run cell.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


# the assigned shape grid (LM transformer shapes) -----------------------------
SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k":    {"seq": 4096,   "batch": 256, "mode": "train"},
    "prefill_32k": {"seq": 32768,  "batch": 32,  "mode": "prefill"},
    "decode_32k":  {"seq": 32768,  "batch": 128, "mode": "decode"},
    "long_500k":   {"seq": 524288, "batch": 1,   "mode": "decode"},
}


@dataclasses.dataclass
class Config:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    source: str = ""                 # provenance note

    # attention
    attn_kind: str = "full"          # full | swa
    window: int = 0
    rope_theta: float = 1e4
    use_rope: bool = True
    mrope: bool = False
    attn_parallel: str = "heads"     # heads | cp
    padded_heads: int = 0            # TP head padding (deployment option)
    n_kv_eff: int = 0                # kv heads after TP replication
    cache_len: Optional[int] = None  # set by prefill()/cache_defs()

    # norms / activations
    norm: str = "rms"                # rms | ln
    act: str = "silu"                # silu | gelu

    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_mode: str = "ep"             # ep | tp

    # ssm / linear recurrence
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    gla_chunk: int = 256

    # hybrid (zamba) / encdec (whisper)
    shared_attn_window: int = 0
    segments_spec: Optional[List[Tuple[str, int]]] = None
    enc_layers: int = 0
    dec_layers: int = 0
    enc_len: int = 4096              # cross-attention context at decode

    # training
    tie_embeddings: bool = False
    optimizer: str = "adamw"         # adamw | adafactor
    loss_chunks: int = 1
    n_microbatches: int = 1
    q_block: int = 2048
    kv_block: int = 2048
    use_pallas: bool = False         # Pallas kernels (TPU); XLA fallback here

    def __post_init__(self):
        if self.head_dim == 0:
            self.head_dim = self.d_model // self.n_heads
        if self.n_kv_eff == 0:
            self.n_kv_eff = (max(self.n_kv_heads, 16)
                             if self.attn_parallel == "heads"
                             else self.n_kv_heads)

    # -- structure -----------------------------------------------------------
    @property
    def segments(self) -> List[Tuple[str, int]]:
        if self.segments_spec is not None:
            return self.segments_spec
        if self.family == "encdec":
            return [("enc", self.enc_layers), ("dec", self.dec_layers)]
        if self.family == "moe":
            return [("moe", self.n_layers)]
        return [("dense", self.n_layers)]

    def stack_sizes(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for kind, count in self.segments:
            out[kind] = out.get(kind, 0) + (1 if kind == "shared_attn" else count)
        return out

    @property
    def subquadratic(self) -> bool:
        return (self.family in ("ssm", "hybrid")
                or (self.attn_kind == "swa"))

    def supports(self, shape_name: str) -> bool:
        if shape_name == "long_500k":
            return self.subquadratic
        return True

    def skip_reason(self, shape_name: str) -> str:
        if shape_name == "long_500k" and not self.subquadratic:
            return ("pure full-attention arch: 512k decode needs "
                    "sub-quadratic attention (see DESIGN.md)")
        return ""

    # -- parameter counts for MODEL_FLOPS -------------------------------------
    def n_params(self) -> int:
        from ..models.lm import LM
        from ..models.params import count_params
        return count_params(LM(self).param_defs())

    def n_params_active(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.n_params()
        from ..models.lm import LM
        from ..models.params import count_params, is_def
        defs = LM(self).param_defs()
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                defs, is_leaf=is_def)[0]:
            keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
            n = math.prod(leaf.shape)
            if any(k in ("wi", "wg", "wo") for k in keys) and \
                    "moe" in keys and "shared" not in keys:
                n = n * self.top_k // self.n_experts
            total += n
        return total

    def model_flops(self, shape_name: str) -> float:
        """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference forward), with
        N = active params, D = tokens processed by the step."""
        sh = SHAPES[shape_name]
        n = self.n_params_active()
        if sh["mode"] == "train":
            tokens = sh["seq"] * sh["batch"]
            return 6.0 * n * tokens
        if sh["mode"] == "prefill":
            tokens = sh["seq"] * sh["batch"]
            return 2.0 * n * tokens
        tokens = sh["batch"]          # one new token per sequence
        return 2.0 * n * tokens

    # -- reduced config for CPU smoke tests ------------------------------------
    def reduced(self) -> "Config":
        r = dataclasses.replace(
            self,
            n_layers=2, d_model=64,
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16, d_ff=128, vocab=256,
            n_kv_eff=min(self.n_kv_heads, 2),
            window=min(self.window, 32) if self.window else 0,
            shared_attn_window=min(self.shared_attn_window, 32)
            if self.shared_attn_window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=64 if self.moe_d_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state or self.family == "ssm" else 64,
            gla_chunk=16,
            enc_layers=min(self.enc_layers, 2),
            dec_layers=min(self.dec_layers, 2),
            enc_len=64,
            loss_chunks=1, q_block=32, kv_block=32,
            segments_spec=self._reduced_segments(),
        )
        return r

    def _reduced_segments(self):
        if self.segments_spec is None:
            return None
        if self.family == "hybrid":
            return [("mamba2", 2), ("shared_attn", 1), ("mamba2", 2)]
        if self.family == "ssm":
            return [("mlstm", 2), ("slstm", 1)]
        return None


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins)
# ---------------------------------------------------------------------------
def _sds(shape, dtype, plan=None, axes=None):
    if plan is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=plan.sharding_for(axes, shape))


def batch_specs(cfg: Config, shape_name: str, plan=None, batch=None, seq=None):
    """Model-input stand-ins for a shape cell (dry-run pattern: weak-type
    correct, shardable, zero allocation).  Frontends are stubs: [audio]/[vlm]
    get precomputed frame/patch embeddings."""
    sh = SHAPES[shape_name]
    B = batch if batch is not None else sh["batch"]
    S = seq if seq is not None else sh["seq"]
    mode = sh["mode"]
    i32, bf16 = jnp.int32, jnp.bfloat16
    bax = ("batch",)

    if mode in ("train", "prefill"):
        if cfg.family == "encdec":
            dec = max(32, S // 8)
            return {"frames": _sds((B, S, cfg.d_model), bf16, plan,
                                   ("batch", None, None)),
                    "tokens": _sds((B, dec), i32, plan, ("batch", None))}
        out = {"tokens": _sds((B, S), i32, plan, ("batch", None))}
        if cfg.family == "vlm":
            out["embeds"] = _sds((B, S, cfg.d_model), bf16, plan,
                                 ("batch", None, None))
            out["mrope_positions"] = _sds((3, B, S), i32, plan,
                                          (None, "batch", None))
        return out

    # decode: one new token against a cache of length S
    out = {"token": _sds((B, 1), i32, plan, ("batch", None)),
           "pos": _sds((), i32, plan, ())}
    if cfg.family == "vlm":
        out["embeds"] = _sds((B, 1, cfg.d_model), bf16, plan,
                             ("batch", None, None))
        out["mrope_positions"] = _sds((3, B, 1), i32, plan,
                                      (None, "batch", None))
    return out


def cache_specs(cfg: Config, B: int, S: int, plan=None):
    from ..models.lm import LM
    defs = LM(cfg).cache_defs(B, S)
    def leaf(t):
        shape, dtype, axes = t
        return _sds(shape, dtype, plan, axes)
    return jax.tree.map(leaf, defs,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 3 and isinstance(x[0], tuple))
