from .base import SHAPES, Config, batch_specs, cache_specs
from .registry import ASSIGNED, get, names, register

__all__ = ["SHAPES", "Config", "batch_specs", "cache_specs",
           "ASSIGNED", "get", "names", "register"]
