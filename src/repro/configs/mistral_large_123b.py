"""Config for --arch mistral-large-123b (see assignment table; source tier noted)."""

from .base import Config
from .registry import register

CONFIG = register(Config(
    name="mistral-large-123b", family="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=32768, act="silu", attn_parallel="heads",
    rope_theta=1e6, optimizer="adafactor", n_microbatches=1))
