"""Config for --arch zamba2-1.2b (see assignment table; source tier noted)."""

from .base import Config
from .registry import register

CONFIG = register(Config(
    name="zamba2-1.2b", family="hybrid", source="arXiv:2411.15242; hf",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000, act="gelu", attn_parallel="heads",
    attn_kind="swa", window=4096, shared_attn_window=4096,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_groups=1, ssm_conv=4,
    segments_spec=([("mamba2", 6), ("shared_attn", 1)] * 5
                   + [("mamba2", 8)])))
