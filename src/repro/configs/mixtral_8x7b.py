"""Config for --arch mixtral-8x7b (see assignment table; source tier noted)."""

from .base import Config
from .registry import register

CONFIG = register(Config(
    name="mixtral-8x7b", family="moe", source="arXiv:2401.04088; hf",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000, act="silu", attn_parallel="heads",
    attn_kind="swa", window=4096,
    n_experts=8, top_k=2, moe_d_ff=14336, moe_mode="tp",
    rope_theta=1e6))
