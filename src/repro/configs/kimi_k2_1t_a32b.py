"""Config for --arch kimi-k2-1t-a32b (see assignment table; source tier noted)."""

from .base import Config
from .registry import register

CONFIG = register(Config(
    name="kimi-k2-1t-a32b", family="moe",
    source="arXiv:2501.kimi2 (paper-table); unverified",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, vocab=163840, act="silu", attn_parallel="heads",
    n_experts=384, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    moe_mode="ep", optimizer="adafactor", loss_chunks=4,
    rope_theta=5e6))
