"""--arch <id> registry: the 10 assigned architectures (+ tiny demo config).

Sources ([tier]) are recorded on each Config; exact numbers follow the
assignment table.
"""

from __future__ import annotations

from .base import Config

_REGISTRY = {}


def register(cfg: Config) -> Config:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> Config:
    import copy
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return copy.deepcopy(_REGISTRY[name])


def names():
    return sorted(_REGISTRY)



def _load_all():
    # one module per assigned architecture (deliverable f)
    from . import (gemma_7b, yi_34b, mistral_large_123b, llama3_2_3b,
                   kimi_k2_1t_a32b, mixtral_8x7b, xlstm_125m, zamba2_1_2b,
                   whisper_medium, qwen2_vl_2b, ff_tiny)  # noqa: F401


_load_all()
ASSIGNED = [n for n in names() if n != "ff-tiny"]
