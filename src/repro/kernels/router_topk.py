"""MoE top-k router + capacity dispatch — Pallas TPU kernel.

The farm emitter's ``selectworker`` as a kernel: per token block, compute
softmax + iterative top-k (K is small), then the capacity-bounded position
of every (token, k) slot in its expert lane.  The running per-expert
counters live in fp32/int32 VMEM scratch and carry across token blocks (the
grid's sequential dimension) — first-come-first-served lane occupancy,
exactly like the bounded SPSC queue it models.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import default_interpret

NEG_INF = -1.0e38


def _kernel(logits_ref, w_ref, idx_ref, pos_ref, keep_ref, counts_ref, *,
            K, E, capacity, bt):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    logits = logits_ref[...].astype(jnp.float32)          # (bt, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # iterative top-k (K small)
    masked = probs
    ws, idxs = [], []
    for _ in range(K):
        w = jnp.max(masked, axis=-1)
        i = jnp.argmax(masked, axis=-1)
        ws.append(w)
        idxs.append(i)
        masked = jnp.where(jax.nn.one_hot(i, E, dtype=jnp.bool_),
                           NEG_INF, masked)
    w = jnp.stack(ws, axis=-1)                            # (bt, K)
    idx = jnp.stack(idxs, axis=-1).astype(jnp.int32)      # (bt, K)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)

    # positions: running expert counters + rank within this block
    flat = idx.reshape(bt * K)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)     # (bt*K, E)
    within = jnp.cumsum(onehot, axis=0) - onehot          # exclusive rank
    base = counts_ref[...]                                # (E,)
    pos = (within + base[None, :])                        # (bt*K, E)
    pos = jnp.sum(pos * onehot, axis=-1)                  # (bt*K,)
    keep = pos < capacity

    w_ref[...] = w.astype(w_ref.dtype)
    idx_ref[...] = idx
    pos_ref[...] = pos.reshape(bt, K).astype(jnp.int32)
    keep_ref[...] = keep.reshape(bt, K)
    counts_ref[...] = base + jnp.sum(onehot, axis=0)


def router_topk(logits, top_k: int, capacity: int, *, block_t: int = 256,
                interpret: Optional[bool] = None):
    """logits: (T, E) -> (weights (T,K) f32, experts (T,K) i32,
    positions (T,K) i32, keep (T,K) bool).  ``interpret=None`` resolves via
    :mod:`kernels.backend`: Mosaic on TPU, Python interpreter elsewhere."""
    interpret = default_interpret(interpret)
    T, E = logits.shape
    bt = min(block_t, T)
    assert T % bt == 0, (T, bt)
    nt = T // bt
    kernel = functools.partial(_kernel, K=top_k, E=E, capacity=capacity,
                               bt=bt)
    return pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((bt, E), lambda t: (t, 0))],
        out_specs=[
            pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
            pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
            pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
            pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, top_k), jnp.float32),
            jax.ShapeDtypeStruct((T, top_k), jnp.int32),
            jax.ShapeDtypeStruct((T, top_k), jnp.int32),
            jax.ShapeDtypeStruct((T, top_k), jnp.bool_),
        ],
        scratch_shapes=[pltpu.VMEM((E,), jnp.int32)],
        interpret=interpret,
    )(logits)
