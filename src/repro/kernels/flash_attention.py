"""Blocked (flash) GQA attention — Pallas TPU kernel.

TPU adaptation of the paper's L1 insight: the HBM->VMEM block stream is a
double-buffered SPSC channel; the grid's sequential minor dimension streams
KV blocks past resident Q blocks with running-softmax state in VMEM scratch
(producer = Pallas prefetch pipeline, consumer = MXU matmuls).

Grid: (B, H, n_q_blocks, n_kv_blocks) — the last dimension iterates
sequentially on TPU, so the fp32 (acc, m, l) scratch carries across KV
blocks of one Q tile.  Causal/SWA masking is applied per block; fully-masked
blocks are skipped with pl.when (the FLOP savings the XLA fallback realizes
by trace-time block skipping).

GQA: the KV head index map is h -> h // group, so KV heads are never
materialized repeated.  Block shapes are MXU-aligned (multiples of 128 on
the contracting/lane dims when shapes allow).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import default_interpret

NEG_INF = -1.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, bq, bk, nk, q_offset):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = iq * bq + q_offset          # global position of first query
    k_lo = jk * bk
    # block-level reachability (static shapes, dynamic predicate)
    reachable = jnp.logical_and(
        jnp.logical_or(not causal, k_lo <= q_lo + bq - 1),
        jnp.logical_or(window <= 0, k_lo + bk - 1 > q_lo - window))

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, P)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """q: (B,H,Sq,D); k,v: (B,Hkv,Sk,D), Hkv | H.  Returns (B,H,Sq,D).
    Queries are aligned to the END of the key sequence (self-attention when
    Sq == Sk; incremental/chunked prefill when Sq < Sk).  ``interpret=None``
    resolves via :mod:`kernels.backend` (Mosaic on TPU)."""
    interpret = default_interpret(interpret)
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(D)
    q_offset = Sk - Sq

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),     # running max
            pltpu.VMEM((bq,), jnp.float32),     # running denom
        ],
        interpret=interpret,
    )(q, k, v)
