"""Chunked gated linear recurrence (SSD / Mamba2 / mLSTM core) — Pallas TPU
kernel.

    h_t = exp(log_a_t) * h_{t-1} + k_t v_t^T ;   y_t = q_t . h_t

The chunk axis is the grid's sequential minor dimension: the (N, P) state
matrix lives in fp32 VMEM scratch and carries chunk-to-chunk — the feedback
(wrap_around) skeleton implemented at the register/VMEM level.  Intra-chunk
work is dense MXU matmuls (Q x Q decay-masked scores), exactly mirroring
models/ssm.chunked_gla; the oracle is kernels/ref.ssd_scan_ref.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import default_interpret


def _kernel(q_ref, k_ref, v_ref, la_ref, y_ref, state_ref, *, Q):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (Q, N)
    k = k_ref[0, 0].astype(jnp.float32)          # (Q, N)
    v = v_ref[0, 0].astype(jnp.float32)          # (Q, P)
    la = la_ref[0, 0].astype(jnp.float32)        # (Q,)

    cum = jnp.cumsum(la)                         # inclusive
    tot = cum[-1]

    # intra-chunk: scores[t,s] = q_t.k_s * exp(cum_t - cum_s), s <= t
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (Q, Q)
    decay = jnp.exp(jnp.clip(cum[:, None] - cum[None, :], -60.0, 0.0))
    ti = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    w = jnp.where(si <= ti, s * decay, 0.0)
    y_intra = jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())))

    # inter-chunk: y_t += exp(cum_t) q_t . h_in
    h_in = state_ref[...]
    y_inter = jnp.exp(jnp.clip(cum, -60.0, 0.0))[:, None] * \
        jax.lax.dot_general(q, h_in, (((1,), (0,)), ((), ())))

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h_out = exp(tot) h_in + sum_s exp(tot - cum_s) k_s v_s^T
    dk = jnp.exp(jnp.clip(tot - cum, -60.0, 0.0))[:, None] * k    # (Q, N)
    inc = jax.lax.dot_general(dk, v, (((0,), (0,)), ((), ())))    # (N, P)
    state_ref[...] = jnp.exp(jnp.clip(tot, -60.0, 0.0)) * h_in + inc


def ssd_scan(q, k, v, log_a, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    """q,k: (B,H,S,N); v: (B,H,S,P); log_a: (B,H,S) -> y (B,H,S,P).
    ``interpret=None`` resolves via :mod:`kernels.backend` (Mosaic on TPU)."""
    interpret = default_interpret(interpret)
    B, H, S, N = q.shape
    P = v.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    kernel = functools.partial(_kernel, Q=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), q.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_a)
