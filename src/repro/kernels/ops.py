"""jit'd public wrappers for the Pallas kernels, with XLA fallbacks and
recompute-from-oracle backward passes.

On this CPU container the kernels run under ``interpret=True`` (the kernel
body executes in Python) — correctness validation only.  On TPU the same
``pl.pallas_call`` lowers to Mosaic.  ``custom_vjp`` backward recomputes
through the ref oracle (forward-optimized; dedicated bwd kernels are listed
as future perf headroom in EXPERIMENTS.md §Perf)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .backend import on_tpu as _on_tpu  # noqa: F401 - re-exported; the
# kernels' own interpret=None defaults resolve through kernels.backend, so
# the explicit interpret= threading below is belt-and-braces documentation
# of the contract: Mosaic on TPU, Python interpreter elsewhere.
from .flash_attention import flash_attention as _flash_fwd
from .router_topk import router_topk as _router_fwd
from .ssd_scan import ssd_scan as _ssd_fwd


# -- flash attention -----------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal=True, window=0, block=128):
    return _flash_fwd(q, k, v, causal=causal, window=window,
                      block_q=block, block_k=block,
                      interpret=not _on_tpu())


def _fa_fwd(q, k, v, causal, window, block):
    return flash_attention(q, k, v, causal, window, block), (q, k, v)


def _fa_bwd(causal, window, block, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: ref.attention_ref(
        q, k, v, causal=causal, window=window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# -- chunked linear recurrence ----------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def ssd_scan(q, k, v, log_a, chunk=128):
    return _ssd_fwd(q, k, v, log_a, chunk=chunk, interpret=not _on_tpu())


def _ssd_fwd_rule(q, k, v, log_a, chunk):
    return ssd_scan(q, k, v, log_a, chunk), (q, k, v, log_a)


def _ssd_bwd_rule(chunk, res, g):
    q, k, v, log_a = res
    _, vjp = jax.vjp(ref.ssd_scan_ref, q, k, v, log_a)
    return vjp(g)


ssd_scan.defvjp(_ssd_fwd_rule, _ssd_bwd_rule)


# -- router (routing itself carries no gradient; weights do, upstream) ------------
def router_topk(logits, top_k: int, capacity: int, block_t: int = 256):
    return _router_fwd(jax.lax.stop_gradient(logits), top_k, capacity,
                       block_t=block_t, interpret=not _on_tpu())
