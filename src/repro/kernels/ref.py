"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately naive (materialize the score matrix, sequential
recurrences) — clarity over speed.  tests/test_kernels.py sweeps shapes and
dtypes asserting the kernels (interpret=True on CPU) match these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,H,Sq,D); k,v: (B,Hkv,Sk,D); GQA by head repetition."""
    B, H, Sq, D = q.shape
    Hkv = k.shape[1]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=1)
        v = jnp.repeat(v, H // Hkv, axis=1)
    Sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)   # q aligned to the end of k
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ssd_scan_ref(q, k, v, log_a):
    """Sequential gated linear recurrence (the oracle for the chunked
    kernel):  h_t = a_t h_{t-1} + k_t v_t^T ; y_t = q_t . h_t.
    q,k: (B,H,S,N); v: (B,H,S,P); log_a: (B,H,S)."""
    B, H, S, N = q.shape
    P = v.shape[-1]

    def step(h, xs):
        qt, kt, vt, lat = xs
        h = jnp.exp(lat)[..., None, None] * h \
            + kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhn,bhnp->bhp", qt, h)
        return h, y

    qs = jnp.moveaxis(q.astype(jnp.float32), 2, 0)
    ks = jnp.moveaxis(k.astype(jnp.float32), 2, 0)
    vs = jnp.moveaxis(v.astype(jnp.float32), 2, 0)
    las = jnp.moveaxis(log_a.astype(jnp.float32), 2, 0)
    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (qs, ks, vs, las))
    return jnp.moveaxis(ys, 0, 2).astype(q.dtype)   # (B,H,S,P)


def a2a_fused_ref(logits, xs, expert_fns, capacity: int):
    """Naive oracle for the fused all-to-all hop: top-1 route per token,
    first-come capacity position, routed expert applied directly, dropped
    tokens zero-filled.  logits: (T, E); xs: (T, *item).  Returns
    ``(out, keep)`` — the kernel must match bit-for-bit (combine is pure
    selection, never arithmetic)."""
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    idx = jnp.argmax(probs, axis=-1).astype(jnp.int32)          # (T,)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1     # FCFS rank
    keep = pos < capacity
    outs = jnp.stack([jax.vmap(fn)(xs) for fn in expert_fns])   # (E, T, ...)
    out = outs[0]
    for j in range(1, E):
        sel = (idx == j).reshape((T,) + (1,) * (out.ndim - 1))
        out = jnp.where(sel, outs[j], out)
    mask = keep.reshape((T,) + (1,) * (out.ndim - 1))
    return jnp.where(mask, out, jnp.zeros_like(out)), keep


def router_topk_ref(logits, top_k: int, capacity: int):
    """Top-k routing with capacity-bounded positions (first-come order).
    logits: (T, E) fp32.  Returns (weights (T,K), experts (T,K),
    positions (T,K), keep (T,K))."""
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    flat_e = idx.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
    keep = pos < capacity
    return (w, idx, pos.reshape(T, top_k).astype(jnp.int32),
            keep.reshape(T, top_k))
